"""Fig. 3 / Listing 2: distributed IoT AI with stream pub/sub.

Two Raspberry-Pi-class camera devices (C1, C2) publish frames under topics;
a processing device (P, "Coral accelerator") subscribes to one stream, runs
object detection, and republishes the inference; a display device (D) muxes
both camera streams + the inference overlay with timestamp synchronization
(§4.2.3) despite skewed device clocks.

    PYTHONPATH=src python examples/multicam_pubsub.py
"""
import jax
import jax.numpy as jnp

from repro.core import SimClock, TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.runtime import Device, Runtime


def init(rng):
    return {"w": jax.random.normal(rng, (2304, 4 + 8)) * 0.02}


def apply(p, x):
    z = x.astype(jnp.float32).reshape(1, -1) @ p["w"]
    return jax.nn.sigmoid(z[:, :4]), jax.nn.softmax(z[0, 4:])


register_model("detector", init, apply,
               out_specs=(TensorSpec((1, 4), "float32"),
                          TensorSpec((8,), "float32")))

rt = Runtime()

# camera devices with skewed clocks (real consumer devices disagree on time)
for side, skew_ms in (("left", 0), ("right", 40)):
    cam = Device(f"cam_{side}", clock=SimClock(skew_ns=skew_ms * 1_000_000))
    p = parse_launch(f"""
        testsrc name=v4l2src width=32 height=24 ! tensor_converter !
          queue leaky=2 ! mqttsink pub-topic=edge/cam/{side}
    """)
    cam.add_pipeline(p, jit=False)
    rt.add_device(cam)

# processing device: subscribe left camera, detect, republish
proc = Device("coral")
pp = parse_launch("""
    mqttsrc sub-topic=edge/cam/left is-live=false !
      tensor_transform mode=arithmetic option=typecast:float32,div:255.0 !
      tensor_filter framework=jax model=detector !
      mqttsink pub-topic=edge/inference
""")
proc.add_pipeline(pp, jit=False)
rt.add_device(proc)

# display device: mux cameras + inference (wildcard discovery, R3)
disp = Device("lcd")
pd = parse_launch("""
    mqttsrc sub-topic=edge/cam/left is-live=false ! queue ! mux.sink_0
    mqttsrc sub-topic=edge/cam/right is-live=false ! queue ! mux.sink_1
    tensor_mux name=mux ! appsink name=video
    mqttsrc sub-topic=edge/inference is-live=false ! queue ! appsink name=boxes
""")
disp.add_pipeline(pd, jit=False)
rt.add_device(disp)

rt.run(8)
run = disp.runs[0]
video = run.last_outputs["video"]
print(f"display muxed {run.frames} frames: "
      f"{[tuple(t.shape) for t in video.tensors]} pts={int(video.pts)}ns")
print(f"inference overlay: boxes={run.last_outputs['boxes'].tensors[0].shape}")
print(f"stats: {rt.stats()}")
assert run.frames >= 6
print("OK — 4 devices, 3 topics, NTP-aligned mux, <40 lines of pipeline code")
