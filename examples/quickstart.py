"""Quickstart: describe an AI pipeline as a gst-launch-style string, compile
it with jax.jit, and run frames through it — the pipe-and-filter core of the
paper in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model


# 1. register a model (any JAX init/apply pair; real apps use repro.models)
def init(rng):
    return {"w": jax.random.normal(rng, (768, 10)) * 0.05}


def apply(p, x):
    return jnp.mean(x.reshape(-1, 3), 0) @ p["w"][:3]


register_model("tiny", init, apply, out_specs=(TensorSpec((10,), "float32"),))

# 2. describe the pipeline (Listing-1 style)
pipe = parse_launch("""
    testsrc name=cam width=32 height=24 ! tee name=ts
    ts. queue leaky=2 ! videoconvert ! appsink name=preview
    ts. videoconvert ! videoscale ! video/x-raw,width=16,height=16,format=RGB !
        tensor_converter !
        tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 !
        tensor_filter model=tiny ! tensor_decoder mode=classification !
        appsink name=label
""").realize()
print(pipe.describe())

# 3. compile & run
params = pipe.init(jax.random.PRNGKey(0))
state = pipe.init_state()
step = jax.jit(pipe.step)
for i in range(5):
    outs, state = step(params, state)
    print(f"frame {i}: preview={outs['preview'].tensor.shape} "
          f"class={int(outs['label'].tensor)} pts={int(outs['label'].pts)}us")
print("OK")
