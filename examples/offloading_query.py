"""Fig. 2 / Listing 1: inference offloading with query elements.

Device A (a TV: camera + display, no NPU) runs the full UI pipeline but its
``tensor_filter`` is replaced by ``tensor_query_client`` — nothing else
changes (R1).  Device B (a phone) serves the model; a second phone joins and
the client fails over when the first dies (R3/R4).

    PYTHONPATH=src python examples/offloading_query.py
"""
import jax
import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.runtime import Device, Runtime


def init(rng):
    return {"w": jax.random.normal(rng, (300 * 300 * 3, 8)) * 0.01}


def apply(p, x):
    logits = x.astype(jnp.float32).reshape(1, -1) @ p["w"]
    boxes = jax.nn.sigmoid(logits[:, :4])
    scores = jax.nn.softmax(logits[:, 4:])[0]
    return boxes.reshape(1, 4), scores


register_model("ssd_v2", init, apply,
               out_specs=(TensorSpec((1, 4), "float32"),
                          TensorSpec((8,), "float32")))

SERVER = """
tensor_query_serversrc operation=objectdetection/ssdv2 name=ssrc !
  tensor_filter framework=jax model=ssd_v2 !
  tensor_query_serversink name=ssink
"""

CLIENT = """
testsrc name=v4l2src width=320 height=240 ! tee name=ts
ts. videoconvert ! videoscale ! video/x-raw,width=300,height=300,format=RGB !
  queue leaky=2 ! tensor_converter !
  tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 !
  tensor_query_client operation=objectdetection/ssdv2 name=qc !
  appsink name=boxes
ts. queue leaky=2 ! videoconvert ! appsink name=screen
"""

rt = Runtime()
for name in ("phoneB", "phoneC"):
    dev = Device(name)
    srv = parse_launch(SERVER)
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    dev.add_pipeline(srv, jit=False)
    rt.add_device(dev)
    # keep handles for the failover demo
    if name == "phoneB":
        primary = srv.elements["ssrc"]

tv = Device("tv")
cli = parse_launch(CLIENT)
tv.add_pipeline(cli, jit=False)
rt.add_device(tv)

rt.run(5)
out = tv.runs[0].last_outputs
print(f"5 frames offloaded: boxes={out['boxes'].tensors[0].shape} "
      f"screen={out['screen'].tensor.shape}")

# phoneB dies mid-stream -> client rebinds to phoneC (R4)
primary.endpoint.alive = False
rt.broker.mark_down(primary.registration)
rt.run(5)
qc = cli.elements["qc"]
print(f"after failover: frames={tv.runs[0].frames} "
      f"(failovers={qc.binding.failovers}) — service uninterrupted")
assert tv.runs[0].frames == 10
print("OK")
