"""Micro-batched inference offloading: one capable hub serving many weak
clients (paper §4.2.2 scaled up — DESIGN.md §2).

Eight TVs offload the same object-detection service to a single phone.
With query batching (default, ``query_batch=8``) the phone gathers the
eight concurrent requests that arrive each tick and serves them in ONE
compiled scan dispatch; each answer routes back by client id.  Setting
``query_batch=0`` restores the paper's one-round-trip-per-frame serving.

    PYTHONPATH=src python examples/batched_offloading.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.runtime import Device, Runtime

N_CLIENTS = 8
TICKS = 12


def init(rng):
    return {"w": jax.random.normal(rng, (48 * 48 * 3, 8)) * 0.01}


def apply(p, x):
    logits = x.astype(jnp.float32).reshape(1, -1) @ p["w"]
    boxes = jax.nn.sigmoid(logits[:, :4])
    scores = jax.nn.softmax(logits[:, 4:])[0]
    return boxes.reshape(1, 4), scores


register_model("ssd_tiny", init, apply,
               out_specs=(TensorSpec((1, 4), "float32"),
                          TensorSpec((4,), "float32")))

SERVER = """
tensor_query_serversrc operation=objdetect name=ssrc !
  tensor_filter framework=jax model=ssd_tiny !
  tensor_query_serversink name=ssink
"""

CLIENT = """
testsrc width=48 height=48 ! tensor_converter !
  tensor_query_client operation=objdetect name=qc ! appsink name=boxes
"""


def build(query_batch: int):
    rt = Runtime(query_batch=query_batch)
    phone = Device("phone")
    srv = parse_launch(SERVER)
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    srv_run = phone.add_pipeline(srv, jit=False)
    rt.add_device(phone)
    tvs = []
    for i in range(N_CLIENTS):
        tv = Device(f"tv{i}")
        tvs.append(tv.add_pipeline(parse_launch(CLIENT), jit=False))
        rt.add_device(tv)
    return rt, srv_run, tvs


for label, batch in (("batched (batch=8)", 8), ("sequential (batch=0)", 0)):
    rt, srv_run, tvs = build(batch)
    rt.run(2)  # warm the executable cache outside the timed window
    t0 = time.perf_counter()
    rt.run(TICKS)
    dt = time.perf_counter() - t0
    qb = rt.stats()["query_batching"]
    assert all(run.frames == TICKS + 2 for run in tvs)
    print(f"{label}: {N_CLIENTS} clients x {TICKS} ticks in {dt * 1e3:.0f}ms"
          f" — server dispatches: {qb['batches'] or qb['sequential_frames']}"
          f" ({qb['batched_frames']} frames batched,"
          f" {qb['sequential_frames']} sequential)")
    boxes = tvs[0].last_outputs["boxes"].tensors[0]
    print(f"  tv0 last boxes: {['%.2f' % float(v) for v in boxes[0]]}")

print("OK — every client answered every tick; batching only changed "
      "how many dispatches the phone paid")
