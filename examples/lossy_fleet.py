"""Among-device offloading over an ADVERSARIAL network (DESIGN.md §10).

Four TVs offload inference to a hub, but the links between them are the
opposite of reliable: both directions drop frames, duplicate frames,
flip bits in payloads — and mid-run the request link suffers a scripted
partition window during which *nothing* gets through.  The delivery
layer (delivery ids + CRC + timeout/backoff retransmit + idempotent
dedup) turns that at-least-once chaos into effectively-once serving:
every TV still collects its full answer budget, every answer is BITWISE
the one a fault-free twin computes, and the per-link message ledgers
balance exactly — zero silent loss, zero double-serves.

    PYTHONPATH=src python examples/lossy_fleet.py
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.core.netfault import DeliveryPolicy, FaultFabric, FaultPolicy
from repro.runtime import Device, Runtime

# the deterministic chaos harness the netfault tests and benchmark use —
# one copy of the lossy-link semantics, everywhere
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from chaoslib import lossy_endpoint  # noqa: E402

N_TVS = 4
BUDGET = 12          # answers each TV must collect
MAX_TICKS = 60       # liveness bound: chaos may stretch, not stall, the run

# the request link: drops, duplicates, corruption, AND a scripted
# partition — fault-clock ticks [10, 14) eat every frame silently
REQ_FAULTS = FaultPolicy(seed=11, drop=0.06, dup=0.03, corrupt=0.02,
                         partitions=((10, 14),))
# answer links (per-client seeds derived by the harness): drops + dups
ANS_FAULTS = FaultPolicy(seed=23, drop=0.05, dup=0.02, corrupt=0.01)


def init(rng):
    return {"w": jax.random.normal(rng, (48, 16)) * 0.05}


def apply(p, x):
    return jnp.tanh(x.astype(jnp.float32).reshape(1, -1) @ p["w"])


register_model("lossy_svc", init, apply,
               out_specs=(TensorSpec((1, 16), "float32"),))


def fleet():
    """One hub + N_TVS query clients, delivery layer ON."""
    rt = Runtime(query_batch=8, delivery=DeliveryPolicy())
    hub = Device("hub")
    srv = parse_launch(
        "tensor_query_serversrc operation=svc name=ssrc ! "
        "tensor_filter model=lossy_svc ! tensor_query_serversink name=ssink")
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    hub.add_pipeline(srv, jit=False)
    rt.add_device(hub)
    tvs = []
    for i in range(N_TVS):
        dev = Device(f"tv{i}")
        cli = parse_launch(
            "testsrc width=4 height=4 ! tensor_converter ! "
            "tensor_query_client operation=svc name=qc ! appsink name=res")
        tvs.append(dev.add_pipeline(cli, jit=False))
        rt.add_device(dev)
    return rt, srv.elements["ssrc"], tvs


def answers(tvs):
    return [[np.asarray(b.tensor) for b in tv.sink_log.get("res", ())]
            for tv in tvs]


# -- fault-free twin: the bitwise reference -----------------------------------
rt0, _, tvs0 = fleet()
rt0.run(BUDGET)
reference = answers(tvs0)

# -- the same fleet on hostile links ------------------------------------------
rt, ssrc, tvs = fleet()
fabric = FaultFabric()
rt.fabric = fabric                # the scheduler drives the fault clock
lossy_endpoint(fabric, ssrc.endpoint, REQ_FAULTS, ANS_FAULTS, name="svc")

ticks = 0
while ticks < MAX_TICKS and any(
        len(tv.sink_log.get("res", ())) < BUDGET for tv in tvs):
    rt.tick()
    ticks += 1

got = answers(tvs)
complete = all(len(g) >= BUDGET for g in got)
bitwise = all(np.array_equal(x, y)
              for ref, g in zip(reference, got)
              for x, y in zip(ref, g))
fabric.assert_conservation()      # every frame accounted, per link

# -- report -------------------------------------------------------------------
d = rt.stats()["delivery"]
print(f"{N_TVS} TVs x {BUDGET} answers over lossy links "
      f"(done in {ticks} ticks; fault-free twin took {BUDGET}):\n")
print(f"{'link':10s} {'sent':>5s} {'dropped':>8s} {'dup':>4s} "
      f"{'corrupt':>8s} {'deduped':>8s} {'accepted':>9s}")
for name, s in sorted(rt.stats()["netfault"].items()):
    print(f"{name:10s} {s['sent']:5d} {s['dropped_by_fault']:8d} "
          f"{s['injected_dups']:4d} {s['corrupted']:8d} "
          f"{s['deduped']:8d} {s['accepted']:9d}")
print(f"\ndelivery layer: {d['retransmits']} retransmits, "
      f"{d['deduped']} server dedups, {d['replayed']} answer replays, "
      f"{d['rejected_corrupt']} corrupt frames rejected, "
      f"{d['client_answer_dups']} client-side dups discarded, "
      f"{d['client_answer_corrupt']} corrupt answers rejected")

assert complete, [len(g) for g in got]
assert bitwise
print(f"\nOK — every TV got its {BUDGET} answers, each BITWISE the "
      f"fault-free twin's, and the message ledgers balance: the network "
      f"lied {sum(s['dropped_by_fault'] + s['corrupted'] for s in rt.stats()['netfault'].values())} "
      f"times and no client ever saw it")
