"""End-to-end training driver: train a ~30M-parameter LM (stablelm family,
reduced width for a 1-core CPU box) for a few hundred steps on the Markov
corpus and watch the loss drop.  On a TPU slice the same launcher trains the
full assigned configs on the production mesh.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    losses = train.main([
        "--arch", "stablelm-1.6b", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])
    assert losses[-1] < losses[0], "loss did not decrease"
    print("OK — loss decreased; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
