"""End-to-end driver (the paper is serving infrastructure, so the e2e run is
SERVING): the full assigned mamba2-130m — real 130M-parameter config, not a
smoke variant — served as an among-device query service with batched
requests from NNStreamer-Edge clients.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 8 --gen 16]

This exercises the whole stack: model zoo (SSD decode path), query protocol
(discovery + client-id routing), continuous batching, broker control plane.
"""
import argparse
import sys

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    ok = serve.main([
        "--arch", "mamba2-130m",            # FULL assigned config (130M)
        "--requests", str(args.requests),
        "--prompt-len", str(args.prompt_len),
        "--gen", str(args.gen),
    ])
    assert ok == args.requests
    print("OK — full mamba2-130m served batched requests end-to-end")


if __name__ == "__main__":
    main()
