"""Multi-tenant serving under overload: three tiers, one elastic fleet.

Nine clients share one inference hub under a three-tier QoS contract
(DESIGN.md §9): ``realtime`` (priority 0, strict deadline), ``standard``
(priority 1, rate-budgeted), ``best-effort`` (priority 2 — the tier that
sheds FIRST, explicitly).  The hub's serve capacity is capped at 3
requests/tick and the fleet at 2 replicas, so nine 1-req/tick clients are
a sustained overload even after scale-up.

Watch three §9 behaviors compose:

* **isolation** — realtime requests keep sub-tick latency through the
  overload; the queueing lands on best-effort;
* **explicit shedding** — best-effort/standard requests over budget come
  back as error frames with a reason, never silent drops, and the ledger
  balances to the conservation law admitted == served + shed + queued +
  in-flight (``Runtime.stats()`` asserts it);
* **elasticity** — the broker's queue-depth scaling signal trips the
  autoscaler, which grows replicas as ordinary §6 reconfigurations; when
  the burst ends the drained replica is removed the same way, zero loss.

    PYTHONPATH=src python examples/multitenant_fleet.py
"""
import os
import sys

import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.admission import QoSConfig, TenantSpec
from repro.core.elements import register_model
from repro.runtime import Device, Runtime
from repro.runtime.autoscale import Autoscaler

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from chaoslib import Chaos  # noqa: E402

TIERS = {"realtime": 3, "standard": 3, "best-effort": 3}   # clients each
TICKS_LOAD, TICKS_DRAIN = 18, 20


def init(rng):
    return {"w": jnp.full((12, 8), 0.25)}


def apply(p, x):
    return x.astype(jnp.float32).reshape(1, -1) @ p["w"]


register_model("mt_svc", init, apply,
               out_specs=(TensorSpec((1, 8), "float32"),))


def serve_ps():
    ps = parse_launch(
        "tensor_query_serversrc operation=infer name=ssrc ! "
        "tensor_filter model=mt_svc ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    return ps


def main():
    qos = QoSConfig(
        tenants=(
            TenantSpec("realtime", priority=0, deadline_ticks=4),
            TenantSpec("standard", priority=1, rate=1, burst=2),
            TenantSpec("best-effort", priority=2, deadline_ticks=6,
                       max_queue=4),
        ),
        default=TenantSpec(priority=2),
        serve_per_tick=3)                      # the overloaded capacity
    rt = Runtime(qos=qos)

    hub = Device("hub")
    hub.add_pipeline(serve_ps(), jit=False)
    rt.add_device(hub)

    clients = []
    for tier, n in TIERS.items():
        for i in range(n):
            dev = Device(f"{tier}-{i}")
            dev.add_pipeline(parse_launch(
                f"testsrc width=2 height=2 ! tensor_converter ! "
                f"tensor_query_client operation=infer tenant={tier} "
                f"name=qc ! appsink name=res"), jit=False)
            rt.add_device(dev)
            clients.append((tier, dev))

    asc = Autoscaler(rt, "query/infer", lambda i: serve_ps(),
                     high_load=3.0, low_load=0.5, max_replicas=2,
                     cooldown_ticks=3, warm_ticks=1)

    # scripted burst end: every client stops after the load phase, so the
    # fleet drains and the autoscaler removes the idle replicas
    chaos = Chaos(rt)
    for _, dev in clients:
        chaos.at(TICKS_LOAD + 1,
                 lambda d=dev: setattr(d, "alive", False), label=None)
    chaos.at(TICKS_LOAD + 1, lambda: None, label="burst ends (clients stop)")

    print(f"== {sum(TIERS.values())} clients / 3 tiers vs 3-req/tick hub "
          f"({TICKS_LOAD} ticks overload, then drain) ==")
    chaos.run(TICKS_LOAD + TICKS_DRAIN)

    stats = rt.stats()                         # asserts conservation
    print("\nper-tenant SLO ledger:")
    hdr = (f"{'tenant':>12} {'prio':>4} {'admitted':>8} {'served':>7} "
           f"{'shed':>5} {'p50':>5} {'p99':>5}  shed reasons")
    print(hdr)
    for tid in ("realtime", "standard", "best-effort"):
        t = stats["tenants"][tid]
        reasons = ", ".join(f"{r}={n}" for r, n in
                            sorted(t["shed_reasons"].items())) or "-"
        print(f"{tid:>12} {t['priority']:>4} {t['admitted']:>8} "
              f"{t['served']:>7} {t['shed']:>5} {t['p50_ticks']:>5.0f} "
              f"{t['p99_ticks']:>5.0f}  {reasons}")
        assert t["admitted"] == t["served"] + t["shed"] + t["queued"] + \
            t["in_flight"]

    rtm = stats["tenants"]["realtime"]
    print(f"\nisolation: realtime p99 {rtm['p99_ticks']:.0f} ticks through "
          f"a 2x overload (shed {rtm['shed']})")
    for scaler in stats.get("autoscale", []):
        print(f"elasticity: {scaler['scale_ups']} scale-up(s), "
              f"{scaler['scale_downs']} scale-down(s), "
              f"{scaler['rollbacks']} rollback(s) on topic {scaler['topic']}"
              f" -> {scaler['managed_replicas']} extra replica(s) left")
    errs = 0
    for _, dev in clients:
        errs += len(dev.runs[0].sink_log.get("qc.error", []))
    total_shed = sum(t["shed"] for t in stats["tenants"].values())
    print(f"explicit degradation: {errs} client-visible error frames for "
          f"{total_shed} sheds — zero silent drops")
    print(f"fleet events: {[(t, l) for t, l in chaos.log]}")


if __name__ == "__main__":
    main()
