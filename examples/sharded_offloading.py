"""Mesh-sharded among-device offloading: one hub, many screens, placement
decided by cost — and survived by failover.

Eight TVs offload a classifier to a hub that owns a jax mesh
(``Runtime(mesh="auto")`` -> a host mesh over every local device).  Each
tick the hub gathers the eight requests into ONE batch; the batcher holds
both the single-device executable and the mesh-sharded one (a frame slice
per device along the mesh's data axes) and, in the default ``auto`` mode,
probes both once and serves through the faster — the NNStreamer-style
transparency promise: placement never changes an answer, only its latency.
Phase three kills the hub mid-batch (chaos harness): orphaned requests
re-dispatch to the backup exactly as in the single-device fabric — the
mesh places compute, the failover plumbing is untouched.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sharded_offloading.py
"""
import os
import sys

# forge an 8-way host mesh BEFORE jax initializes, so the demo has real
# data-axis placement even on a laptop (skip if the user already set flags)
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402

from repro.core import TensorSpec, parse_launch               # noqa: E402
from repro.core.elements import register_model                # noqa: E402
from repro.launch.mesh import data_axis_size, make_host_mesh  # noqa: E402
from repro.runtime import Device, Runtime                     # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from chaoslib import Chaos  # noqa: E402

N_TVS = 8
TICKS_A, TICKS_B = 5, 5      # healthy (sharded-capable) / degraded


def init(rng):
    return {"w": jax.random.normal(rng, (48 * 48 * 3, 8)) * 0.01}


def apply(p, x):
    logits = x.astype(jnp.float32).reshape(1, -1) @ p["w"]
    return jax.nn.sigmoid(logits[:, :4]).reshape(1, 4)


register_model("cls_tiny_sh", init, apply,
               out_specs=(TensorSpec((1, 4), "float32"),))


def hub(rt, name, throughput):
    dev = Device(name)
    srv = parse_launch(
        f"tensor_query_serversrc operation=classify name=ssrc "
        f"throughput={throughput} ! "
        f"tensor_filter model=cls_tiny_sh ! tensor_query_serversink name=ssink")
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    run = dev.add_pipeline(srv, jit=False)
    rt.add_device(dev)
    return dev, run, srv.elements["ssrc"]


mesh = make_host_mesh()
print(f"host mesh: {mesh} ({data_axis_size(mesh)}-way data axis, "
      f"{len(jax.devices())} devices)")

rt = Runtime(query_batch=N_TVS, mesh=mesh)   # shard_mode="auto" is default
primary_dev, primary_run, primary_ssrc = hub(rt, "edge-server", throughput=8)
backup_dev, backup_run, backup_ssrc = hub(rt, "old-phone", throughput=2)

tv_runs = []
for i in range(N_TVS):
    dev = Device(f"tv{i}")
    pc = parse_launch(
        "testsrc width=48 height=48 ! tensor_converter ! "
        "tensor_query_client operation=classify name=qc ! appsink name=out")
    tv_runs.append(dev.add_pipeline(pc, jit=False))
    rt.add_device(dev)

# -- phase A: healthy fleet — one batch per tick, placement calibrated -------
rt.run(TICKS_A)
batcher = rt._batchers[primary_ssrc.endpoint.endpoint_id]
qb = rt.stats()["query_batching"]
print(f"\nphase A ({TICKS_A} ticks, {N_TVS} TVs):")
print(f"  primary served {primary_run.frames} frames in "
      f"{primary_run.bursts} batched dispatches")
print(f"  calibrated placement for batch {N_TVS}: "
      f"{batcher.placements.get(N_TVS, 'single')} "
      f'(auto-probed; force with Runtime(shard_mode="always"/"never"))')
print(f"  sharded frames so far: {qb['sharded_frames']}")

# -- phase B: the serving hub dies mid-batch; orphans re-dispatch ------------
harness = Chaos(rt)
harness.kill_server_mid_batch(rt.ticks + 1, primary_dev, primary_ssrc,
                              after_n=N_TVS // 2)
harness.run(TICKS_B)
fo = rt.stats()["failover"]
print(f"\nphase B (hub killed mid-batch at tick {TICKS_A + 1}):")
for t, label in harness.log:
    print(f"  tick {t}: {label}")
print(f"  redispatches={fo['redispatches']} parked_now={fo['parked_now']} "
      f"orphaned={fo['orphaned_requests']}")
print(f"  backup served {backup_run.frames} frames")

total = TICKS_A + TICKS_B
assert all(r.frames == total for r in tv_runs), "a TV lost a frame!"
print(f"\nevery TV got {total}/{total} answers — zero loss under the mesh.")
