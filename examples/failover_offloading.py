"""Fault-tolerant among-device offloading: a fleet that degrades gracefully.

Six TVs offload object detection to two hubs.  The capability-aware broker
routes every TV to the primary hub (it declares the higher throughput).
Mid-run the primary dies *mid-batch* — three requests already sit on its
queue.  Nothing is lost: the scheduler re-dispatches the orphaned requests
to the backup within the same tick, the TVs never miss a frame, and when
the primary revives (same registration, so it outranks the backup again)
the bindings win back automatically.

    PYTHONPATH=src python examples/failover_offloading.py
"""
import os
import sys

import jax
import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.runtime import Device, Runtime

# the deterministic chaos harness the failover tests and benchmark use —
# one copy of the fault semantics, everywhere
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from chaoslib import Chaos  # noqa: E402

N_TVS = 6
TICKS_A, TICKS_B, TICKS_C = 4, 4, 4   # healthy / degraded / recovered


def init(rng):
    return {"w": jax.random.normal(rng, (48 * 48 * 3, 8)) * 0.01}


def apply(p, x):
    logits = x.astype(jnp.float32).reshape(1, -1) @ p["w"]
    return jax.nn.sigmoid(logits[:, :4]).reshape(1, 4)


register_model("ssd_tiny_fo", init, apply,
               out_specs=(TensorSpec((1, 4), "float32"),))


def hub(rt, name, throughput):
    dev = Device(name)
    srv = parse_launch(
        f"tensor_query_serversrc operation=objdetect name=ssrc "
        f"throughput={throughput} ! "
        f"tensor_filter model=ssd_tiny_fo ! tensor_query_serversink name=ssink")
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    run = dev.add_pipeline(srv, jit=False)
    rt.add_device(dev)
    return dev, run, srv.elements["ssrc"]


rt = Runtime(query_batch=8, lease_ticks=3)
primary_dev, primary_run, primary_ssrc = hub(rt, "living-room-pc", throughput=8)
backup_dev, backup_run, backup_ssrc = hub(rt, "old-phone", throughput=2)

tvs = []
for i in range(N_TVS):
    dev = Device(f"tv{i}")
    cli = parse_launch(
        "testsrc width=48 height=48 ! tensor_converter ! "
        "tensor_query_client operation=objdetect name=qc ! appsink name=boxes")
    tvs.append(dev.add_pipeline(cli, jit=False))
    rt.add_device(dev)

rt.run(TICKS_A)
print(f"healthy:   primary served {primary_run.frames:3d} frames, "
      f"backup {backup_run.frames:3d} — throughput ranking routes all "
      f"{N_TVS} TVs to the PC")

# the PC dies the instant the 3rd request of the next tick lands on it —
# a genuine mid-batch crash with orphans on the dead queue
harness = Chaos(rt)
harness.kill_server_mid_batch(TICKS_A + 1, primary_dev, primary_ssrc,
                              after_n=3)
harness.run(TICKS_B)
assert any("mid-batch" in label for _, label in harness.log)
fo = rt.stats()["failover"]
print(f"degraded:  PC crashed mid-batch — {fo['orphaned_requests']} orphaned "
      f"requests re-dispatched ({fo['redispatches']} redispatches), backup "
      f"now at {backup_run.frames:3d} frames; every TV still on cadence: "
      f"{all(tv.frames == TICKS_A + TICKS_B for tv in tvs)}")

# the PC comes back: same registration revives, outranks the phone again
before = primary_run.frames
harness.revive_server(TICKS_A + TICKS_B + 1, primary_dev, primary_ssrc)
harness.run(TICKS_C)
print(f"recovered: PC revived and won its bindings back — served "
      f"{primary_run.frames - before:3d} of the last {TICKS_C * N_TVS} "
      f"requests; backup is idle again")

assert all(tv.frames == TICKS_A + TICKS_B + TICKS_C for tv in tvs)
assert rt.stats()["failover"]["parked_now"] == 0
print(f"OK — {N_TVS} TVs x {TICKS_A + TICKS_B + TICKS_C} ticks, zero lost "
      f"requests across one crash and one revival "
      f"(lease expiries: {rt.broker.expiries})")
