"""Fig. 5: augmented worker — multi-device AND multi-modal.

A wearable streams IMU+audio frames; the mobile's DETECT pipeline gates on
action onset (tensor_if) and publishes an activation signal back; the
wearable only streams full-rate sensors while activated (power saving), and
the mobile's classifier decides correct/incorrect assembly.

    PYTHONPATH=src python examples/augmented_worker.py
"""
import jax
import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.runtime import Device, Runtime


def det_init(rng):
    return {"w": jax.random.normal(rng, (96, 1)) * 0.3}


def det_apply(p, x):
    return jax.nn.sigmoid(x.astype(jnp.float32).reshape(1, -1) @ p["w"])


register_model("detect", det_init, det_apply,
               out_specs=(TensorSpec((1, 1), "float32"),))


def cls_init(rng):
    return {"w": jax.random.normal(rng, (96, 2)) * 0.3}


def cls_apply(p, x):
    return jax.nn.softmax(x.astype(jnp.float32).reshape(1, -1) @ p["w"])


register_model("assembly_cls", cls_init, cls_apply,
               out_specs=(TensorSpec((1, 2), "float32"),))

rt = Runtime()

watch = Device("wearable")
pw = parse_launch("""
    testsrc name=imu width=8 height=4 ! tensor_converter !
      queue leaky=2 ! mqttsink pub-topic=worker/sensors
""")
watch.add_pipeline(pw, jit=False)
rt.add_device(watch)

phone = Device("mobile")
# left pipeline: DETECT action onset, gate, publish activation
p_detect = parse_launch("""
    mqttsrc sub-topic=worker/sensors is-live=false !
      tensor_transform mode=arithmetic option=typecast:float32,div:255.0 !
      tensor_filter framework=jax model=detect !
      tensor_if name=gate threshold=0.5 operator=GE !
      mqttsink pub-topic=worker/activation
""")
phone.add_pipeline(p_detect, jit=False)
# right pipeline: classify assembly correctness while activated
p_cls = parse_launch("""
    mqttsrc sub-topic=worker/sensors is-live=false !
      tensor_transform mode=arithmetic option=typecast:float32,div:255.0 !
      tensor_filter framework=jax model=assembly_cls !
      appsink name=verdict
""")
phone.add_pipeline(p_cls, jit=False)
rt.add_device(phone)

# the wearable listens for activation (to duty-cycle its sensors)
p_act = parse_launch("mqttsrc sub-topic=worker/activation is-live=false ! "
                     "appsink name=act")
watch.add_pipeline(p_act, jit=False)
rt._wire(watch, watch.runs[-1])

rt.run(8)
verdict = phone.runs[1].last_outputs["verdict"]
act = watch.runs[1].last_outputs.get("act")
print(f"assembly verdict p(correct)={float(verdict.tensor[0, 0]):.3f}")
if act is not None:
    print(f"wearable activation signal received, gate={int(act.tensors[-1])}")
print(f"frames: detect={phone.runs[0].frames} classify={phone.runs[1].frames}")
assert phone.runs[1].frames >= 6
print("OK — gated multi-modal among-device pipeline (Fig. 5)")
