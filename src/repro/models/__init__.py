# Model zoo: pure-JAX implementations of every assigned architecture family
# (dense GQA/MQA, sliding-window + local:global, MoE with shared experts,
# MLA, Mamba-2 SSD, RG-LRU hybrid, encoder-decoder, VLM) behind one
# ModelConfig + Model facade.
from .config import ModelConfig
from .model import Model, build_model, cross_entropy
