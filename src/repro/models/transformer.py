"""Decoder-only LM assembled from per-layer mixer kinds (G/L/R/S) and
dense-or-MoE MLPs, with three entry points sharing one parameter tree:

* train   — full-sequence teacher forcing (no cache)
* prefill — full sequence, returns logits + a decode cache
* decode  — one token against the cache (serve_step)

Two parameter layouts:

* list layout   — params["layers"] = [per-layer dict] (tests, small models)
* stacked layout — params["prefix"/"stack"/"tail"]: the repeating
  layer-pattern unit is stacked over repeats and executed with lax.scan
  (+ per-unit remat).  This is what the production launcher lowers: an
  80-layer model compiles as one scanned unit, not 80 inlined blocks.

``layer_plan`` splits layers into (prefix | R repeats of the pattern unit |
tail) so heterogeneous patterns (gemma3 LLLLLG, deepseek first-dense,
recurrentgemma RRL) scan their homogeneous core.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import mla as MLA
from . import moe as MOE
from . import rglru as RG
from . import ssm as SSM
from .sharding import shard


# ---------------------------------------------------------------------------
# layer plan (scan grouping)
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> Tuple[List[int], int, int, List[int]]:
    """-> (prefix_layers, period, repeats, tail_layers).

    Layers [0, first_dense) are structurally unique (dense MLP before MoE) —
    unrolled.  The middle is R repeats of the pattern unit (all same
    structure per unit position).  A remainder tail is unrolled."""
    p = len(cfg.layer_pattern)
    start = cfg.first_dense
    n = cfg.n_layers
    repeats = max(0, (n - start) // p)
    tail_start = start + repeats * p
    return list(range(start)), p, repeats, list(range(tail_start, n))


def kind_at(cfg: ModelConfig, layer: int) -> str:
    return cfg.kind(layer)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def block_init(rng, cfg: ModelConfig, layer: int) -> Dict:
    kind = cfg.kind(layer)
    k1, k2 = jax.random.split(rng, 2)
    p: Dict = {"norm1": L.norm_init(cfg.d_model, cfg)}
    if kind in ("G", "L"):
        p["attn"] = MLA.mla_init(k1, cfg) if cfg.mla else L.attn_init(k1, cfg)
    elif kind == "R":
        p["rec"] = RG.rglru_init(k1, cfg)
    elif kind == "S":
        p["ssm"] = SSM.ssm_init(k1, cfg)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    if kind == "S":
        return p  # mamba2 blocks have no separate MLP
    p["norm2"] = L.norm_init(cfg.d_model, cfg)
    if cfg.is_moe_layer(layer):
        p["moe"] = MOE.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k2, cfg)
    return p


def init_params(rng, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(rng, cfg.n_layers + 2)
    return {
        "embed": L.embed_init(ks[0], cfg),
        "layers": [block_init(ks[i + 1], cfg, i) for i in range(cfg.n_layers)],
        "final_norm": L.norm_init(cfg.d_model, cfg),
    }


def stack_params(cfg: ModelConfig, params: Dict) -> Dict:
    """list layout -> stacked layout (pure tree ops, works on
    ShapeDtypeStructs under eval_shape too)."""
    prefix, period, repeats, tail = layer_plan(cfg)
    layers = params["layers"]
    stack = []
    for j in range(period):
        unit = [layers[len(prefix) + r * period + j] for r in range(repeats)]
        stack.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *unit)
                     if repeats else None)
    return {
        "embed": params["embed"],
        "final_norm": params["final_norm"],
        "prefix": [layers[i] for i in prefix],
        "stack": stack,
        "tail": [layers[i] for i in tail],
        **({"vis_norm": params["vis_norm"]} if "vis_norm" in params else {}),
    }


def init_params_stacked(rng, cfg: ModelConfig) -> Dict:
    return stack_params(cfg, init_params(rng, cfg))


# ---------------------------------------------------------------------------
# block apply (kind-based)
# ---------------------------------------------------------------------------

def _mixer_train(p, cfg: ModelConfig, kind: str, x):
    if kind in ("G", "L"):
        window = cfg.window if kind == "L" else None
        if cfg.mla:
            return MLA.mla_train(p["attn"], cfg, x)
        return L.attn_train(p["attn"], cfg, x, window)
    if kind == "R":
        return RG.rglru_train(p["rec"], cfg, x)
    if kind == "S":
        return SSM.ssm_train(p["ssm"], cfg, x)
    raise ValueError(kind)


def _mlp_part(p, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = L.apply_norm(p["norm2"], x, cfg)
    if "moe" in p:
        y, aux = MOE.apply_moe(p["moe"], cfg, h)
    else:
        y, aux = L.apply_mlp(p["mlp"], cfg, h), jnp.float32(0)
    return x + y, aux


def block_train(p, cfg: ModelConfig, kind: str, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = L.apply_norm(p["norm1"], x, cfg)
    x = x + _mixer_train(p, cfg, kind, h)
    if kind == "S":
        return x, jnp.float32(0)
    return _mlp_part(p, cfg, x)


def block_prefill(p, cfg: ModelConfig, kind: str, x, max_seq: int
                  ) -> Tuple[jnp.ndarray, Dict, jnp.ndarray]:
    """Train-mode forward that also emits this layer's decode cache."""
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    h = L.apply_norm(p["norm1"], x, cfg)
    if kind in ("G", "L"):
        window = cfg.window if kind == "L" else None
        if cfg.mla:
            y = MLA.mla_train(p["attn"], cfg, h)
            c_kv, k_rope = MLA._latent(p["attn"], cfg, h, pos)
            cache = MLA.mla_cache_init(cfg, b, max_seq)
            cache["c_kv"] = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
            cache["k_rope"] = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, 0, 0))
        else:
            y = L.attn_train(p["attn"], cfg, h, window)
            _, k, v = L._qkv(p["attn"], cfg, h, pos)
            cache = L.attn_cache_init(cfg, b, max_seq, window)
            size = cache["k"].shape[1]
            if size >= s:
                cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
                cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
            else:  # ring buffer: keep the last `size`, rotated into slot order
                shift = (-(s % size)) % size
                cache["k"] = jnp.roll(k[:, -size:], shift, axis=1)
                cache["v"] = jnp.roll(v[:, -size:], shift, axis=1)
    elif kind == "R":
        y = RG.rglru_train(p["rec"], cfg, h)
        cache = _rglru_prefill_cache(p["rec"], cfg, h)
    elif kind == "S":
        y, cache = SSM.ssm_prefill(p["ssm"], cfg, h)
    else:
        raise ValueError(kind)
    x = x + y
    if kind == "S":
        return x, cache, jnp.float32(0)
    x, aux = _mlp_part(p, cfg, x)
    return x, cache, aux


def block_decode(p, cfg: ModelConfig, kind: str, x, cache, pos):
    h = L.apply_norm(p["norm1"], x, cfg)
    if kind in ("G", "L"):
        window = cfg.window if kind == "L" else None
        if cfg.mla:
            y, new_cache = MLA.mla_decode(p["attn"], cfg, h, cache, pos)
        else:
            y, new_cache = L.attn_decode(p["attn"], cfg, h, cache, pos, window)
    elif kind == "R":
        y, new_cache = RG.rglru_decode(p["rec"], cfg, h, cache)
    elif kind == "S":
        y, new_cache = SSM.ssm_decode(p["ssm"], cfg, h, cache)
    else:
        raise ValueError(kind)
    x = x + y
    if kind == "S":
        return x, new_cache
    x, _ = _mlp_part(p, cfg, x)
    return x, new_cache


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def layer_cache_init(cfg: ModelConfig, layer: int, batch: int, max_seq: int) -> Dict:
    kind = cfg.kind(layer)
    if kind in ("G", "L"):
        if cfg.mla:
            return MLA.mla_cache_init(cfg, batch, max_seq)
        window = cfg.window if kind == "L" else None
        return L.attn_cache_init(cfg, batch, max_seq, window)
    if kind == "R":
        return RG.rglru_cache_init(cfg, batch)
    if kind == "S":
        return SSM.ssm_cache_init(cfg, batch)
    raise ValueError(kind)


def cache_init(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    return {
        "pos": jnp.int32(0),
        "layers": [layer_cache_init(cfg, i, batch, max_seq)
                   for i in range(cfg.n_layers)],
    }


def cache_init_stacked(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    prefix, period, repeats, tail = layer_plan(cfg)
    caches = [layer_cache_init(cfg, i, batch, max_seq) for i in range(cfg.n_layers)]
    stack = []
    for j in range(period):
        unit = [caches[len(prefix) + r * period + j] for r in range(repeats)]
        stack.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *unit)
                     if repeats else None)
    return {
        "pos": jnp.int32(0),
        "prefix": [caches[i] for i in prefix],
        "groups": stack,
        "tail": [caches[i] for i in tail],
    }


# ---------------------------------------------------------------------------
# list-layout entry points
# ---------------------------------------------------------------------------

def backbone_train(params, cfg: ModelConfig, x,
                   remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux_total = jnp.float32(0)
    for i, p in enumerate(params["layers"]):
        fn = jax.checkpoint(block_train, prevent_cse=False,
                            static_argnums=(1, 2)) if remat else block_train
        x, aux = fn(p, cfg, cfg.kind(i), x)
        aux_total = aux_total + aux
    return L.apply_norm(params["final_norm"], x, cfg), aux_total


def lm_train(params, cfg: ModelConfig, tokens,
             remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = L.embed(params["embed"], cfg, tokens)
    h, aux = backbone_train(params, cfg, x, remat)
    return L.unembed(params["embed"], cfg, h), aux


def lm_decode(params, cfg: ModelConfig, token, cache) -> Tuple[jnp.ndarray, Dict]:
    pos = cache["pos"]
    x = L.embed(params["embed"], cfg, token[:, None])
    new_layers = []
    for i, p in enumerate(params["layers"]):
        x, c = block_decode(p, cfg, cfg.kind(i), x, cache["layers"][i], pos)
        new_layers.append(c)
    h = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], cfg, h)[:, 0]
    return logits, {"pos": pos + 1, "layers": new_layers}


def lm_prefill(params, cfg: ModelConfig, tokens, max_seq: Optional[int] = None
               ) -> Tuple[jnp.ndarray, Dict]:
    x = L.embed(params["embed"], cfg, tokens)
    return lm_prefill_embedded(params, cfg, x, max_seq or tokens.shape[1])


def lm_prefill_embedded(params, cfg: ModelConfig, x, max_seq: int
                        ) -> Tuple[jnp.ndarray, Dict]:
    caches: List[Dict] = []
    for i, p in enumerate(params["layers"]):
        x, cache, _ = block_prefill(p, cfg, cfg.kind(i), x, max_seq)
        caches.append(cache)
    hfin = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], cfg, hfin[:, -1:])[:, 0]
    return logits, {"pos": jnp.int32(x.shape[1]), "layers": caches}


# ---------------------------------------------------------------------------
# pipeline-stage entry points (among-device hops, DESIGN.md §8)
#
# A stage is a contiguous slice [lo, hi) of the layer stack running as its
# own pipeline on its own device: stage 0 embeds, the last stage norms and
# unembeds, middle stages map activations to activations.  Layer kinds and
# cache shapes are indexed by GLOBAL layer number, so an N-stage chain runs
# layer-for-layer the identical traced blocks ``lm_decode``/``lm_prefill``
# run — chaining the stages reproduces the monolithic model bitwise (pinned
# in tests/test_pp_staged_serving.py).
# ---------------------------------------------------------------------------

def stage_bounds(cfg: ModelConfig, stage: int, n_stages: int
                 ) -> Tuple[int, int]:
    """Global layer range [lo, hi) owned by ``stage`` of ``n_stages``."""
    if not 0 <= stage < n_stages:
        raise ValueError(f"stage {stage} not in [0, {n_stages})")
    if cfg.n_layers % n_stages:
        raise ValueError(f"n_layers={cfg.n_layers} not divisible by "
                         f"n_stages={n_stages}")
    r = cfg.n_layers // n_stages
    return stage * r, (stage + 1) * r


def stage_params(params: Dict, cfg: ModelConfig, stage: int, n_stages: int
                 ) -> Dict:
    """Slice a full list-layout param tree down to one stage's share.
    ``embed`` rides on the first stage (token embedding) AND the last
    (unembed reads ``params["embed"]`` — tied or ``head``)."""
    lo, hi = stage_bounds(cfg, stage, n_stages)
    out: Dict = {"layers": params["layers"][lo:hi]}
    if stage == 0 or stage == n_stages - 1:
        out["embed"] = params["embed"]
    if stage == n_stages - 1:
        out["final_norm"] = params["final_norm"]
    return out


def stage_cache_init(cfg: ModelConfig, stage: int, n_stages: int, batch: int,
                     max_seq: int) -> Dict:
    """Zero decode cache covering only this stage's layers (its slice of
    the monolithic ``cache_init`` tree, same per-layer shapes)."""
    lo, hi = stage_bounds(cfg, stage, n_stages)
    return {"pos": jnp.int32(0),
            "layers": [layer_cache_init(cfg, i, batch, max_seq)
                       for i in range(lo, hi)]}


def stage_prefill(params, cfg: ModelConfig, stage: int, n_stages: int, x,
                  max_seq: int) -> Tuple[jnp.ndarray, Dict]:
    """Prefill one stage: tokens ``[b, L]`` in for stage 0, activations
    ``[b, L, d]`` for later stages; out is the boundary activations (or
    final-position logits ``[b, vocab]`` on the last stage) plus this
    stage's decode cache."""
    lo, hi = stage_bounds(cfg, stage, n_stages)
    if stage == 0:
        x = L.embed(params["embed"], cfg, x)
    caches: List[Dict] = []
    for j in range(hi - lo):
        x, cache, _ = block_prefill(params["layers"][j], cfg, cfg.kind(lo + j),
                                    x, max_seq)
        caches.append(cache)
    out = x
    if stage == n_stages - 1:
        hfin = L.apply_norm(params["final_norm"], x, cfg)
        out = L.unembed(params["embed"], cfg, hfin[:, -1:])[:, 0]
    return out, {"pos": jnp.int32(x.shape[1]), "layers": caches}


def stage_decode(params, cfg: ModelConfig, stage: int, n_stages: int, x,
                 cache) -> Tuple[jnp.ndarray, Dict]:
    """One decode step through one stage: token ``[b]`` in for stage 0,
    activations ``[b, 1, d]`` for later stages; out is activations
    ``[b, 1, d]`` (or logits ``[b, vocab]`` on the last stage) plus the
    advanced stage cache."""
    lo, hi = stage_bounds(cfg, stage, n_stages)
    pos = cache["pos"]
    if stage == 0:
        x = L.embed(params["embed"], cfg, x[:, None])
    new_layers = []
    for j in range(hi - lo):
        x, c = block_decode(params["layers"][j], cfg, cfg.kind(lo + j), x,
                            cache["layers"][j], pos)
        new_layers.append(c)
    out = x
    if stage == n_stages - 1:
        h = L.apply_norm(params["final_norm"], x, cfg)
        out = L.unembed(params["embed"], cfg, h)[:, 0]
    return out, {"pos": pos + 1, "layers": new_layers}


def _rglru_prefill_cache(p, cfg: ModelConfig, x) -> Dict:
    u = x @ p["w_rec"]
    u_conv, conv_state = RG._conv4(u, p["conv"])
    a, bx = RG._gates(p, u_conv)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return {"h": h[:, -1], "conv": conv_state}


def _ssm_prefill_cache(p, cfg: ModelConfig, x) -> Dict:
    b, s, d = x.shape
    d_inner, hh, hd, n = SSM._dims(cfg)
    proj = x @ p["w_in"]
    z, xbc, dt_raw = SSM._split_proj(cfg, proj)
    xbc_c, conv_state = SSM._causal_conv(xbc, p["conv"])
    xs, B, C = jnp.split(xbc_c, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(b, s, hh, hd)
    _, h_final, _ = SSM._ssd_scan(cfg, p, xh, B, C, dt, None)
    return {"h": h_final, "conv": conv_state}


# ---------------------------------------------------------------------------
# stacked-layout entry points (lax.scan over pattern repeats, remat per unit)
# ---------------------------------------------------------------------------

def _unit_kinds(cfg: ModelConfig) -> List[str]:
    prefix, period, _, _ = layer_plan(cfg)
    return [cfg.kind(len(prefix) + j) for j in range(period)]


def backbone_train_stacked(params, cfg: ModelConfig, x,
                           remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    prefix, period, repeats, tail = layer_plan(cfg)
    kinds = _unit_kinds(cfg)
    aux_total = jnp.float32(0)
    for i, p in zip(range(len(prefix)), params["prefix"]):
        x, aux = block_train(p, cfg, cfg.kind(i), x)
        aux_total = aux_total + aux

    def unit(carry, unit_params):
        x, aux = carry
        for j in range(period):
            x, a = block_train(unit_params[j], cfg, kinds[j], x)
            aux = aux + a
        return (x, aux), None

    if repeats:
        body = jax.checkpoint(unit, prevent_cse=False) if remat else unit
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         tuple(params["stack"]))
    for i, p in zip(tail, params["tail"]):
        x, aux = block_train(p, cfg, cfg.kind(i), x)
        aux_total = aux_total + aux
    return L.apply_norm(params["final_norm"], x, cfg), aux_total


def lm_train_stacked(params, cfg: ModelConfig, tokens, remat: bool = True):
    x = L.embed(params["embed"], cfg, tokens)
    h, aux = backbone_train_stacked(params, cfg, x, remat)
    return L.unembed(params["embed"], cfg, h), aux


def lm_prefill_stacked(params, cfg: ModelConfig, tokens, max_seq: int,
                       x=None) -> Tuple[jnp.ndarray, Dict]:
    prefix, period, repeats, tail = layer_plan(cfg)
    kinds = _unit_kinds(cfg)
    if x is None:
        x = L.embed(params["embed"], cfg, tokens)
    pre_caches = []
    for i, p in zip(range(len(prefix)), params["prefix"]):
        x, c, _ = block_prefill(p, cfg, cfg.kind(i), x, max_seq)
        pre_caches.append(c)

    def unit(x, unit_params):
        caches = []
        for j in range(period):
            x, c, _ = block_prefill(unit_params[j], cfg, kinds[j], x, max_seq)
            caches.append(c)
        return x, tuple(caches)

    groups = [None] * period
    if repeats:
        body = jax.checkpoint(unit, prevent_cse=False)
        x, stacked = jax.lax.scan(body, x, tuple(params["stack"]))
        groups = list(stacked)
    tail_caches = []
    for i, p in zip(tail, params["tail"]):
        x, c, _ = block_prefill(p, cfg, cfg.kind(i), x, max_seq)
        tail_caches.append(c)
    hfin = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], cfg, hfin[:, -1:])[:, 0]
    return logits, {"pos": jnp.int32(x.shape[1]), "prefix": pre_caches,
                    "groups": groups, "tail": tail_caches}


def lm_decode_stacked(params, cfg: ModelConfig, token, cache
                      ) -> Tuple[jnp.ndarray, Dict]:
    prefix, period, repeats, tail = layer_plan(cfg)
    kinds = _unit_kinds(cfg)
    pos = cache["pos"]
    x = L.embed(params["embed"], cfg, token[:, None])
    new_prefix = []
    for i, p, c in zip(range(len(prefix)), params["prefix"], cache["prefix"]):
        x, nc = block_decode(p, cfg, cfg.kind(i), x, c, pos)
        new_prefix.append(nc)

    def unit(x, scanned):
        unit_params, unit_cache = scanned
        new_caches = []
        for j in range(period):
            x, nc = block_decode(unit_params[j], cfg, kinds[j], x,
                                 unit_cache[j], pos)
            new_caches.append(nc)
        return x, tuple(new_caches)

    new_groups = [None] * period
    if repeats:
        x, stacked = jax.lax.scan(unit, x,
                                  (tuple(params["stack"]), tuple(cache["groups"])))
        new_groups = list(stacked)
    new_tail = []
    for i, p, c in zip(tail, params["tail"], cache["tail"]):
        x, nc = block_decode(p, cfg, cfg.kind(i), x, c, pos)
        new_tail.append(nc)
    h = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], cfg, h)[:, 0]
    return logits, {"pos": pos + 1, "prefix": new_prefix,
                    "groups": new_groups, "tail": new_tail}
