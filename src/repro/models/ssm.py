"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD: the sequence is split into chunks of length Q; within a chunk
the *dual* (attention-like) quadratic form runs on the MXU, between chunks a
linear state recurrence runs via lax.scan (or one-step update at decode).

    h_t = exp(A·dt_t) h_{t-1} + dt_t · B_t ⊗ x_t         (state  [H, hd, N])
    y_t = C_t · h_t + D ⊙ x_t

Decode state is O(H·hd·N) — constant in sequence length, which is why the
SSM archs run the long_500k shape.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..jaxcompat import pvary, shard_map

from .config import ModelConfig
from .layers import dense_init
from .sharding import shard


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def ssm_init(rng, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    d_inner, h, hd, n = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    # in_proj packs [z (gate), x, B, C, dt] as in mamba2
    d_in_proj = 2 * d_inner + 2 * n + h
    return {
        "w_in": dense_init(ks[0], d, d_in_proj, dt),
        "conv": (jax.random.normal(ks[1], (cfg.ssm_conv, d_inner + 2 * n))
                 * 0.1).astype(dt),
        "A_log": jnp.zeros((h,), jnp.float32),         # A = -exp(A_log) in (-1, 0)
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "w_out": dense_init(ks[2], d_inner, d, dt),
    }


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    d_inner, h, hd, n = _dims(cfg)
    z, xbc, dt_raw = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt_raw


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, prev: jnp.ndarray = None):
    """Depthwise causal conv1d, window K.  xbc: [B,S,C]; w: [K,C];
    prev: [B,K-1,C] carried state for decode."""
    k = w.shape[0]
    if prev is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = prev
    xp = jnp.concatenate([pad, xbc], axis=1)                    # [B,S+K-1,C]
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out), xp[:, -(k - 1):]                   # new conv state


def _ssd_scan(cfg: ModelConfig, p: Dict, xh, B, C, dt, h0):
    """Chunked SSD scan.
    xh: [B,S,H,hd]; B,C: [B,S,N]; dt: [B,S,H] (softplus'd).
    Returns y [B,S,H,hd] (incl. D skip), final state [B,H,N,hd]."""
    b, s, h, hd = xh.shape
    n = B.shape[-1]
    q = min(cfg.ssm_chunk, s)
    pad = (-s) % q
    if pad:  # zero-dt padding: decay=1, update=0 -> state and outputs exact
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // q
    A = -jnp.exp(p["A_log"])                                    # [H], negative
    dA = dt * A[None, None, :]                                  # [B,S,H]
    dA_c = dA.reshape(b, nc, q, h)
    xh_c = xh.reshape(b, nc, q, h, hd)
    B_c = B.reshape(b, nc, q, n)
    C_c = C.reshape(b, nc, q, n)
    dt_c = dt.reshape(b, nc, q, h)

    cum = jnp.cumsum(dA_c, axis=2)                              # [B,nc,q,H]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # [B,nc,q,q,H] i>=j
    causal = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk (dual quadratic form): y_intra[i] = Σ_j L[i,j] (C_i·B_j) dt_j x_j
    G = jnp.einsum("bcin,bcjn->bcij", C_c.astype(jnp.float32),
                   B_c.astype(jnp.float32))                     # [B,nc,q,q]
    M = G[..., None] * L                                        # [B,nc,q,q,H]
    y_intra = jnp.einsum("bcijh,bcjh,bcjhd->bcihd", M, dt_c,
                         xh_c.astype(jnp.float32))

    # chunk-final states: S_c = Σ_j exp(cum_last - cum_j) dt_j B_j ⊗ x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)             # [B,nc,q,H]
    S_c = jnp.einsum("bcjh,bcjh,bcjn,bcjhd->bchnd",
                     decay_to_end, dt_c, B_c.astype(jnp.float32),
                     xh_c.astype(jnp.float32))                  # [B,nc,H,N,hd]

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(dA_c, axis=2))                # [B,nc,H]
    if h0 is None:
        h0 = jnp.zeros((b, h, n, hd), jnp.float32)

    def scan_fn(hprev, inp):
        dec, s_new = inp                                        # [B,H], [B,H,N,hd]
        hnext = hprev * dec[:, :, None, None] + s_new
        return hnext, hprev

    dec_t = jnp.moveaxis(chunk_decay, 1, 0)                     # [nc,B,H]
    s_t = jnp.moveaxis(S_c, 1, 0)                               # [nc,B,H,N,hd]
    h_final, h_starts = jax.lax.scan(scan_fn, h0, (dec_t, s_t))
    h_starts = jnp.moveaxis(h_starts, 0, 1)                     # [B,nc,H,N,hd]

    # inter-chunk contribution: y_inter[i] = C_i · (decay_to_i * h_start)
    decay_from_start = jnp.exp(cum)                             # [B,nc,q,H]
    y_inter = jnp.einsum("bcin,bcih,bchnd->bcihd",
                         C_c.astype(jnp.float32), decay_from_start, h_starts)

    y = (y_intra + y_inter).reshape(b, s, h, hd)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    # running log-decay from sequence start (for cross-shard correction):
    # per-chunk cum + exclusive chunk-offset
    chunk_sum = jnp.sum(dA_c, axis=2)                           # [B,nc,H]
    offs = jnp.cumsum(chunk_sum, axis=1) - chunk_sum            # exclusive
    cum_total = (cum + offs[:, :, None, :]).reshape(b, s, h)
    return y[:, :s_orig], h_final, cum_total[:, :s_orig]


def ssm_train(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.ssm_seq_parallel:
        from .sharding import current_rules
        mesh = current_rules().get("__mesh__")
        if mesh is not None and "model" in getattr(mesh, "axis_names", ()) \
                and x.shape[1] % mesh.shape["model"] == 0:
            return ssm_train_seq_parallel(p, cfg, x, mesh)
    b, s, d = x.shape
    d_inner, h, hd, n = _dims(cfg)
    proj = x @ p["w_in"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, _ = _causal_conv(xbc, p["conv"])
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    xh = xs.reshape(b, s, h, hd)
    xh = shard(xh, "batch", "seq", "heads", None)
    y, _, _ = _ssd_scan(cfg, p, xh, B, C, dt, None)
    y = y.astype(x.dtype).reshape(b, s, d_inner)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"]
    return shard(out, "batch", "seq", None)


def ssm_cache_init(cfg: ModelConfig, batch: int) -> Dict:
    d_inner, h, hd, n = _dims(cfg)
    return {
        "h": jnp.zeros((batch, h, n, hd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * n),
                          jnp.dtype(cfg.dtype)),
    }


def ssm_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray, cache: Dict
               ) -> Tuple[jnp.ndarray, Dict]:
    """x: [B,1,d]; O(1) state update."""
    b = x.shape[0]
    d_inner, h, hd, n = _dims(cfg)
    proj = x @ p["w_in"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(xbc, p["conv"], prev=cache["conv"])
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]  # [B,H]
    xh = xs.reshape(b, h, hd).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A[None, :])                              # [B,H]
    hs = shard(cache["h"], "batch", "heads", None, None)
    upd = jnp.einsum("bh,bn,bhd->bhnd", dt, B[:, 0].astype(jnp.float32), xh)
    hnew = hs * dec[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnd->bhd", C[:, 0].astype(jnp.float32), hnew)
    y = y + p["D"][None, :, None] * xh
    y = (y.reshape(b, 1, d_inner).astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["w_out"]
    return shard(out, "batch", None, None), {"h": hnew, "conv": conv_state}


# ---------------------------------------------------------------------------
# Sequence-parallel SSD (§Perf beyond-paper optimization)
# ---------------------------------------------------------------------------
#
# mamba2-130m's channel dims (24 heads of 64) don't divide a 16-way model
# axis, so tensor parallelism either emits halo collective-permutes every
# layer (misaligned channel shards — the collective-bound baseline) or
# degenerates to replication (16× redundant compute).  The dimension that
# IS huge is the sequence (32k–512k): shard it over `model`.
#
# SSD's inter-chunk recurrence is associative over (decay, state) pairs:
#   (D1, S1) ∘ (D2, S2) = (D1·D2, S1·D2 + S2)
# so cross-shard states combine with a log2(model)-depth ppermute scan —
# 4 rounds of a [B,H,N,hd] message (~1.5 MB) instead of per-layer halos.
# The conv1d needs a 3-frame halo from the left neighbour (one tiny
# ppermute), and each position's output gains the h0 correction
# y += C_t · exp(cum_dA_t) · h0.

def ssm_train_seq_parallel(p: Dict, cfg: ModelConfig, x: jnp.ndarray, mesh
                           ) -> jnp.ndarray:
    from jax.sharding import PartitionSpec as P
    b, s, d = x.shape
    d_inner, h, hd, n = _dims(cfg)
    m = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    import numpy as np
    dsize = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if b % max(dsize, 1):
        dp = ()
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    perm_fwd = [(i, i + 1) for i in range(m - 1)]

    def body(xl, w_in, conv, A_log, D, dt_bias, w_out):
        bl, sl, _ = xl.shape
        idx = jax.lax.axis_index("model")
        proj = xl @ w_in
        z, xbc, dt_raw = _split_proj(cfg, proj)
        # conv halo: last K-1 frames from the left neighbour (zeros at shard 0)
        k = conv.shape[0]
        tail = xbc[:, -(k - 1):]
        prev = jax.lax.ppermute(tail, "model", perm_fwd)
        lp = {"conv": conv, "A_log": A_log, "D": D, "dt_bias": dt_bias}
        xbc_c, _ = _causal_conv(xbc, conv, prev=prev)
        xs, B, C = jnp.split(xbc_c, [d_inner, d_inner + n], axis=-1)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + dt_bias)
        xh = xs.reshape(bl, sl, h, hd)
        # scan carry must carry the body's varying manual axes
        h_init = pvary(jnp.zeros((bl, h, n, hd), jnp.float32),
                               tuple(mesh.axis_names))
        y0, h_loc, cum = _ssd_scan(cfg, lp, xh, B, C, dt, h_init)

        # cross-shard inclusive scan of (decay_prod, state)
        d_loc = jnp.exp(jnp.sum(dt * (-jnp.exp(A_log))[None, None, :], axis=1))
        d_acc, s_acc = d_loc, h_loc                    # [B,H], [B,H,N,hd]
        shift = 1
        while shift < m:
            pairs = [(i, i + shift) for i in range(m - shift)]
            d_in = jax.lax.ppermute(d_acc, "model", pairs)
            s_in = jax.lax.ppermute(s_acc, "model", pairs)
            has_left = (idx >= shift).astype(jnp.float32)
            # combine(left=(d_in,s_in), right=(d_acc,s_acc));
            # shards with no left neighbour keep their values (d_in=0 there,
            # so gate with has_left)
            d_new = jnp.where(has_left > 0, d_in * d_acc, d_acc)
            s_new = s_in * d_acc[:, :, None, None] * has_left + s_acc
            d_acc, s_acc = d_new, s_new
            shift *= 2
        # exclusive prefix: previous shard's inclusive state (zeros at shard 0)
        h0 = jax.lax.ppermute(s_acc, "model", perm_fwd)  # [B,H,N,hd]

        # correction: y += C_t · exp(cum_t) · h0
        y_corr = jnp.einsum("bsn,bsh,bhnd->bshd",
                            C.astype(jnp.float32), jnp.exp(cum), h0)
        y = (y0 + y_corr).astype(xl.dtype).reshape(bl, sl, d_inner)
        y = y * jax.nn.silu(z)
        return y @ w_out

    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, "model", None), P(), P(), P(), P(), P(), P()),
        out_specs=P(bspec, "model", None),
    )(x, p["w_in"], p["conv"], p["A_log"], p["D"], p["dt_bias"], p["w_out"])
    return out


def ssm_prefill(p: Dict, cfg: ModelConfig, x: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Dict]:
    """Single-pass prefill: y plus the decode cache (final SSD state + conv
    tail) from ONE SSD computation.  The two-pass alternative (ssm_train +
    a separate cache pass) doubles compute and, under sequence sharding,
    all-gathers every chunk state in the duplicate GSPMD scan (§Perf H3)."""
    if cfg.ssm_seq_parallel:
        from .sharding import current_rules
        mesh = current_rules().get("__mesh__")
        if mesh is not None and "model" in getattr(mesh, "axis_names", ()) \
                and x.shape[1] % mesh.shape["model"] == 0:
            return _ssm_prefill_seq_parallel(p, cfg, x, mesh)
    b, s, d = x.shape
    d_inner, h, hd, n = _dims(cfg)
    proj = x @ p["w_in"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc_c, conv_state = _causal_conv(xbc, p["conv"])
    xs, B, C = jnp.split(xbc_c, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(b, s, h, hd)
    y, h_final, _ = _ssd_scan(cfg, p, xh, B, C, dt, None)
    y = (y.astype(x.dtype).reshape(b, s, d_inner)) * jax.nn.silu(z)
    out = y @ p["w_out"]
    return shard(out, "batch", "seq", None), \
        {"h": h_final, "conv": conv_state}


def _ssm_prefill_seq_parallel(p: Dict, cfg: ModelConfig, x: jnp.ndarray, mesh
                              ) -> Tuple[jnp.ndarray, Dict]:
    from jax.sharding import PartitionSpec as P
    import numpy as np
    b, s, d = x.shape
    d_inner, h, hd, n = _dims(cfg)
    m = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if b % max(dsize, 1):
        dp = ()
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    perm_fwd = [(i, i + 1) for i in range(m - 1)]

    def body(xl, w_in, conv, A_log, D, dt_bias, w_out):
        bl, sl, _ = xl.shape
        idx = jax.lax.axis_index("model")
        proj = xl @ w_in
        z, xbc, dt_raw = _split_proj(cfg, proj)
        k = conv.shape[0]
        tail = xbc[:, -(k - 1):]
        prev = jax.lax.ppermute(tail, "model", perm_fwd)
        lp = {"conv": conv, "A_log": A_log, "D": D, "dt_bias": dt_bias}
        xbc_c, conv_tail = _causal_conv(xbc, conv, prev=prev)
        xs, B, C = jnp.split(xbc_c, [d_inner, d_inner + n], axis=-1)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + dt_bias)
        xh = xs.reshape(bl, sl, h, hd)
        h_init = pvary(jnp.zeros((bl, h, n, hd), jnp.float32),
                               tuple(mesh.axis_names))
        y0, h_loc, cum = _ssd_scan(cfg, lp, xh, B, C, dt, h_init)

        d_loc = jnp.exp(jnp.sum(dt * (-jnp.exp(A_log))[None, None, :], axis=1))
        d_acc, s_acc = d_loc, h_loc
        shift = 1
        while shift < m:
            pairs = [(i, i + shift) for i in range(m - shift)]
            d_in = jax.lax.ppermute(d_acc, "model", pairs)
            s_in = jax.lax.ppermute(s_acc, "model", pairs)
            has_left = (idx >= shift).astype(jnp.float32)
            d_new = jnp.where(has_left > 0, d_in * d_acc, d_acc)
            s_new = s_in * d_acc[:, :, None, None] * has_left + s_acc
            d_acc, s_acc = d_new, s_new
            shift *= 2
        h0 = jax.lax.ppermute(s_acc, "model", perm_fwd)
        y_corr = jnp.einsum("bsn,bsh,bhnd->bshd",
                            C.astype(jnp.float32), jnp.exp(cum), h0)
        y = (y0 + y_corr).astype(xl.dtype).reshape(bl, sl, d_inner)
        y = y * jax.nn.silu(z)
        out = y @ w_out
        # cache: global final state = last shard's inclusive state; conv tail
        # = last shard's trailing K-1 frames.  mask + psum broadcasts them.
        is_last = (idx == m - 1).astype(jnp.float32)
        h_final = jax.lax.psum(s_acc * is_last, "model")
        conv_final = jax.lax.psum(
            conv_tail.astype(jnp.float32) * is_last, "model"
        ).astype(conv_tail.dtype)
        return out, h_final, conv_final

    out, h_final, conv_final = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, "model", None), P(), P(), P(), P(), P(), P()),
        out_specs=(P(bspec, "model", None), P(bspec, None, None, None),
                   P(bspec, None, None)),
    )(x, p["w_in"], p["conv"], p["A_log"], p["D"], p["dt_bias"], p["w_out"])
    return out, {"h": h_final, "conv": conv_final}
