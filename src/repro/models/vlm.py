"""VLM wrapper (InternVL2-style: ViT encoder + MLP projector + LLM).

The vision tower is the allowed STUB: ``input_specs`` supplies projected
patch embeddings [B, n_patches, d_model] (InternViT-6B output after the
pixel-shuffle + MLP projector).  This module implements the multimodal
interleave — patch tokens prepended to text embeddings, one shared decoder —
which is the part the among-device pipeline cares about (camera device
publishes patch streams; LM device consumes them).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import transformer as T
from .sharding import shard


def init_params(rng, cfg: ModelConfig) -> Dict:
    k1, k2 = jax.random.split(rng)
    p = T.init_params(k1, cfg)
    # learnable projector bias marks modality boundary (projector weights are
    # part of the stubbed tower; this is the LM-side adapter norm)
    p["vis_norm"] = L.norm_init(cfg.d_model, cfg)
    return p


def train(params, cfg: ModelConfig, patches, tokens,
          remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """patches: [B,P,d] float; tokens: [B,S].  Returns logits over [P+S]."""
    pe = L.apply_norm(params["vis_norm"], patches.astype(jnp.dtype(cfg.dtype)), cfg)
    te = L.embed(params["embed"], cfg, tokens)
    x = jnp.concatenate([pe, te], axis=1)
    x = shard(x, "batch", "seq", None)
    h, aux = T.backbone_train(params, cfg, x, remat=remat)
    return L.unembed(params["embed"], cfg, h), aux


def prefill(params, cfg: ModelConfig, patches, tokens, max_seq: int
            ) -> Tuple[jnp.ndarray, Dict]:
    """Prefill over [patches|tokens]; cache covers the combined sequence."""
    b, s = tokens.shape
    p_len = patches.shape[1]
    pe = L.apply_norm(params["vis_norm"], patches.astype(jnp.dtype(cfg.dtype)), cfg)
    # reuse the LM prefill by embedding externally: temporarily inline
    return _prefill_embedded(params, cfg, pe, tokens, max_seq)


def _prefill_embedded(params, cfg, pe, tokens, max_seq):
    # embed text, concat, then run the same per-layer prefill as lm_prefill
    # but over pre-built embeddings.
    b, s = tokens.shape
    te = L.embed(params["embed"], cfg, tokens)
    x = jnp.concatenate([pe, te], axis=1)
    total = x.shape[1]
    fake_tokens = jnp.zeros((b, total), jnp.int32)
    # lm_prefill embeds internally; we bypass by calling the shared body with
    # a pre-embedded hook.
    return T.lm_prefill_embedded(params, cfg, x, max_seq)


decode_step = T.lm_decode  # decode is pure text continuation
