"""Model facade: one object per architecture config exposing

    init(rng) -> params
    loss(params, batch) -> (scalar, aux)           # train_step payload
    prefill(params, batch, max_seq) -> (logits, cache)
    decode_step(params, token, cache) -> (logits, cache)
    init_cache(batch, max_seq) -> cache
    input_specs(mode, batch, seq) -> dict of ShapeDtypeStruct

Batches are dicts: tokens/labels always; + patches (vlm) or frames (audio)
from the stubbed modality frontends.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import encdec as ED
from . import transformer as T
from . import vlm as V
from .sharding import shard


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """logits [B,S,V] predicting labels [B,S] (already shifted by caller)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params ---------------------------------------------------------------
    def init(self, rng) -> Dict:
        cfg = self.cfg
        if cfg.enc_dec:
            return ED.init_params(rng, cfg)
        if cfg.frontend == "vision":
            return V.init_params(rng, cfg)
        return T.init_params(rng, cfg)

    # -- train ------------------------------------------------------------------
    def train_logits(self, params, batch,
                     remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        if cfg.enc_dec:
            return ED.train(params, cfg, batch["frames"], batch["tokens"])
        if cfg.frontend == "vision":
            return V.train(params, cfg, batch["patches"], batch["tokens"],
                           remat=remat)
        return T.lm_train(params, cfg, batch["tokens"], remat=remat)

    def loss(self, params, batch, remat: bool = False) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        logits, aux = self.train_logits(params, batch, remat=remat)
        tokens = batch["tokens"]
        if cfg.frontend == "vision":
            # loss over text positions only: text token i sits at P+i and is
            # predicted by position P+i-1
            p_len = logits.shape[1] - tokens.shape[1]
            pred = logits[:, p_len - 1:-1]
            ce = cross_entropy(pred, tokens[:, :] if p_len == 0 else tokens)
        else:
            ce = cross_entropy(logits[:, :-1], tokens[:, 1:])
        total = ce + aux
        return total, {"ce": ce, "aux": aux}

    # -- serve ------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        if cfg.enc_dec:
            return ED.cache_init(cfg, batch, max_seq)
        return T.cache_init(cfg, batch, max_seq)

    def prefill(self, params, batch, max_seq: int) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        if cfg.enc_dec:
            return ED.prefill(params, cfg, batch["frames"], batch["tokens"], max_seq)
        if cfg.frontend == "vision":
            return V.prefill(params, cfg, batch["patches"], batch["tokens"], max_seq)
        return T.lm_prefill(params, cfg, batch["tokens"], max_seq)

    def decode_step(self, params, token, cache) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        if cfg.enc_dec:
            return ED.decode_step(params, cfg, token, cache)
        return T.lm_decode(params, cfg, token, cache)

    # -- stacked (scanned) layout: what the production launcher lowers -----------
    @property
    def supports_stacked(self) -> bool:
        return not self.cfg.enc_dec

    def init_stacked(self, rng) -> Dict:
        params = self.init(rng)
        return self.stack_params(params)

    def stack_params(self, params) -> Dict:
        if not self.supports_stacked:
            return params
        return T.stack_params(self.cfg, params)

    def loss_stacked(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        if cfg.enc_dec:
            return self.loss(params, batch)
        tokens = batch["tokens"]
        if cfg.frontend == "vision":
            import jax.numpy as _j
            from . import layers as _L
            pe = _L.apply_norm(params["vis_norm"],
                               batch["patches"].astype(_j.dtype(cfg.dtype)), cfg)
            te = _L.embed(params["embed"], cfg, tokens)
            x = _j.concatenate([pe, te], axis=1)
            h, aux = T.backbone_train_stacked(params, cfg, x)
            logits = _L.unembed(params["embed"], cfg, h)
            p_len = logits.shape[1] - tokens.shape[1]
            ce = cross_entropy(logits[:, p_len - 1:-1], tokens)
        else:
            logits, aux = T.lm_train_stacked(params, cfg, tokens)
            ce = cross_entropy(logits[:, :-1], tokens[:, 1:])
        return ce + aux, {"ce": ce, "aux": aux}

    def init_cache_stacked(self, batch: int, max_seq: int) -> Dict:
        if self.cfg.enc_dec:
            return self.init_cache(batch, max_seq)
        return T.cache_init_stacked(self.cfg, batch, max_seq)

    def prefill_stacked(self, params, batch, max_seq: int):
        cfg = self.cfg
        if cfg.enc_dec:
            return self.prefill(params, batch, max_seq)
        if cfg.frontend == "vision":
            import jax.numpy as _j
            from . import layers as _L
            pe = _L.apply_norm(params["vis_norm"],
                               batch["patches"].astype(_j.dtype(cfg.dtype)), cfg)
            te = _L.embed(params["embed"], cfg, batch["tokens"])
            x = _j.concatenate([pe, te], axis=1)
            return T.lm_prefill_stacked(params, cfg, None, max_seq, x=x)
        return T.lm_prefill_stacked(params, cfg, batch["tokens"], max_seq)

    def decode_step_stacked(self, params, token, cache):
        if self.cfg.enc_dec:
            return self.decode_step(params, token, cache)
        return T.lm_decode_stacked(params, self.cfg, token, cache)

    # -- shape plumbing ------------------------------------------------------------
    def clamp_seq(self, seq: int) -> int:
        return min(seq, self.cfg.max_seq) if self.cfg.max_seq else seq

    def input_specs(self, mode: str, batch: int, seq: int) -> Dict:
        """ShapeDtypeStruct stand-ins for every model input (no allocation).
        mode: train | prefill | decode."""
        cfg = self.cfg
        seq = self.clamp_seq(seq)
        i32 = jnp.int32
        emb = jnp.dtype(cfg.dtype)
        S = jax.ShapeDtypeStruct
        if mode == "decode":
            return {"token": S((batch,), i32)}
        specs = {"tokens": S((batch, seq), i32)}
        if cfg.enc_dec:
            specs["frames"] = S((batch, cfg.enc_seq, cfg.d_model), emb)
        if cfg.frontend == "vision":
            specs["patches"] = S((batch, cfg.n_patches, cfg.d_model), emb)
        return specs

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree_util.tree_leaves(params))

    def model_flops_per_token(self) -> float:
        """6·N (dense) or 6·N_active (MoE) — the §Roofline MODEL_FLOPS term
        (per token, times seq·batch for a step, ×3 for fwd+bwd? no: 6N·D
        already counts fwd+bwd; serve uses 2N·D)."""
        n = self.active_param_count()
        return 6.0 * n

    def active_param_count(self) -> int:
        """Analytic parameter count, MoE counted at top_k + shared."""
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        if cfg.mla:
            attn = (d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                    + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                    + (d * cfg.q_lora_rank
                       + cfg.q_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                       if cfg.q_lora_rank else d * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim))
                    + cfg.n_heads * cfg.v_head_dim * d)
        else:
            attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
        glu = 3 if cfg.mlp_glu else 2
        per_layer = {}
        total = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
        for i in range(cfg.n_layers):
            kind = cfg.kind(i)
            if kind in ("G", "L"):
                mix = attn
            elif kind == "R":
                w = cfg.lru_width or d
                mix = d * w * 2 + w * w * 2 + w * d
            elif kind == "S":
                d_inner = cfg.ssm_expand * d
                n = cfg.ssm_state
                mix = d * (2 * d_inner + 2 * n + d_inner // cfg.ssm_head_dim) \
                    + d_inner * d
            total += mix
            if kind == "S":
                continue
            if cfg.is_moe_layer(i):
                f = cfg.d_ff_expert or cfg.d_ff
                total += glu * d * f * cfg.top_k
                total += glu * d * f * cfg.n_shared_experts
                total += d * cfg.n_experts  # router
            else:
                total += glu * d * cfg.d_ff
        if cfg.enc_dec:
            total += cfg.n_enc_layers * (attn + glu * d * cfg.d_ff)
            total += cfg.n_layers * (4 * d * cfg.n_heads * hd)  # cross-attn
        return total


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
