"""Shared neural-net layers (pure JAX): norms, RoPE, GQA attention with
global/sliding-window masking and ring-buffer KV caches, gated MLPs.

Conventions:
* params are nested dicts of arrays; init functions take an rng and return
  the dict.  Compute dtype follows cfg.dtype; norms/softmax accumulate f32.
* activations are tagged with logical axes via models.sharding.shard —
  no-ops on CPU, PartitionSpecs on the production mesh.
* decode caches: global layers keep [B, S_max, kv, hd]; local (sliding
  window) layers keep a ring buffer [B, W, kv, hd] — this is what makes
  window archs viable at 500k context.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .sharding import shard

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(rng, (d_in, d_out)) * scale).astype(dtype)


def norm_init(d: int, cfg: ModelConfig) -> Dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        y = y * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, rope_frac: float, theta: float) -> jnp.ndarray:
    rot = int(head_dim * rope_frac) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, rope_frac: float,
               theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd]; positions: [B, S] (absolute).  Rotates the leading
    rope_frac fraction of hd (partial rotary, stablelm-style)."""
    hd = x.shape[-1]
    rot = int(hd * rope_frac) // 2 * 2
    if rot == 0:
        return x
    freqs = rope_freqs(hd, rope_frac, theta)                       # [rot/2]
    ang = positions[..., None].astype(jnp.float32) * freqs         # [B,S,rot/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA, global or sliding-window)
# ---------------------------------------------------------------------------


def attn_init(rng, cfg: ModelConfig) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, kv * hd, dt),
        "wv": dense_init(ks[2], d, kv * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    return p


def _qkv(p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"] + (p["bq"] if "bq" in p else 0)
    k = x @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = x @ p["wv"] + (p["bv"] if "bv" in p else 0)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_frac, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_frac, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, softcap: Optional[float], n_heads: int, n_kv: int,
          f32_logits: bool = True, additive_mask=None):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd]; mask: [B?,Sq,Sk] bool or None.

    f32_logits=False is the §Perf bf16-softmax variant: halves the bytes of
    the S×S score tensors (the memory-roofline hot spot at train_4k).
    additive_mask: [Sq,Sk] float bias — §Perf alternative to the boolean
    select (no [B,h,Sq,Sk] bool broadcast + select_n passes)."""
    b, sq, h, hd = q.shape
    groups = h // n_kv
    acc = jnp.float32 if f32_logits else jnp.bfloat16
    qg = q.reshape(b, sq, n_kv, groups, hd)
    logits = jnp.einsum("bsngh,btnh->bngst", qg.astype(acc) * hd ** -0.5,
                        k.astype(acc))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    if additive_mask is not None:
        logits = logits + additive_mask[None, None, None].astype(logits.dtype)
    elif mask is not None:
        neg = jnp.asarray(-1e30 if f32_logits else -3e38, logits.dtype)
        logits = jnp.where(mask[:, None, None, :, :], logits, neg)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(acc) \
        if not f32_logits else jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngst,btnh->bsngh", w, v.astype(acc))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def causal_mask(sq: int, sk: int, positions_q, positions_k, window: Optional[int]):
    """mask[b, i, j] = may q-position i attend to k-position j."""
    m = positions_q[:, :, None] >= positions_k[:, None, :]
    if window is not None:
        m &= positions_q[:, :, None] - positions_k[:, None, :] < window
    return m


def attn_train(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
               window: Optional[int]) -> jnp.ndarray:
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    q, k, v = _qkv(p, cfg, x, pos)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    if cfg.use_flash_attn and window is None and not cfg.logit_softcap:
        # Pallas flash attention (kernels/flash_attn.py): no S×S HBM tensor.
        from ..kernels.flash_attn import flash_attention
        from ..kernels.ops import use_interpret
        h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        q2 = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        k2 = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, hd)
        v2 = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, hd)
        o2 = flash_attention(q2, k2, v2, causal=True, kv_groups=h // kvh,
                             interpret=use_interpret())
        out = o2.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
        y = out.reshape(b, s, -1) @ p["wo"]
        return shard(y, "batch", "seq", None)
    if cfg.attn_additive_mask:
        idx = jnp.arange(s, dtype=jnp.int32)
        ok = idx[:, None] >= idx[None, :]
        if window is not None:
            ok &= idx[:, None] - idx[None, :] < window
        bias = jnp.where(ok, 0.0, -1e30)
        out = _sdpa(q, k, v, None, cfg.logit_softcap, cfg.n_heads,
                    cfg.n_kv_heads, f32_logits=cfg.attn_f32_logits,
                    additive_mask=bias)
    else:
        mask = causal_mask(s, s, pos, pos, window)
        out = _sdpa(q, k, v, mask, cfg.logit_softcap, cfg.n_heads,
                    cfg.n_kv_heads, f32_logits=cfg.attn_f32_logits)
    y = out.reshape(b, s, -1) @ p["wo"]
    return shard(y, "batch", "seq", None)


def attn_cache_init(cfg: ModelConfig, batch: int, max_seq: int,
                    window: Optional[int]) -> Dict:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    size = min(window, max_seq) if window is not None else max_seq
    if cfg.kv_cache_quant:
        # §Perf: int8 KV + per-(token, kv-head) f32 scales — halves the
        # dominant cache-read bytes of long-context decode on TPU
        return {
            "k": jnp.zeros((batch, size, kv, hd), jnp.int8),
            "v": jnp.zeros((batch, size, kv, hd), jnp.int8),
            "k_s": jnp.ones((batch, size, kv, 1), jnp.float32),
            "v_s": jnp.ones((batch, size, kv, 1), jnp.float32),
        }
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, size, kv, hd), dt),
        "v": jnp.zeros((batch, size, kv, hd), dt),
    }


def _quant_kv(x: jnp.ndarray):
    """x: [B,1,kv,hd] -> (int8, f32 scale [B,1,kv,1])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.round(x.astype(jnp.float32) / scale).astype(jnp.int8)
    return q, scale


def attn_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray, cache: Dict,
                pos: jnp.ndarray, window: Optional[int]) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. x: [B,1,d]; pos: scalar int32 (current position);
    cache k/v: [B, S_cache, kv, hd] (ring buffer iff window)."""
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    q, k1, v1 = _qkv(p, cfg, x, positions)
    size = cache["k"].shape[1]
    slot = (pos % size).astype(jnp.int32) if window is not None else pos
    if cfg.kv_cache_quant:
        k1q, k1s = _quant_kv(k1)
        v1q, v1s = _quant_kv(v1)
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k1q, (0, slot, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v1q, (0, slot, 0, 0)),
            "k_s": jax.lax.dynamic_update_slice(cache["k_s"], k1s, (0, slot, 0, 0)),
            "v_s": jax.lax.dynamic_update_slice(cache["v_s"], v1s, (0, slot, 0, 0)),
        }
        dt = jnp.dtype(cfg.dtype)
        ck = (new_cache["k"].astype(jnp.float32) * new_cache["k_s"]).astype(dt)
        cv = (new_cache["v"].astype(jnp.float32) * new_cache["v_s"]).astype(dt)
    else:
        new_cache = None
        ck = jax.lax.dynamic_update_slice(cache["k"], k1, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v1, (0, slot, 0, 0))
    ck = shard(ck, "batch", "kv_seq", "kv_heads", None)
    cv = shard(cv, "batch", "kv_seq", "kv_heads", None)
    # key positions: absolute position of each cache slot
    idx = jnp.arange(size, dtype=jnp.int32)
    if window is not None:
        # ring: slot i holds position p where p % size == i and p <= pos
        kpos = pos - ((pos - idx) % size)
    else:
        kpos = idx
    valid = (kpos <= pos) & (kpos >= 0)
    if window is not None:
        valid &= pos - kpos < window
    if cfg.use_flash_attn and window is None and not cfg.logit_softcap \
            and not cfg.kv_cache_quant:
        # serve-path decode step (kernels/flash_attn.py): online softmax
        # over the cached KV stream, no [B, S_cache] score row in one
        # piece — the cached-KV twin of attn_train's flash gate, with the
        # same layout transform ([B,s,h,hd] -> flat [B·h, ...])
        from ..kernels.flash_attn import flash_decode_step
        h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        q2 = q.transpose(0, 2, 1, 3).reshape(b * h, hd)
        k2 = ck.transpose(0, 2, 1, 3).reshape(b * kvh, size, hd)
        v2 = cv.transpose(0, 2, 1, 3).reshape(b * kvh, size, hd)
        o2 = flash_decode_step(q2, k2, v2, pos, kv_groups=h // kvh)
        out = o2.reshape(b, h, 1, hd).transpose(0, 2, 1, 3)
    else:
        mask = jnp.broadcast_to(valid[None, None, :], (b, 1, size))
        out = _sdpa(q, ck, cv, mask, cfg.logit_softcap, cfg.n_heads,
                    cfg.n_kv_heads, f32_logits=cfg.attn_f32_logits)
    y = out.reshape(b, 1, -1) @ p["wo"]
    return shard(y, "batch", None, None), \
        (new_cache if new_cache is not None else {"k": ck, "v": cv})


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None,
             d_model: Optional[int] = None) -> Dict:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 3)
    p = {"w_up": dense_init(ks[0], d, f, dt),
         "w_down": dense_init(ks[1], f, d, dt)}
    if cfg.mlp_glu:
        p["w_gate"] = dense_init(ks[2], d, f, dt)
    return p


def _act(cfg: ModelConfig, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def apply_mlp(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    up = x @ p["w_up"]
    up = shard(up, "batch", "seq", "ff")
    if "w_gate" in p:
        gate = shard(x @ p["w_gate"], "batch", "seq", "ff")
        h = _act(cfg, gate) * up
    else:
        h = _act(cfg, up)
    y = h @ p["w_down"]
    return shard(y, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_init(rng, cfg: ModelConfig) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 2)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], cfg.d_model, cfg.vocab, dt)
    return p


def embed(p: Dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(p["tok"], tokens, axis=0)
    return shard(x, "batch", "seq", None)


def unembed(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = x @ w
    return shard(logits, "batch", "seq", "vocab")
