"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Griffin recurrent block: two branches from the residual stream —
  gate branch : linear -> GeLU
  rec branch  : linear -> causal conv1d(4) -> RG-LRU
merged multiplicatively, then projected out.

RG-LRU (real-gated linear recurrent unit):
  r_t = σ(W_r x_t)         recurrence gate
  i_t = σ(W_i x_t)         input gate
  a_t = a^(c·r_t)          with a = σ(Λ) learnable, c = 8
  h_t = a_t ⊙ h_{t-1} + √(1 - a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is a first-order linear scan — implemented with
jax.lax.associative_scan over the sequence (TPU-friendly log-depth), and as
a single fused update at decode.  State is [B, lru_width]: O(1) in sequence
length (long_500k viable).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init
from .sharding import shard

_C = 8.0
_MAX_SQRT = 1e-6


def rglru_init(rng, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 6)
    # Λ init so that a = σ(Λ)^c spans ~(0.9, 0.999) as in the paper
    lam = jnp.log(jnp.expm1(jnp.linspace(2.0, 6.0, w))).astype(jnp.float32)
    return {
        "w_gate": dense_init(ks[0], d, w, dt),      # GeLU branch
        "w_rec": dense_init(ks[1], d, w, dt),       # recurrent branch in
        "conv": (jax.random.normal(ks[2], (4, w)) * 0.1).astype(dt),
        "w_r": dense_init(ks[3], w, w, dt),
        "w_i": dense_init(ks[4], w, w, dt),
        "lam": lam,
        "w_out": dense_init(ks[5], w, d, dt),
    }


def _conv4(x: jnp.ndarray, w: jnp.ndarray, prev=None):
    k = w.shape[0]
    pad = prev if prev is not None else jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out, xp[:, -(k - 1):]


def _gates(p: Dict, u: jnp.ndarray):
    """u: [..., w] conv output.  Returns (a, beta·i·u) in f32."""
    r = jax.nn.sigmoid((u @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_i"]).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lam"])       # log σ(Λ)^(c·r) stable form
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), _MAX_SQRT))
    return a, beta * i * u.astype(jnp.float32)


def rglru_train(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    b, s, d = x.shape
    gate = jax.nn.gelu(x @ p["w_gate"])
    u = x @ p["w_rec"]
    u, _ = _conv4(u, p["conv"])
    a, bx = _gates(p, u)                               # [B,S,w] each, f32
    a = shard(a, "batch", "seq", "ff")
    bx = shard(bx, "batch", "seq", "ff")

    # h_t = a_t h_{t-1} + bx_t  — associative: (a1,b1)∘(a2,b2)=(a1a2, a2 b1 + b2)
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return shard(y, "batch", "seq", None)


def rglru_cache_init(cfg: ModelConfig, batch: int) -> Dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, 3, w), jnp.dtype(cfg.dtype)),
    }


def rglru_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray, cache: Dict
                 ) -> Tuple[jnp.ndarray, Dict]:
    gate = jax.nn.gelu(x @ p["w_gate"])                # [B,1,w]
    u = x @ p["w_rec"]
    u, conv_state = _conv4(u, p["conv"], prev=cache["conv"])
    a, bx = _gates(p, u[:, 0])                         # [B,w]
    h = shard(cache["h"], "batch", "ff") * a + bx
    y = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return shard(y, "batch", None, None), {"h": h, "conv": conv_state}
