"""Mixture-of-Experts layer: top-k router + capacity-bounded gather dispatch
+ optional shared experts (DeepSeek-V2) — expert-parallel over the `model`
mesh axis.

Dispatch avoids the GShard one-hot einsum (whose FLOPs, T·E·C·d, would dwarf
the expert FLOPs at 160 experts) in favour of sort+gather: tokens are
argsorted by expert id, each expert gathers its first C tokens, computes the
gated FF, and results scatter-add back weighted by router probs.  Gathers
are bandwidth, not FLOPs, so HLO_FLOPs stays close to 6·N_active·D.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..jaxcompat import shard_map

from .config import ModelConfig
from .layers import dense_init, _act
from .sharding import shard


def moe_init(rng, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    f = cfg.d_ff_expert or cfg.d_ff
    e = cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=0.02),
        "w_up": (jax.random.normal(ks[1], (e, d, f)) * d ** -0.5).astype(dt),
        "w_gate": (jax.random.normal(ks[2], (e, d, f)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * f ** -0.5).astype(dt),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_up": dense_init(k1, d, fs, dt),
            "w_gate": dense_init(k2, d, fs, dt),
            "w_down": dense_init(k3, fs, d, dt),
        }
    return p


def apply_moe(p: Dict, cfg: ModelConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar).

    On a production mesh (rules carry "__mesh__") this takes the shard_map
    expert-parallel path; otherwise the plain single-device path below."""
    from .sharding import current_rules
    rules = current_rules()
    mesh = rules.get("__mesh__")
    if mesh is not None and "model" in getattr(mesh, "axis_names", ()):
        return _apply_moe_shard_map(p, cfg, x, mesh)
    return _apply_moe_dense(p, cfg, x)


def _apply_moe_dense(p: Dict, cfg: ModelConfig, x: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                        # [T, k]
    topw = topw / jnp.sum(topw, -1, keepdims=True)

    # ---- load-balance aux (Switch): E * Σ_e fraction_e * prob_e ----
    onehot_count = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=1)
    frac = jnp.mean(onehot_count, axis=0)                       # [E]
    pmean = jnp.mean(probs, axis=0)                             # [E]
    aux = e * jnp.sum(frac / k * pmean) * cfg.router_aux_weight

    # ---- sort+gather dispatch ----
    cap = max(1, int(t * k / e * cfg.capacity_factor))
    flat_e = topi.reshape(-1)                                    # [T*k]
    order = jnp.argsort(flat_e)                                  # [T*k]
    counts = jnp.bincount(flat_e, length=e)                      # [E]
    offsets = jnp.cumsum(counts) - counts                        # [E]
    slot_pos = offsets[:, None] + jnp.arange(cap)[None, :]       # [E, C]
    valid = jnp.arange(cap)[None, :] < counts[:, None]           # [E, C]
    slot = jnp.take(order, jnp.clip(slot_pos, 0, t * k - 1), axis=0)  # [E, C]
    tok = slot // k                                              # [E, C]

    xe = jnp.take(xt, tok, axis=0) * valid[..., None].astype(xt.dtype)  # [E,C,d]
    xe = shard(xe, "experts", None, None)
    gate = _act(cfg, jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", gate * up, p["w_down"])      # [E,C,d]
    ye = shard(ye, "experts", None, None)

    w_slot = jnp.take(topw.reshape(-1), slot) * valid.astype(jnp.float32)  # [E,C]
    contrib = (ye.astype(jnp.float32) * w_slot[..., None]).reshape(e * cap, d)
    y = jnp.zeros((t, d), jnp.float32).at[tok.reshape(-1)].add(contrib)

    if "shared" in p:
        sp = p["shared"]
        up_s = xt @ sp["w_up"]
        gate_s = _act(cfg, xt @ sp["w_gate"])
        y = y + ((gate_s * up_s) @ sp["w_down"]).astype(jnp.float32)

    y = y.astype(x.dtype).reshape(b, s, d)
    return shard(y, "batch", "seq", None), aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel path (production mesh)
# ---------------------------------------------------------------------------
#
# GSPMD cannot shard the data-dependent sort+gather dispatch (global token
# indices over a batch-sharded array force full replication: measured 80×
# FLOP and 40× collective blow-ups).  The TPU-native design keeps tokens
# SHARD-LOCAL and moves no tokens at all:
#
#   * every model shard holds the full local token set (activations are
#     replicated over `model`, sharded over `data` — standard TP layout);
#   * expert weights are sharded over `model`: whole experts when
#     E % model == 0 (expert parallelism: deepseek 160/16), else the expert
#     hidden dim f (intra-expert TP: mixtral 8 experts on 16 shards);
#   * each shard gathers ITS experts' tokens locally, computes, and
#     scatter-adds a partial output; one psum over `model` combines both
#     expert partitions and f-partials — the same collective shape as a
#     row-parallel dense MLP ([T_loc, d] per layer).

def _moe_specs(cfg: ModelConfig, mesh, batch: int):
    from jax.sharding import PartitionSpec as P
    import numpy as np
    ep = (cfg.n_experts % mesh.shape["model"] == 0) and not cfg.moe_force_tp
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if batch % max(dsize, 1):
        dp = ()  # batch=1 decode: tokens replicated over data
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    if ep:
        w_up = w_gate = P("model", None, None)
        w_down = P("model", None, None)
    else:
        w_up = w_gate = P(None, None, "model")
        w_down = P(None, "model", None)
    return ep, bspec, (w_up, w_gate, w_down)


def _apply_moe_shard_map(p: Dict, cfg: ModelConfig, x: jnp.ndarray, mesh
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    from jax.sharding import PartitionSpec as P
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    ep, bspec, (s_up, s_gate, s_down) = _moe_specs(cfg, mesh, b)
    # aux varies over the data axes (different tokens) and is already
    # invariant over model (x is model-replicated) — pmean the former only
    dp_axes = bspec if isinstance(bspec, tuple) else ((bspec,) if bspec else ())

    def body(xl, router, w_gate, w_up, w_down):
        bl, sl, _ = xl.shape
        t = bl * sl
        xt = xl.reshape(t, d)
        logits = xt.astype(jnp.float32) @ router              # [T_loc, E]
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.sum(topw, -1, keepdims=True)

        onehot_count = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), 1)
        frac = jnp.mean(onehot_count, axis=0)
        pmean = jnp.mean(probs, axis=0)
        aux = e * jnp.sum(frac / k * pmean) * cfg.router_aux_weight
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)

        e_loc = w_up.shape[0]
        first = jax.lax.axis_index("model") * e_loc if e_loc < e else 0
        cap = max(1, int(t * k / e * cfg.capacity_factor))

        flat_e = topi.reshape(-1)                              # [T_loc*k]
        order = jnp.argsort(flat_e)
        counts = jnp.bincount(flat_e, length=e)
        offsets = jnp.cumsum(counts) - counts
        cnt_l = jax.lax.dynamic_slice(counts, (first,), (e_loc,))
        off_l = jax.lax.dynamic_slice(offsets, (first,), (e_loc,))
        slot_pos = off_l[:, None] + jnp.arange(cap)[None, :]
        valid = jnp.arange(cap)[None, :] < cnt_l[:, None]
        slot = jnp.take(order, jnp.clip(slot_pos, 0, t * k - 1), axis=0)
        tok = slot // k                                        # [E_loc, C]

        xe = jnp.take(xt, tok, axis=0) * valid[..., None].astype(xt.dtype)
        gate = _act(cfg, jnp.einsum("ecd,edf->ecf", xe, w_gate))
        up = jnp.einsum("ecd,edf->ecf", xe, w_up)
        ye = jnp.einsum("ecf,efd->ecd", gate * up, w_down)     # [E_loc,C,d]

        w_slot = jnp.take(topw.reshape(-1), slot) * valid.astype(jnp.float32)
        acc = jnp.bfloat16 if cfg.moe_psum_bf16 else jnp.float32
        contrib = (ye.astype(acc) * w_slot[..., None].astype(acc)).reshape(-1, d)
        y = jnp.zeros((t, d), acc).at[tok.reshape(-1)].add(contrib)
        y = jax.lax.psum(y, "model")                           # combine partials
        return y.astype(xl.dtype).reshape(bl, sl, d), aux

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), P(), s_gate, s_up, s_down),
        out_specs=(P(bspec, None, None), P()),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if "shared" in p:
        sp = p["shared"]
        xt = x.reshape(-1, d)
        up_s = xt @ sp["w_up"]
        gate_s = _act(cfg, xt @ sp["w_gate"])
        y = y + ((gate_s * up_s) @ sp["w_down"]).reshape(b, s, d)

    return shard(y, "batch", "seq", None), aux
