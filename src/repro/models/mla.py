"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed into a rank-``kv_lora_rank`` latent c_kv plus a shared
RoPE key k_rope; queries optionally go through a q_lora bottleneck.  The
decode path uses the *absorbed* form: W_uk is folded into the query so
attention runs directly against the cached latent — the cache holds only
[S, kv_lora + rope_dim] per token (the paper's 93% KV-cache cut), not
per-head keys/values.

Train/prefill uses the naive (materialized) form, which is einsum-friendlier
for long sequences; decode uses absorption.  Both share the same params.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_norm, apply_rope, dense_init, norm_init
from .sharding import shard


def mla_init(rng, cfg: ModelConfig) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 8)
    p = {
        # KV path: x -> [c_kv | k_rope]
        "w_dkv": dense_init(ks[0], d, r_kv + dr, dt),
        "norm_kv": norm_init(r_kv, cfg),
        "w_uk": (jax.random.normal(ks[1], (r_kv, h, dn)) * r_kv ** -0.5).astype(dt),
        "w_uv": (jax.random.normal(ks[2], (r_kv, h, dv)) * r_kv ** -0.5).astype(dt),
        "wo": dense_init(ks[3], h * dv, d, dt),
    }
    if r_q:
        p["w_dq"] = dense_init(ks[4], d, r_q, dt)
        p["norm_q"] = norm_init(r_q, cfg)
        p["w_uq"] = (jax.random.normal(ks[5], (r_q, h, dn + dr)) * r_q ** -0.5).astype(dt)
    else:
        p["w_q"] = (jax.random.normal(ks[5], (d, h, dn + dr)) * d ** -0.5).astype(dt)
    return p


def _queries(p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    if "w_dq" in p:
        cq = apply_norm(p["norm_q"], x @ p["w_dq"], cfg)
        q = jnp.einsum("bsr,rhd->bshd", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, 1.0, cfg.rope_theta)
    return q_nope, q_rope


def _latent(p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions):
    r_kv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    dkv = x @ p["w_dkv"]                                        # [B,S,r+dr]
    c_kv = apply_norm(p["norm_kv"], dkv[..., :r_kv], cfg)       # [B,S,r]
    k_rope = dkv[..., None, r_kv:]                              # [B,S,1,dr]
    k_rope = apply_rope(k_rope, positions, 1.0, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope                                         # [B,S,r], [B,S,dr]


def mla_train(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Naive (materialized) form for train/prefill."""
    b, s, _ = x.shape
    h, dn, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q_nope, q_rope = _queries(p, cfg, x, pos)
    c_kv, k_rope = _latent(p, cfg, x, pos)
    k_nope = jnp.einsum("btr,rhd->bthd", c_kv, p["w_uk"])        # [B,S,h,dn]
    v = jnp.einsum("btr,rhd->bthd", c_kv, p["w_uv"])             # [B,S,h,dv]
    q_nope = shard(q_nope, "batch", "seq", "heads", None)
    k_nope = shard(k_nope, "batch", "seq", "heads", None)
    v = shard(v, "batch", "seq", "heads", None)
    scale = (dn + cfg.qk_rope_dim) ** -0.5
    acc = jnp.float32 if cfg.attn_f32_logits else jnp.bfloat16
    if cfg.mla_fused_qk:
        # §Perf: one QK dot over concat features — the naive two-einsum form
        # writes+reads the S×S tensor twice more (dot #2 + transpose + add)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)       # [B,S,h,dn+dr]
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (*k_rope.shape[:2], h, cfg.qk_rope_dim))],
            axis=-1)
        logits = jnp.einsum("bshd,bthd->bhst",
                            (q_full * scale).astype(acc), k_full.astype(acc))
    else:
        logits = (jnp.einsum("bshd,bthd->bhst", q_nope.astype(acc),
                             k_nope.astype(acc))
                  + jnp.einsum("bshd,btd->bhst", q_rope.astype(acc),
                               k_rope.astype(acc))) * jnp.asarray(scale, acc)
    if cfg.attn_additive_mask:
        # §Perf: additive causal bias, no [B,h,S,S] bool broadcast + select
        bias = jnp.where(pos[0][:, None] >= pos[0][None, :], 0.0, -1e30)
        logits = logits + bias[None, None, :, :].astype(logits.dtype)
    else:
        mask = pos[:, :, None] >= pos[:, None, :]
        neg = jnp.asarray(-1e30 if cfg.attn_f32_logits else -3e38, acc)
        logits = jnp.where(mask[:, None, :, :], logits, neg)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(acc) \
        if not cfg.attn_f32_logits else jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", w, v.astype(acc)).astype(x.dtype)
    y = out.reshape(b, s, h * dv) @ p["wo"]
    return shard(y, "batch", "seq", None)


def mla_cache_init(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dt),
    }


def mla_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray, cache: Dict,
               pos: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed-form decode: attention runs in the latent space against the
    compressed cache."""
    b = x.shape[0]
    h, dn, dv, r = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    q_nope, q_rope = _queries(p, cfg, x, positions)              # [B,1,h,*]
    c1, kr1 = _latent(p, cfg, x, positions)                      # [B,1,r],[B,1,dr]
    ck = jax.lax.dynamic_update_slice(cache["c_kv"], c1, (0, pos, 0))
    cr = jax.lax.dynamic_update_slice(cache["k_rope"], kr1, (0, pos, 0))
    ck = shard(ck, "batch", "kv_seq", None)
    cr = shard(cr, "batch", "kv_seq", None)
    # absorb W_uk into q: q_lat[b,h,r] = Σ_d q_nope[b,h,d] · W_uk[r,h,d]
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, p["w_uk"])[:, 0]    # [B,h,r]
    scale = (dn + cfg.qk_rope_dim) ** -0.5
    logits = (jnp.einsum("bhr,btr->bht", q_lat.astype(jnp.float32),
                         ck.astype(jnp.float32))
              + jnp.einsum("bhd,btd->bht", q_rope[:, 0].astype(jnp.float32),
                           cr.astype(jnp.float32))) * scale
    size = ck.shape[1]
    valid = jnp.arange(size, dtype=jnp.int32) <= pos
    logits = jnp.where(valid[None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)                          # [B,h,S]
    lat = jnp.einsum("bht,btr->bhr", w, ck.astype(jnp.float32))  # [B,h,r]
    out = jnp.einsum("bhr,rhd->bhd", lat.astype(x.dtype), p["w_uv"])  # [B,h,dv]
    y = out.reshape(b, 1, h * dv) @ p["wo"]
    return shard(y, "batch", None, None), {"c_kv": ck, "k_rope": cr}
