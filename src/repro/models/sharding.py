"""Logical-axis sharding annotations for model code.

Model code tags activations with *logical* axis names; the launcher installs
rules mapping logical names to mesh axes.  With no rules installed (CPU
tests), every annotation is a no-op — the same model code runs single-device
and on the production mesh.

    with sharding_rules(batch=("pod", "data"), heads="model", ...):
        lowered = jax.jit(step).lower(...)
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _rules() -> Dict[str, Union[str, Tuple[str, ...], None]]:
    return getattr(_state, "rules", None) or {}


def current_rules() -> Dict[str, Union[str, Tuple[str, ...], None]]:
    """Installed logical-axis rules (empty dict when none).  The launcher
    additionally stashes the live Mesh under key "__mesh__" so modules that
    need explicit collectives (shard_map MoE) can reach it."""
    return _rules()


@contextmanager
def sharding_rules(**rules):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def logical_spec(*names: Optional[str]) -> P:
    rules = _rules()
    return P(*[rules.get(n) if n is not None else None for n in names])


def shard(x, *names: Optional[str]):
    """Annotate array x (rank == len(names)) with logical axes.  No-op when no
    rules are installed or the annotation refers to axes absent from the
    ambient mesh."""
    rules = _rules()
    if not rules:
        return x
    if x.ndim != len(names):
        raise ValueError(f"shard({x.shape}) got {len(names)} names {names}")
    try:
        return jax.lax.with_sharding_constraint(x, logical_spec(*names))
    except Exception:
        return x  # no mesh in context / inapplicable spec for this shape
