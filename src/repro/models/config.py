"""ModelConfig — one dataclass drives every assigned architecture.

``layer_pattern`` is a cycled string of per-layer mixer kinds:
  G = global attention, L = local (sliding-window) attention,
  R = RG-LRU recurrent block, S = Mamba-2 SSD block.
MLP kind per layer is derived from the MoE fields (first ``first_dense``
layers stay dense, as in DeepSeek-V2).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None      # default d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    act: str = "silu"                   # silu | gelu
    mlp_glu: bool = True                # gated (SwiGLU/GeGLU) vs plain
    rope_theta: float = 10_000.0
    rope_frac: float = 1.0              # partial rotary (stablelm: 0.25)
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None

    layer_pattern: str = "G"
    window: Optional[int] = None        # sliding window for 'L' layers

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: Optional[int] = None
    first_dense: int = 0                # leading dense layers (deepseek: 1)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # MLA (deepseek-v2)
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv: int = 4

    # RG-LRU (recurrentgemma)
    lru_width: Optional[int] = None

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500                 # whisper: 30s of audio
    max_seq: Optional[int] = None       # architectural context cap (whisper dec: 448)

    # modality frontend stubs (the one allowed stub): embeddings arrive
    # precomputed via input_specs()
    frontend: Optional[str] = None      # None | vision | audio
    n_patches: int = 0                  # vision tokens prepended to text

    dtype: str = "bfloat16"
    source: str = ""                    # citation

    # ---- perf-iteration knobs (§Perf hillclimb; defaults = paper-faithful
    # baseline). Each is measurable in the compiled dry-run HLO. ----
    attn_f32_logits: bool = True        # False: bf16 attention logits/softmax
    kv_cache_quant: bool = False        # int8 KV cache + per-token scales
    moe_psum_bf16: bool = False         # bf16 MoE combine psum
    moe_force_tp: bool = False          # ablation: intra-expert TP even when
                                        # expert parallelism divides
    ssm_seq_parallel: bool = False      # sequence-parallel SSD over `model`
                                        # (log-depth cross-shard state scan)
    mla_fused_qk: bool = False          # one concat QK einsum (no 2nd S×S
                                        # dot + transpose + add pass)
    use_flash_attn: bool = False        # Pallas flash-attention for global
                                        # causal layers (TPU production path)
    attn_additive_mask: bool = False    # additive causal bias instead of
                                        # boolean select (fewer S×S passes)

    # ------------------------------------------------------------------
    def kind(self, layer: int) -> str:
        return self.layer_pattern[layer % len(self.layer_pattern)]

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def is_moe_layer(self, layer: int) -> bool:
        return self.n_experts > 0 and layer >= self.first_dense

    @property
    def attention_free(self) -> bool:
        return all(k in ("S", "R") for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is bounded (no full-length KV on any layer) or
        attention layers are all windowed."""
        return all(k in ("S", "R", "L") for k in self.layer_pattern)

    def smoke(self) -> "ModelConfig":
        """Reduced variant of the same family: <=2 layers (pattern-preserving),
        d_model<=512, <=4 experts — runs a real step on CPU."""
        pat = self.layer_pattern
        n_layers = max(2, min(len(pat), 3)) if len(pat) > 1 else 2
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        hd = min(self.resolved_head_dim, 64)
        return replace(
            self,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            d_ff_expert=min(self.d_ff_expert, 128) if self.d_ff_expert else None,
            first_dense=min(self.first_dense, 1),
            kv_lora_rank=min(self.kv_lora_rank, 64) if self.kv_lora_rank else 0,
            q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
            qk_nope_dim=min(self.qk_nope_dim, 32),
            qk_rope_dim=min(self.qk_rope_dim, 16),
            v_head_dim=min(self.v_head_dim, 32),
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_chunk=16 if self.ssm_state else self.ssm_chunk,
            lru_width=min(self.lru_width, 256) if self.lru_width else None,
            n_enc_layers=2 if self.enc_dec else 0,
            enc_seq=32 if self.enc_dec else self.enc_seq,
            window=min(self.window, 32) if self.window else None,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            max_seq=None if self.max_seq is None else min(self.max_seq, 64),
            dtype="float32",
        )
