"""Whisper-style encoder-decoder (arXiv:2212.04356).

The mel-spectrogram + conv2 frontend is the allowed STUB: ``input_specs``
supplies precomputed frame embeddings [B, enc_seq, d_model] (enc_seq = 1500
for 30 s audio).  Everything downstream — sinusoidal-free learned positions,
pre-norm encoder blocks (bidirectional attention), decoder blocks with
causal self-attention + cross-attention — is implemented here.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from .sharding import shard


def _xattn_init(rng, cfg: ModelConfig) -> Dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    return {"wq": L.dense_init(ks[0], d, h * hd, dt),
            "wk": L.dense_init(ks[1], d, h * hd, dt),
            "wv": L.dense_init(ks[2], d, h * hd, dt),
            "wo": L.dense_init(ks[3], h * hd, d, dt)}


def enc_block_init(rng, cfg: ModelConfig) -> Dict:
    k1, k2 = jax.random.split(rng)
    return {"norm1": L.norm_init(cfg.d_model, cfg),
            "attn": L.attn_init(k1, cfg),
            "norm2": L.norm_init(cfg.d_model, cfg),
            "mlp": L.mlp_init(k2, cfg)}


def dec_block_init(rng, cfg: ModelConfig) -> Dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {"norm1": L.norm_init(cfg.d_model, cfg),
            "attn": L.attn_init(k1, cfg),
            "norm_x": L.norm_init(cfg.d_model, cfg),
            "xattn": _xattn_init(k2, cfg),
            "norm2": L.norm_init(cfg.d_model, cfg),
            "mlp": L.mlp_init(k3, cfg)}


def init_params(rng, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(rng, cfg.n_enc_layers + cfg.n_layers + 3)
    dt = jnp.dtype(cfg.dtype)
    max_dec = cfg.max_seq or 448
    return {
        "embed": L.embed_init(ks[0], cfg),
        "pos_enc": (jax.random.normal(ks[1], (cfg.enc_seq, cfg.d_model)) * 0.01).astype(dt),
        "pos_dec": (jax.random.normal(ks[2], (max_dec, cfg.d_model)) * 0.01).astype(dt),
        "enc_layers": [enc_block_init(ks[3 + i], cfg)
                       for i in range(cfg.n_enc_layers)],
        "dec_layers": [dec_block_init(ks[3 + cfg.n_enc_layers + i], cfg)
                       for i in range(cfg.n_layers)],
        "enc_final": L.norm_init(cfg.d_model, cfg),
        "dec_final": L.norm_init(cfg.d_model, cfg),
    }


def _bidir_attn(p, cfg: ModelConfig, x):
    """Encoder self-attention: no mask, no RoPE (whisper uses learned pos)."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, h, hd)
    v = (x @ p["wv"]).reshape(b, s, h, hd)
    q = shard(q, "batch", "seq", "heads", None)
    out = L._sdpa(q, k, v, None, None, h, h)
    return out.reshape(b, s, -1) @ p["wo"]


def encode(params, cfg: ModelConfig, frames) -> jnp.ndarray:
    """frames: [B, enc_seq, d_model] precomputed conv-frontend embeddings."""
    x = frames.astype(jnp.dtype(cfg.dtype)) + params["pos_enc"][None]
    x = shard(x, "batch", "seq", None)
    for p in params["enc_layers"]:
        h = L.apply_norm(p["norm1"], x, cfg)
        x = x + _bidir_attn(p["attn"], cfg, h)
        h = L.apply_norm(p["norm2"], x, cfg)
        x = x + L.apply_mlp(p["mlp"], cfg, h)
    return L.apply_norm(params["enc_final"], x, cfg)


def _cross_attn(p, cfg: ModelConfig, x, enc_k, enc_v):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    out = L._sdpa(q, enc_k, enc_v, None, None, h, h)
    return out.reshape(b, s, -1) @ p["wo"]


def _enc_kv(p, cfg: ModelConfig, enc_out):
    b, t, _ = enc_out.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(b, t, h, hd)
    v = (enc_out @ p["wv"]).reshape(b, t, h, hd)
    return k, v


def decode_train(params, cfg: ModelConfig, tokens, enc_out
                 ) -> jnp.ndarray:
    """Teacher-forced decoder pass. tokens: [B,S]."""
    b, s = tokens.shape
    x = L.embed(params["embed"], cfg, tokens) + params["pos_dec"][None, :s]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    for p in params["dec_layers"]:
        h = L.apply_norm(p["norm1"], x, cfg)
        # causal self-attn (no RoPE: learned positions already added)
        q, k, v = L._qkv({**p["attn"]}, _norope(cfg), h, pos)
        mask = L.causal_mask(s, s, pos, pos, None)
        sa = L._sdpa(q, k, v, mask, None, cfg.n_heads, cfg.n_kv_heads)
        x = x + sa.reshape(b, s, -1) @ p["attn"]["wo"]
        h = L.apply_norm(p["norm_x"], x, cfg)
        enc_k, enc_v = _enc_kv(p["xattn"], cfg, enc_out)
        x = x + _cross_attn(p["xattn"], cfg, h, enc_k, enc_v)
        h = L.apply_norm(p["norm2"], x, cfg)
        x = x + L.apply_mlp(p["mlp"], cfg, h)
    x = L.apply_norm(params["dec_final"], x, cfg)
    return L.unembed(params["embed"], cfg, x)


_NOROPE_CACHE: Dict[int, ModelConfig] = {}


def _norope(cfg: ModelConfig) -> ModelConfig:
    from dataclasses import replace
    key = id(cfg)
    if key not in _NOROPE_CACHE:
        _NOROPE_CACHE[key] = replace(cfg, rope_frac=0.0)
    return _NOROPE_CACHE[key]


def train(params, cfg: ModelConfig, frames, tokens) -> Tuple[jnp.ndarray, jnp.ndarray]:
    enc_out = encode(params, cfg, frames)
    logits = decode_train(params, cfg, tokens, enc_out)
    return logits, jnp.float32(0)


def cache_init(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    max_dec = min(max_seq, cfg.max_seq or 448)
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "pos": jnp.int32(0),
        "self": [{"k": jnp.zeros((batch, max_dec, cfg.n_kv_heads, hd), dt),
                  "v": jnp.zeros((batch, max_dec, cfg.n_kv_heads, hd), dt)}
                 for _ in range(cfg.n_layers)],
        "cross_k": [jnp.zeros((batch, cfg.enc_seq, h, hd), dt)
                    for _ in range(cfg.n_layers)],
        "cross_v": [jnp.zeros((batch, cfg.enc_seq, h, hd), dt)
                    for _ in range(cfg.n_layers)],
    }


def prefill(params, cfg: ModelConfig, frames, tokens, max_seq: int
            ) -> Tuple[jnp.ndarray, Dict]:
    """Encode audio + teacher-force the prompt, building the decode cache."""
    b, s = tokens.shape
    enc_out = encode(params, cfg, frames)
    cache = cache_init(cfg, b, max_seq)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = L.embed(params["embed"], cfg, tokens) + params["pos_dec"][None, :s]
    for i, p in enumerate(params["dec_layers"]):
        h = L.apply_norm(p["norm1"], x, cfg)
        q, k, v = L._qkv(p["attn"], _norope(cfg), h, pos)
        cache["self"][i]["k"] = jax.lax.dynamic_update_slice(
            cache["self"][i]["k"], k, (0, 0, 0, 0))
        cache["self"][i]["v"] = jax.lax.dynamic_update_slice(
            cache["self"][i]["v"], v, (0, 0, 0, 0))
        mask = L.causal_mask(s, s, pos, pos, None)
        sa = L._sdpa(q, k, v, mask, None, cfg.n_heads, cfg.n_kv_heads)
        x = x + sa.reshape(b, s, -1) @ p["attn"]["wo"]
        h = L.apply_norm(p["norm_x"], x, cfg)
        enc_k, enc_v = _enc_kv(p["xattn"], cfg, enc_out)
        cache["cross_k"][i] = enc_k
        cache["cross_v"][i] = enc_v
        x = x + _cross_attn(p["xattn"], cfg, h, enc_k, enc_v)
        h = L.apply_norm(p["norm2"], x, cfg)
        x = x + L.apply_mlp(p["mlp"], cfg, h)
    x = L.apply_norm(params["dec_final"], x, cfg)
    logits = L.unembed(params["embed"], cfg, x[:, -1:])[:, 0]
    cache["pos"] = jnp.int32(s)
    return logits, cache


def decode_step(params, cfg: ModelConfig, token, cache) -> Tuple[jnp.ndarray, Dict]:
    b = token.shape[0]
    pos = cache["pos"]
    x = L.embed(params["embed"], cfg, token[:, None]) \
        + jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1, 0)[None]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    new_self = []
    for i, p in enumerate(params["dec_layers"]):
        h = L.apply_norm(p["norm1"], x, cfg)
        q, k1, v1 = L._qkv(p["attn"], _norope(cfg), h, positions)
        ck = jax.lax.dynamic_update_slice(cache["self"][i]["k"], k1, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["self"][i]["v"], v1, (0, pos, 0, 0))
        size = ck.shape[1]
        valid = jnp.arange(size, dtype=jnp.int32) <= pos
        mask = jnp.broadcast_to(valid[None, None, :], (b, 1, size))
        sa = L._sdpa(q, ck, cv, mask, None, cfg.n_heads, cfg.n_kv_heads)
        x = x + sa.reshape(b, 1, -1) @ p["attn"]["wo"]
        h = L.apply_norm(p["norm_x"], x, cfg)
        x = x + _cross_attn(p["xattn"], cfg, h, cache["cross_k"][i],
                            cache["cross_v"][i])
        h = L.apply_norm(p["norm2"], x, cfg)
        x = x + L.apply_mlp(p["mlp"], cfg, h)
        new_self.append({"k": ck, "v": cv})
    x = L.apply_norm(params["dec_final"], x, cfg)
    logits = L.unembed(params["embed"], cfg, x)[:, 0]
    return logits, {"pos": pos + 1, "self": new_self,
                    "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
