from .ckpt import load_checkpoint, save_checkpoint, latest_step
