"""Checkpointing: pytree -> sharded .npz files + a json manifest.

Layout:  <dir>/step_<n>/manifest.json + arrays_<k>.npz  (arrays chunked so
no single file exceeds ~512 MB; restore is lazy per-chunk).  Paths in the
manifest are '/'-joined pytree key paths, so restore round-trips dicts,
lists, and NamedTuples produced by the optimizer.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_CHUNK_BYTES = 512 << 20


def _flatten(tree) -> List[Tuple[str, np.ndarray, str]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        true_dtype = str(arr.dtype)
        if true_dtype == "bfloat16":  # numpy npz can't store ml_dtypes
            arr = arr.view(np.uint16)
        out.append((key, arr, true_dtype))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    leaves = _flatten(tree)
    chunks: List[Dict[str, np.ndarray]] = [{}]
    sizes = [0]
    manifest = {"step": step, "leaves": {}, "chunks": 0}
    for key, arr, true_dtype in leaves:
        if sizes[-1] + arr.nbytes > _CHUNK_BYTES and chunks[-1]:
            chunks.append({})
            sizes.append(0)
        ck = len(chunks) - 1
        slot = f"a{len(chunks[ck])}"
        chunks[ck][slot] = arr
        sizes[ck] += arr.nbytes
        manifest["leaves"][key] = {"chunk": ck, "slot": slot,
                                   "shape": list(arr.shape),
                                   "dtype": true_dtype}
    manifest["chunks"] = len(chunks)
    for i, ch in enumerate(chunks):
        np.savez(os.path.join(d, f"arrays_{i}.npz"), **ch)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return d


def load_checkpoint(directory: str, step: Optional[int] = None,
                    like: Any = None) -> Tuple[int, Any]:
    """Returns (step, tree).  If ``like`` is given, the result has its exact
    pytree structure (required to restore lists/NamedTuples)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    cache: Dict[int, Any] = {}

    def chunk(i):
        if i not in cache:
            cache[i] = np.load(os.path.join(d, f"arrays_{i}.npz"))
        return cache[i]

    def restore(meta):
        arr = chunk(meta["chunk"])[meta["slot"]]
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        return arr

    by_key = {k: restore(v) for k, v in manifest["leaves"].items()}
    if like is not None:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat:
            key = "/".join(_path_str(p) for p in path)
            if key not in by_key:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            target = np.asarray(leaf).dtype
            got = by_key[key]
            leaves.append(got if str(got.dtype) == str(target)
                          else got.astype(target))
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
    # best-effort nested-dict reconstruction
    tree: Dict = {}
    for key, arr in by_key.items():
        node = tree
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return step, tree


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for n in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", n))]
    return max(steps) if steps else None
