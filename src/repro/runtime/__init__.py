from .autoscale import Autoscaler
from .scheduler import Device, Runtime

__all__ = ["Autoscaler", "Device", "Runtime"]
