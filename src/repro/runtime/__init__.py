from .scheduler import Device, Runtime
