"""Multi-pipeline runtime: simulates an among-device deployment in-process.

Each Device owns a clock (with skew/jitter — real consumer devices disagree
about time) and a set of pipelines.  The Runtime drives everything with a
global tick (default 60 Hz frame cadence, matching the paper's evaluation):

  * per tick, every device advances its clock and runs each pipeline whose
    inputs are ready (mqttsrc with an empty channel = not ready, like a
    GStreamer src blocking on no data);
  * mqttsink pushes into its Channel; Channels can carry latency (the
    paper's queue2 latency-injection experiment) and bounded capacity with
    leaky-drop semantics;
  * query clients run synchronously against their server pipeline (the
    runtime wires ``inline_runner`` so a client step triggers the remote
    inference — one round-trip per frame, as in Fig. 2).

Statistics (frames, drops, bytes, per-sink pts) feed the Fig. 7 benchmark.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax

from ..core.broker import Broker, BrokerError
from ..core.buffers import StreamBuffer
from ..core.element import Element
from ..core.pipeline import Pipeline
from ..core.pubsub import Channel, MqttSink, MqttSrc
from ..core.query import TensorQueryClient, TensorQueryServerSrc
from ..core.sync import PipelineClock, SimClock

TICK_NS = 16_666_667  # 60 Hz


@dataclass
class _PipeRun:
    pipe: Pipeline
    params: dict
    state: dict
    step_fn: Callable
    frames: int = 0
    skipped: int = 0
    last_outputs: Dict[str, StreamBuffer] = field(default_factory=dict)
    sink_log: Dict[str, list] = field(default_factory=dict)


class Device:
    def __init__(self, name: str, clock: Optional[SimClock] = None):
        self.name = name
        self.clock = clock or SimClock()
        self.pipeline_clock = PipelineClock(self.clock)
        self.runs: List[_PipeRun] = []

    def add_pipeline(self, pipe: Pipeline, rng=None, jit: bool = True) -> _PipeRun:
        pipe.realize()
        # wire pipeline clock into pub/sub elements for §4.2.3 sync
        for e in pipe.elements.values():
            if isinstance(e, (MqttSink, MqttSrc)) and e.sync_clock is None:
                e.sync_clock = self.pipeline_clock
        params = pipe.init(rng if rng is not None else jax.random.PRNGKey(0))
        state = pipe.init_state()
        fn = jax.jit(pipe.step) if jit else pipe.step
        run = _PipeRun(pipe=pipe, params=params, state=state, step_fn=fn)
        self.runs.append(run)
        return run


class Runtime:
    def __init__(self, broker: Optional[Broker] = None, tick_ns: int = TICK_NS):
        self.broker = broker or Broker()
        self.devices: List[Device] = []
        self.tick_ns = tick_ns
        self.ticks = 0

    def add_device(self, device: Device) -> Device:
        self.devices.append(device)
        # connect broker-facing elements & calibrate NTP against the broker's
        # reference clock (a fresh zero-skew SimClock)
        if not hasattr(self, "_ntp_ref"):
            self._ntp_ref = SimClock()
        for run in device.runs:
            self._wire(device, run)
        device.pipeline_clock.calibrate(self._ntp_ref)
        device.pipeline_clock.start()
        return device

    def _wire(self, device: Device, run: _PipeRun):
        for e in run.pipe.elements.values():
            if isinstance(e, (MqttSink, MqttSrc, TensorQueryClient)) and e.broker is None:
                e.connect(self.broker)
            if isinstance(e, TensorQueryServerSrc) and e.registration is None:
                e.connect(self.broker, inline_runner=lambda r=run: self._run_once(r))
        # (re)negotiate with broker wiring in place so mqttsink registers
        run.pipe._realized = False
        run.pipe.realize()

    # -- readiness ---------------------------------------------------------------
    def _ready(self, run: _PipeRun) -> bool:
        for e in run.pipe.elements.values():
            if isinstance(e, MqttSrc):
                try:
                    if len(e._resolve()) == 0:
                        return False
                except BrokerError:
                    return False
            if isinstance(e, TensorQueryServerSrc):
                if len(e.endpoint.requests) == 0:
                    return False
        return True

    def _run_once(self, run: _PipeRun):
        # host-level elements (mqttsrc pull / query send) are impure, so
        # pipelines containing them run un-jitted; pure pipelines run jitted.
        outputs, run.state = run.pipe.step(run.params, run.state)
        run.frames += 1
        run.last_outputs = outputs
        for name, buf in outputs.items():
            run.sink_log.setdefault(name, []).append(buf)
        return outputs

    def tick(self):
        self.ticks += 1
        self._ntp_ref.advance(self.tick_ns)
        for dev in self.devices:
            dev.clock.advance(self.tick_ns)
        for dev in self.devices:
            for run in dev.runs:
                if any(isinstance(e, TensorQueryServerSrc)
                       for e in run.pipe.elements.values()):
                    continue  # servers run inline, driven by clients
                if self._ready(run):
                    self._run_once(run)
                else:
                    run.skipped += 1

    def run(self, n_ticks: int):
        for _ in range(n_ticks):
            self.tick()
        return self

    # -- stats --------------------------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        out = {}
        for dev in self.devices:
            for i, run in enumerate(dev.runs):
                key = f"{dev.name}/p{i}"
                out[key] = {"frames": run.frames, "skipped": run.skipped}
        out["broker"] = {"relay_msgs": self.broker.relay_msgs,
                         "relay_bytes": self.broker.relay_bytes}
        return out
