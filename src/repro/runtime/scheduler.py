"""Multi-pipeline runtime: simulates an among-device deployment in-process.

Each Device owns a clock (with skew/jitter — real consumer devices disagree
about time) and a set of pipelines.  The Runtime drives everything with a
global tick (default 60 Hz frame cadence, matching the paper's evaluation):

  * per tick, every device advances its clock and runs each pipeline whose
    inputs are ready (mqttsrc with an empty channel = not ready, like a
    GStreamer src blocking on no data);
  * mqttsink pushes into its Channel; Channels can carry latency (the
    paper's queue2 latency-injection experiment) and bounded capacity with
    leaky-drop semantics;
  * query clients run synchronously against their server pipeline (the
    runtime wires ``inline_runner`` so a client step triggers the remote
    inference — one round-trip per frame, as in Fig. 2).

Burst draining (default on, ``burst=8``): when a subscriber pipeline has
frames queued in its Channels — a slow consumer that fell behind, or a late
joiner replaying retained history — the scheduler drains up to ``burst``
frames in ONE dispatch instead of one frame per tick.  The host pulls and
decodes the queued frames, stacks them (``stack_buffers``), and runs the
pipeline's compiled plan in hoisted-I/O mode: a single ``lax.scan`` executes
the whole DAG N times, then captured mqttsink frames are replayed through
the real (impure) sink ``apply`` in order.  Pipelines whose impure elements
are not hoistable fall back to per-frame stepping automatically.

Query micro-batching (default on, ``query_batch=8``, DESIGN.md §2): client
pipelines run *deferred* — the plan pauses at each ``tensor_query_client``,
the scheduler ships the request to the server endpoint's ``QueryBatcher``,
and once every ready pipeline has sent (the tick deadline — or earlier when
a batcher hits ``max_batch``), each server serves its gathered requests in
ONE hoisted scan dispatch and the paused frames resume with their routed
answers.  ``query_batch=0`` restores the legacy synchronous one-round-trip-
per-frame path inside ``tensor_query_client.apply``.

Failover fabric (DESIGN.md §3): the runtime heartbeats the broker on behalf
of every live device each tick and advances the broker's lease clock, so a
silently dead device's registrations expire and fire ``down`` events; query
requests whose serving endpoint dies in flight are re-dispatched — each
``PendingQuery`` retains its request buffer and records the endpoint it was
shipped to — to the next-ranked surviving server, or *parked* until one
registers (retried at the top of every tick; ``park_deadline_ticks`` bounds
how long — an expired park becomes an accounted, client-visible error
instead of an unbounded busy-skip).  Killing a server therefore loses zero
client requests; with a surviving (same-seeded) server the answers are
bitwise what the fault-free run produces.

Live reconfiguration (DESIGN.md §6): ``Runtime.reconfigure(run, edit)``
applies a topology edit — swap an element, re-route a link, add/remove an
endpoint or binding — to a RUNNING pipeline with prepare → warm → commit →
drain semantics (``core/reconfig.py``): the new plan realizes and warms off
the serving path, then cuts over at a tick boundary with queued frames and
in-flight queries carried across the swap.  Broker liveness events route
through the same machinery (``ReconfigManager.on_broker_event``): a server
death or revival is an unplanned topology edit, handled by the same
endpoint teardown/activation a planned remove/add uses.

Mesh-sharded serving (DESIGN.md §4): ``Runtime(mesh=...)`` can lay batched
query serves and hoisted pub/sub bursts out along the mesh's data axes —
one frame slice per device, params replicated — whenever the batch tiles
the mesh and the plan threads no cross-frame state
(``ExecutionPlan.shardable_batch``).  ``mesh="auto"`` builds a host mesh
over the local devices; ``shard_mode`` picks the placement policy
("auto" probes sharded-vs-single once per batch size and keeps the faster,
"always"/"never" force it).  Sharding never changes semantics: non-tiling
groups, stateful plans, and 1-device meshes serve exactly like
``Runtime(mesh=None)``, and the failover fabric re-dispatches sharded
batches' orphans identically (the mesh only places compute; the
request/answer plumbing is untouched).

Fused wire path (default on, ``fused_wire=True``, DESIGN.md §5): the whole
codec/transport hot path of a tick runs batched.  Client pipelines whose
only impure elements are their query clients start and resume through
jitted deferred SEGMENTS (``plan.run_deferred_compiled``) instead of
interpreted walks; a dispatch round gathers every freshly paused frame,
encodes the requests per (codec, structure) group in ONE batched codec
dispatch, and pushes in arrival order; each server flush serves wire-form
groups through the codec-fused executable (decode → stacked scan → answer
re-encode inside one jit, ``core/batching.py``); and the drain batch-
decodes the round's answers per group before resuming.  Bitwise identical
to the eager path at every seam (codec kernels batch by tile/block merge,
segments jit the same program); ``fused_wire=False`` restores the PR-4
eager wire path end to end — the benchmark baseline.

Statistics (frames, drops, bytes, bursts, batches, redispatches, per-sink
pts) feed the Fig. 7 benchmark.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax

from ..core.admission import (DEFAULT_TENANT, QoSConfig, merge_tenant_stats,
                              percentile_from_hist)
from ..core.batching import (BatchingPolicy, QueryBatcher,
                             StagedStreamingBatcher, StageQueryBatcher,
                             StreamingQueryBatcher, DEFAULT_QUERY_BATCH)
from ..core.broker import Broker, BrokerError
from ..core.buffers import (StreamBuffer, stack_buffers, structure_key,
                            unstack_buffers)
from ..core.element import Element
from ..core import netfault
from ..core.pipeline import Pipeline
from ..core.plan import PendingQuery
from ..core.pubsub import Channel, MqttSink, MqttSrc
from ..core.query import (QueryServerEndpoint, TensorQueryClient,
                          TensorQueryServerSrc)
from ..core.sync import PipelineClock, SimClock

TICK_NS = 16_666_667  # 60 Hz
DEFAULT_BURST = 8


@dataclass
class _PipeRun:
    pipe: Pipeline
    params: dict
    state: dict
    step_fn: Callable
    frames: int = 0
    skipped: int = 0
    bursts: int = 0              # multi-frame drains executed
    burst_frames: int = 0        # frames delivered via bursts
    last_outputs: Dict[str, StreamBuffer] = field(default_factory=dict)
    sink_log: Dict[str, list] = field(default_factory=dict)
    #: mesh-replicated copy of ``params``, placed lazily at first sharded
    #: burst (re-broadcasting params per dispatch costs more than the serve)
    mesh_params: Optional[dict] = None
    #: whether the run steps through the cached compiled plan (Device.add_
    #: pipeline's ``jit`` flag, retained so a hot swap rebuilds ``step_fn``
    #: in the same execution mode)
    jit: bool = True
    #: decommissioned by a reconfiguration that removed every element — the
    #: scheduler skips it without counting skips (there is nothing to run)
    retired: bool = False
    #: drops inherited from elements a reconfiguration REMOVED: their queued
    #: backlogs and leaky-drop histories leave the topology with them, and
    #: the conservation accounting must not forget those frames
    carried_drops: int = 0

    @property
    def host_srcs(self) -> List[MqttSrc]:
        return self.pipe.plan.host_sources

    @property
    def host_sinks(self) -> List[MqttSink]:
        return self.pipe.plan.host_sinks


class Device:
    def __init__(self, name: str, clock: Optional[SimClock] = None):
        self.name = name
        self.clock = clock or SimClock()
        self.pipeline_clock = PipelineClock(self.clock)
        self.runs: List[_PipeRun] = []
        #: liveness flag the chaos harness flips: a dead device's pipelines
        #: stop running and the runtime stops heartbeating its registrations
        #: (so leases expire and the broker announces the death)
        self.alive = True

    def add_pipeline(self, pipe: Pipeline, rng=None, jit: bool = True) -> _PipeRun:
        pipe.realize()
        # wire pipeline clock into pub/sub elements for §4.2.3 sync
        for e in pipe.elements.values():
            if isinstance(e, (MqttSink, MqttSrc)) and e.sync_clock is None:
                e.sync_clock = self.pipeline_clock
        params = pipe.init(rng if rng is not None else jax.random.PRNGKey(0))
        state = pipe.init_state()
        # pure pipelines step through the cached compiled plan; host-impure
        # ones run the plan interpreted (their apply does channel I/O)
        fn = pipe.compiled_step() if (jit and pipe.plan.pure) else pipe.step
        run = _PipeRun(pipe=pipe, params=params, state=state, step_fn=fn,
                       jit=jit)
        self.runs.append(run)
        return run


class Runtime:
    def __init__(self, broker: Optional[Broker] = None, tick_ns: int = TICK_NS,
                 burst: int = DEFAULT_BURST,
                 query_batch=DEFAULT_QUERY_BATCH,
                 lease_ticks: Optional[int] = None,
                 mesh=None, shard_mode: str = "auto",
                 fused_wire: bool = True,
                 park_deadline_ticks: Optional[int] = None,
                 qos: Optional[QoSConfig] = None,
                 delivery: Optional["netfault.DeliveryPolicy"] = None):
        self.broker = broker or Broker()
        if lease_ticks is not None:
            self.broker.default_lease_ticks = lease_ticks
        self.devices: List[Device] = []
        self.tick_ns = tick_ns
        self.burst = max(1, int(burst))
        #: jax Mesh for among-device serving (DESIGN.md §4): batched query
        #: serves and hoisted bursts lay their frame axis out along the
        #: mesh's data axes when shardable; ``mesh="auto"``/``True`` builds a
        #: host mesh over the local devices.  None = single-device serving.
        if mesh in ("auto", True):
            from ..launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.mesh = mesh
        #: "auto" probes sharded-vs-single per batch size and keeps the
        #: faster (core/batching.py docstring); "always"/"never" force it.
        #: Validated HERE, not only in QueryBatcher: a pub/sub-only
        #: deployment never builds a batcher, and the burst path's string
        #: compare would silently turn a typo into "never".
        if shard_mode not in ("auto", "always", "never"):
            raise ValueError(f"shard_mode {shard_mode!r} not in "
                             f"('auto', 'always', 'never')")
        self.shard_mode = shard_mode
        #: fused batched wire path (module docstring; DESIGN.md §5) —
        #: False restores the PR-4 eager codec path end to end
        self.fused_wire = bool(fused_wire)
        #: tenant-aware admission policy (DESIGN.md §9): None keeps every
        #: batcher's AdmissionQueue in exact global-FIFO pass-through —
        #: the pre-QoS fabric, bit for bit
        self.qos = qos
        #: at-least-once delivery layer (DESIGN.md §10): None keeps the
        #: reliable-transport fabric bit for bit — no delivery ids, no
        #: checksums, no retransmits.  Set, every query/hop/answer frame
        #: carries a (sender, seq) id + CRC, receivers dedup and reject
        #: corruption, and unanswered requests retransmit on the backoff
        #: clock below.
        self.delivery = delivery
        #: FaultFabric (core/netfault.py) a chaos scenario installed —
        #: stepped at the top of every tick so delayed/reordered frames
        #: release on the scheduler's clock.  None outside chaos runs.
        self.fabric = None
        #: devices whose CONTROL plane is partitioned (heartbeats lost in
        #: the network, data plane per the installed fault links) — the §10
        #: suspicion scenario: their leases expire; their beats resume and
        #: heal the suspicion when removed from this set
        self._control_blocked: set = set()
        #: §10 retransmit ledger (client-side timeouts that re-shipped)
        self.retransmits = 0
        #: elastic-serving controllers (runtime/autoscale.py) — stepped at
        #: every tick boundary right after pending reconfigs; an Autoscaler
        #: registers itself here
        self.autoscalers: List = []
        #: transient per-tick dispatch load (QoS join-shortest-queue): a
        #: round's requests spread over replicas whose heartbeat load has
        #: not seen this tick's dispatches yet; cleared every tick
        self._load_bumps: Dict[int, int] = {}
        #: tenant sheds the RUNTIME owns (park/deadline expiries — frames
        #: that never reached a server's admission queue), same schema as
        #: AdmissionQueue.stats() entries so the ledgers merge
        self._tenant_shed: Dict[str, Dict] = {}
        #: per-tenant ledgers of batchers a reconfiguration retired —
        #: conservation must survive replica scale-down
        self._tenant_archive: Dict[str, Dict] = {}
        #: query micro-batching policy (int = max batch; 0 disables —
        #: legacy synchronous round-trips inside the client's apply)
        self.batching = BatchingPolicy.of(query_batch)
        #: endpoint_id -> QueryBatcher for every runtime-wired serversrc
        self._batchers: Dict[int, QueryBatcher] = {}
        #: frames paused at a query client with NO live server to take the
        #: request — retried at the top of every tick until one registers,
        #: each entry ``(run, pq, parked_at_tick)``; the park tick survives
        #: re-parks so ``park_deadline_ticks`` measures TOTAL time parked
        self._parked: List[Tuple[_PipeRun, PendingQuery, int]] = []
        #: frames whose request is a STREAM mid-generation on a live server
        #: (StreamingQueryBatcher.in_flight) — unlike parked frames they
        #: have a server, it just needs more decode ticks; re-enter the
        #: drain at the top of every tick until the stream finishes
        self._inflight: List[Tuple[_PipeRun, PendingQuery]] = []
        #: ticks a frame may stay parked before it expires into an accounted
        #: client-visible error (None = park forever, the pre-PR-6 behavior)
        self.park_deadline_ticks = park_deadline_ticks
        # failover accounting (DESIGN.md §3)
        self.redispatches = 0
        self.parked_total = 0
        self.parked_expired = 0
        self.orphaned_requests = 0
        self.ticks = 0
        # every topology change — planned hot swaps AND broker liveness
        # events (a server death/revival is an unplanned edit) — routes
        # through the reconfiguration manager (DESIGN.md §6)
        from ..core.reconfig import ReconfigManager
        self.reconfig = ReconfigManager(self)
        self.broker.watch(self.reconfig.on_broker_event)

    def add_device(self, device: Device) -> Device:
        self.devices.append(device)
        # connect broker-facing elements & calibrate NTP against the broker's
        # reference clock (a fresh zero-skew SimClock)
        if not hasattr(self, "_ntp_ref"):
            self._ntp_ref = SimClock()
        for run in device.runs:
            self._wire(device, run)
        device.pipeline_clock.calibrate(self._ntp_ref)
        device.pipeline_clock.start()
        return device

    def _wire(self, device: Device, run: _PipeRun):
        for e in run.pipe.elements.values():
            if isinstance(e, (MqttSink, MqttSrc, TensorQueryClient)) and e.broker is None:
                e.connect(self.broker)
            if isinstance(e, TensorQueryClient) and self.delivery is not None:
                e.delivery = self.delivery
            if isinstance(e, TensorQueryServerSrc) and e.registration is None:
                # the endpoint's inline_runner is the batcher's flush: edge
                # clients and direct pipe.step round-trips keep their
                # serve-before-return contract, while runtime-driven clients
                # go through the deferred queue-gather-flush path
                stream = any(getattr(el, "is_stream_serve", False)
                             for el in run.pipe.elements.values())
                staged = [el for el in run.pipe.elements.values()
                          if getattr(el, "is_stage_serve", False)]
                if staged and staged[0].stage > 0:
                    # downstream hop of an among-device pipeline-parallel
                    # chain (DESIGN.md §8): serves prefill/replay/decode-hop
                    # verbs against its layer slice, parking b=1 caches by
                    # stream id — no admission lifecycle of its own
                    batcher = StageQueryBatcher(
                        e.endpoint, run, self.batching,
                        inline_step=lambda r=run: self._run_once(r),
                        mesh=self.mesh, shard_mode=self.shard_mode,
                        fused=self.fused_wire,
                        on_orphans=self._count_orphans,
                        # hop traffic is NEVER re-scheduled (each hop is one
                        # step of a stream the stage-0 coordinator already
                        # admitted under its tenant's budget) — qos stays off
                        qos=None, clock=lambda: self.ticks)
                elif staged:
                    # stage-0 coordinator: owns the admission lifecycle AND
                    # drives the per-tick hop chain to downstream stages it
                    # discovers through the broker
                    batcher = StagedStreamingBatcher(
                        e.endpoint, run, self.batching,
                        inline_step=lambda r=run: self._run_once(r),
                        mesh=self.mesh, shard_mode=self.shard_mode,
                        fused=self.fused_wire,
                        on_orphans=self._count_orphans,
                        tick_source=lambda: self.ticks,
                        broker=self.broker,
                        qos=self.qos, clock=lambda: self.ticks)
                elif stream:
                    # streaming serve pipeline (model_serve): requests live
                    # across ticks in plan-state slots, so the endpoint gets
                    # the continuous-batching lifecycle instead of the
                    # stateless gather-stack-flush
                    batcher = StreamingQueryBatcher(
                        e.endpoint, run, self.batching,
                        inline_step=lambda r=run: self._run_once(r),
                        mesh=self.mesh, shard_mode=self.shard_mode,
                        fused=self.fused_wire,
                        on_orphans=self._count_orphans,
                        tick_source=lambda: self.ticks,
                        qos=self.qos, clock=lambda: self.ticks)
                else:
                    batcher = QueryBatcher(
                        e.endpoint, run, self.batching,
                        inline_step=lambda r=run: self._run_once(r),
                        mesh=self.mesh, shard_mode=self.shard_mode,
                        fused=self.fused_wire,
                        on_orphans=self._count_orphans,
                        qos=self.qos, clock=lambda: self.ticks)
                if self.delivery is not None:
                    # one guard per endpoint, shared by the batcher (request
                    # triage) and its paired serversink (answer CRC + replay
                    # cache) — §10's receiver half
                    guard = netfault.DeliveryGuard(self.delivery)
                    batcher.guard = guard
                    if isinstance(batcher, StagedStreamingBatcher):
                        batcher.delivery = self.delivery
                    for el in run.pipe.elements.values():
                        if getattr(el, "is_query_sink", False) and \
                                getattr(el, "serversrc", None) is e:
                            el.guard = guard
                self._batchers[e.endpoint.endpoint_id] = batcher
                e.connect(self.broker, inline_runner=batcher.flush)
        # (re)negotiate with broker wiring in place so mqttsink registers;
        # the rebuilt plan keeps its fingerprint, so compiled executables
        # from before the re-wire are reused, not retraced
        run.pipe._realized = False
        run.pipe.realize()

    # -- live reconfiguration (DESIGN.md §6) --------------------------------------
    def reconfigure(self, run: _PipeRun, edit, warm_ticks: int = 1,
                    rng=None):
        """Apply a topology edit to a RUNNING pipeline with prepare → warm →
        commit → drain semantics.  ``edit`` is a
        :class:`~repro.core.reconfig.ReconfigPlan` (``run.pipe.reconfig()``)
        or a callable receiving a fresh one; the edit prepares and warms
        immediately (off the serving path) and commits at the first tick
        boundary after ``warm_ticks`` ticks — or rolls back with explicit
        stats if the prepare fails or the target device dies mid-warm.
        Returns the :class:`~repro.core.reconfig.Reconfiguration` handle
        (``status``, ``frames_carried``, ``committed_tick``)."""
        from ..core.reconfig import ReconfigPlan
        plan = edit
        if not isinstance(edit, ReconfigPlan):
            plan = ReconfigPlan(run.pipe)
            edit(plan)
        return self.reconfig.request(run, plan, warm_ticks=warm_ticks,
                                     rng=rng)

    def _device_of(self, run: _PipeRun) -> Optional[Device]:
        for dev in self.devices:
            if run in dev.runs:
                return dev
        return None

    def _run_in_flight(self, run: _PipeRun) -> bool:
        """Whether the run has a frame paused mid-schedule across ticks (a
        parked PendingQuery, or a stream mid-generation) — a commit must
        drain those on the old epoch before cutting over, never swap a plan
        out from under a live walk.  Note this guards the CLIENT pipeline's
        run only: the server run itself carries no paused walk, so a server
        hot-swap commits mid-decode (the stateful-plan contract pins it)."""
        return any(r is run for r, _, _ in self._parked) or \
            any(r is run for r, _ in self._inflight)

    def _count_orphans(self, n: int):
        """Orphan-ledger hook for mid-flush deaths (QueryBatcher)."""
        self.orphaned_requests += n

    def _retire_element(self, e: Element):
        """Take an element a committed reconfiguration removed out of the
        control plane: unregister standing registrations (fires
        ``unregister`` — clients re-bind via the exactly-once win-back, and
        a query endpoint tears down through the manager's event path), close
        consumer bindings, and drop the endpoint's batcher."""
        reg = getattr(e, "registration", None)
        if reg is not None:
            self.broker.unregister(reg)
            e.registration = None
        binding = getattr(e, "binding", None)
        if binding is not None:
            binding.close()
            e.binding = None
        ep = getattr(e, "endpoint", None)
        if isinstance(ep, QueryServerEndpoint):
            b = self._batchers.pop(ep.endpoint_id, None)
            if b is not None:
                # fold the retired batcher's per-tenant ledgers into the
                # archive — scale-down must not forget served/shed history
                # or the conservation law breaks at the next stats() call
                merge_tenant_stats(self._tenant_archive, b.tenant_stats())

    # -- liveness: heartbeats, leases -----------------------------------------
    def _heartbeat_and_lease(self):
        """Beat on behalf of every live device's registrations, refresh load
        declarations from the serving queues, then advance the broker's
        lease clock (expiring whoever went silent)."""
        for dev in self.devices:
            if not dev.alive:
                continue
            if dev in self._control_blocked:
                # control partition (§10): the device is up and serving but
                # its heartbeats are lost in the network — the broker sees
                # silence, the lease lapses, and the expiry lands as
                # SUSPICION rather than declared death
                continue
            for run in dev.runs:
                for e in run.pipe.elements.values():
                    reg = getattr(e, "registration", None)
                    if reg is None:
                        continue
                    if not reg.alive and reg.suspected:
                        # the suspected device is beating again: the expiry
                        # was delay/partition, not death.  Win-back is the
                        # ordinary revive "register" event; requests already
                        # re-dispatched stay wherever dedup settles them.
                        self.broker.heal(reg)
                    self.broker.heartbeat(reg)
                    if isinstance(e, TensorQueryServerSrc):
                        # "server workload status": instantaneous backlog —
                        # channel depth plus whatever admission already
                        # ingested; under QoS the load signal also counts
                        # active decode slots (streams occupying capacity
                        # across ticks), which the autoscaler and the JSQ
                        # dispatch read.  Pre-QoS deployments keep the
                        # channel-only signal bit for bit (binding choices
                        # in the failover pins depend on it).
                        load = float(len(e.endpoint.requests))
                        b = self._batchers.get(e.endpoint.endpoint_id)
                        if b is not None:
                            load += float(len(b.admission))
                            if self.qos is not None and \
                                    hasattr(b, "active_streams"):
                                load += float(b.active_streams())
                        reg.load = load
        self.broker.tick()

    # -- readiness ---------------------------------------------------------------
    def _ready(self, run: _PipeRun) -> bool:
        for e in run.pipe.elements.values():
            if isinstance(e, MqttSrc):
                if e.queued() == 0:
                    return False
            if isinstance(e, TensorQueryServerSrc):
                if len(e.endpoint.requests) == 0:
                    return False
        return True

    def _finish_frame(self, run: _PipeRun, outputs: Dict[str, StreamBuffer]):
        run.frames += 1
        run.last_outputs = outputs
        for name, buf in outputs.items():
            run.sink_log.setdefault(name, []).append(buf)
        return outputs

    def _run_once(self, run: _PipeRun):
        # host-level elements (mqttsrc pull / query send) are impure, so
        # pipelines containing them run the plan interpreted; pure pipelines
        # step through the cached compiled executable.
        outputs, run.state = run.step_fn(run.params, run.state)
        return self._finish_frame(run, outputs)

    # -- deferred query clients (micro-batched offloading + failover) ------------
    def _begin_deferred(self, run: _PipeRun
                        ) -> Optional[Tuple[_PipeRun, PendingQuery]]:
        """Begin a frame for a pipeline containing query clients: the plan
        pauses at the first client.  On the fused wire path, plans whose
        only impure elements are query clients run the walk as ONE jitted
        segment (plan.run_deferred_compiled) — bitwise the interpreted
        deferral without its per-element dispatch cost.  Returns the paused
        frame (NOT yet dispatched — the tick batches a whole round's
        request encodes), or None if the frame completed without pausing."""
        plan = run.pipe.plan
        if self.fused_wire and plan.deferred_compilable:
            res = plan.run_deferred_compiled(run.params, run.state)
        else:
            res = plan.run_deferred(run.params, run.state)
        if isinstance(res, PendingQuery):
            return run, res
        outputs, run.state = res
        self._finish_frame(run, outputs)
        return None

    @staticmethod
    def _codec_round(pairs, batch_fn) -> List:
        """Shared shape of a batched codec round: group ``(client, buffer)``
        pairs by (codec, TENSORS structure), run ``batch_fn(buffers,
        codec)`` once per group, scatter results back in input order.  The
        key covers the tensors only: the codec batch helpers stack payloads
        and keep each frame's own meta, so differing meta (client ids, pts
        tags) must not split a batchable group."""
        res: List = [None] * len(pairs)
        groups: Dict[Tuple, List[int]] = {}
        for i, (qc, buf) in enumerate(pairs):
            key = (qc.codec, structure_key(buf.tensors))
            groups.setdefault(key, []).append(i)
        for (codec, _), idxs in groups.items():
            for i, out in zip(idxs, batch_fn([pairs[i][1] for i in idxs],
                                             codec)):
                res[i] = out
        return res

    def _encode_requests(self, pairs) -> List[Tuple]:
        """Encode a dispatch round's requests: one batched codec dispatch
        per (codec, structure) group instead of one per request, results
        returned in input order.  Bitwise per-request ``encode`` (payload,
        meta, wire bytes — core/compression.py batch contract)."""
        from ..core import compression as comp
        return self._codec_round(pairs, comp.encode_batch)

    def _dispatch_round(self, fresh: List[Tuple[_PipeRun, PendingQuery]]
                        ) -> List[Tuple[_PipeRun, PendingQuery]]:
        """Ship a round of freshly paused frames.  Fused wire path: resolve
        every endpoint first (unplaceable frames park before any encode is
        paid), batch-encode the requests per codec group, then push in
        arrival order — server channels stay FIFO, so batching the encodes
        never reorders what the scan serves.  Early flushes still fire the
        moment an endpoint's gather fills.  Legacy path: per-frame
        ``_dispatch_query`` exactly as before."""
        if not fresh:
            return []
        out: List[Tuple[_PipeRun, PendingQuery]] = []
        if not self.fused_wire:
            for run, pq in fresh:
                if self._dispatch_query(pq):
                    out.append((run, pq))
                else:
                    self._park(run, pq)
            return out
        ready = []
        for run, pq in fresh:
            qc = pq.client
            try:
                ep = self._select_endpoint(qc)
            except BrokerError:
                # keep pq.endpoint (the dead server) — a later successful
                # dispatch of this parked frame is still a failover hop
                self._park(run, pq)
                continue
            ready.append((run, pq, qc, ep))
        encs = self._encode_requests([(qc, pq.request)
                                      for _, pq, qc, _ in ready])
        for (run, pq, qc, ep), (enc, nbytes) in zip(ready, encs):
            if self.delivery is not None:
                if pq.dseq is None:
                    pq.dseq = qc.next_dseq()
                pq.next_retry = self.ticks + \
                    self.delivery.retry_in(pq.retries)
            qc.send_query_wire(enc, nbytes, ep, dseq=pq.dseq)
            if pq.endpoint is not None and pq.endpoint is not ep:
                self.redispatches += 1
                pq.redispatches += 1
            pq.endpoint = ep
            batcher = self._batchers.get(ep.endpoint_id)
            if batcher is None:
                runner = ep.spec.get("inline_runner")
                if runner is not None:
                    runner()
            elif batcher.full():
                batcher.flush()
            out.append((run, pq))
        return out

    def _dispatch_query(self, pq: PendingQuery) -> bool:
        """Ship a paused frame's request to the best-ranked live endpoint
        (encode + client_id tag + push), recording on the PendingQuery where
        the request actually went — if that server dies before answering,
        the drain loop re-dispatches from this record.  Flushes early when
        the endpoint's batch fills.  Endpoints the runtime does not manage
        (manually wired servers) serve inline immediately.  Returns False
        when no live server matches (the caller parks the frame)."""
        qc = pq.client
        try:
            ep = self._select_endpoint(qc)
        except BrokerError:
            # keep pq.endpoint (the dead server) — a later successful
            # dispatch of this parked frame is still a failover hop and
            # must count in `redispatches`
            return False
        if self.delivery is not None:
            # the delivery id is minted ONCE per logical request: parks,
            # failover re-dispatches, and timeout retransmits all reuse it,
            # so receiver dedup makes every duplicate path harmless (§10)
            if pq.dseq is None:
                pq.dseq = qc.next_dseq()
            pq.next_retry = self.ticks + self.delivery.retry_in(pq.retries)
        qc.send_query(pq.request, ep=ep, dseq=pq.dseq)
        if pq.endpoint is not None and pq.endpoint is not ep:
            self.redispatches += 1
            pq.redispatches += 1
        pq.endpoint = ep
        batcher = self._batchers.get(ep.endpoint_id)
        if batcher is None:
            runner = ep.spec.get("inline_runner")
            if runner is not None:
                runner()
        elif batcher.full():
            batcher.flush()
        return True

    def _select_endpoint(self, qc) -> QueryServerEndpoint:
        """Endpoint for one dispatch.  Pre-QoS this is exactly the sticky
        binding (``qc._endpoint()`` — the failover pins depend on its
        exactly-once win-back semantics).  Under QoS with multiple live
        replicas it becomes join-shortest-queue: requests spread over the
        candidates by heartbeat load PLUS this tick's own dispatches
        (``_load_bumps`` — heartbeat load lags by a tick, and without the
        bump every frame of a round would pile onto the same replica).
        Hard preferences (stage, tenant affinity, codec) still dominate;
        the binding itself is untouched, so win-back behavior and the
        recorded failover semantics are identical."""
        ep = qc._endpoint()
        if self.qos is None or qc.binding is None:
            return ep
        cands = [r for r in qc.binding._candidates()
                 if getattr(r.endpoint, "alive", True)]
        if len(cands) <= 1:
            return ep
        prefer = qc.binding.prefer

        def key(r):
            hard = self.broker.rank_key(r, prefer)[:3]
            return (hard, r.load + self._load_bumps.get(r.reg_id, 0),
                    r.reg_id)
        best = min(cands, key=key)
        self._load_bumps[best.reg_id] = \
            self._load_bumps.get(best.reg_id, 0) + 1
        return best.endpoint

    def _park(self, run: _PipeRun, pq: PendingQuery,
              t0: Optional[int] = None):
        """``t0`` is the tick the frame FIRST parked — re-parks preserve it
        so the park deadline measures total time stranded, not time since
        the latest failed retry."""
        self.parked_total += 1
        self._parked.append((run, pq, self.ticks if t0 is None else t0))

    def _retry_parked(self) -> List[Tuple[_PipeRun, PendingQuery]]:
        """Give every parked frame another shot at dispatch (a server may
        have registered or revived since last tick); still-unplaceable
        frames stay parked."""
        parked, self._parked = self._parked, []
        pending = []
        for run, pq, t0 in parked:
            if self._dispatch_query(pq):
                pending.append((run, pq))
            else:
                self._park(run, pq, t0)
        return pending

    def _park_limit(self, qc) -> Optional[int]:
        """Ticks a frame of this client may stay parked: the tighter of the
        runtime-wide ``park_deadline_ticks`` and the client tenant's own
        ``deadline_ticks`` (DESIGN.md §9 — the deadline clock keeps running
        while a request is parked: parked time IS queue time, the tenant
        just never reached a server's queue)."""
        limits = [self.park_deadline_ticks]
        if self.qos is not None:
            tenant = getattr(qc, "tenant", None) or DEFAULT_TENANT
            limits.append(self.qos.spec(tenant).deadline_ticks)
        limits = [m for m in limits if m is not None]
        return min(limits) if limits else None

    def _expire_parked(self):
        """Park deadline (DESIGN.md §6 satellite, §9 tenant interaction): a
        frame parked past its limit stops burning a busy-skip per tick and
        degrades EXPLICITLY — counted in ``parked_expired`` AND on its
        tenant's shed ledger, and answered with a client-visible error
        buffer in the pipeline's sink log; the pipeline is freed to start
        fresh frames next tick."""
        if not self._parked:
            return
        keep = []
        for run, pq, t0 in self._parked:
            limit = self._park_limit(pq.client)
            if limit is not None and self.ticks - t0 >= limit:
                self.parked_expired += 1
                self._account_tenant_shed(pq.client, "deadline",
                                          self.ticks - t0)
                self._expire_query(run, pq, parked_ticks=limit)
            else:
                keep.append((run, pq, t0))
        self._parked = keep

    def _account_tenant_shed(self, qc, reason: str, waited: int = 0):
        """Book a runtime-owned shed (park/deadline expiry — the request
        never reached a server's admission queue) on the tenant's ledger in
        the AdmissionQueue.stats() schema: one admission, one shed, so the
        merged conservation law stays exact."""
        tenant = getattr(qc, "tenant", None) or DEFAULT_TENANT
        led = self._tenant_shed.setdefault(tenant, {
            "admitted": 0, "served": 0, "shed": 0, "queued": 0,
            "in_flight": 0, "shed_reasons": {}, "latency_hist": {}})
        if self.qos is not None:
            led["priority"] = self.qos.spec(tenant).priority
        led["admitted"] += 1
        led["shed"] += 1
        led["shed_reasons"][reason] = led["shed_reasons"].get(reason, 0) + 1

    def _expire_query(self, run: _PipeRun, pq: PendingQuery,
                      parked_ticks: Optional[int] = None):
        """Answer an expired park with an error frame: empty tensors, meta
        naming the operation that never found a server — logged under
        ``<client>.error`` so clients distinguish degradation from silence.
        The frame itself is abandoned (its walk never resumes)."""
        qc = pq.client
        err = StreamBuffer(tensors=(), meta={
            "error": "park-deadline",
            "operation": qc.operation,
            "parked_ticks": (parked_ticks if parked_ticks is not None
                             else self.park_deadline_ticks),
            "redispatches": pq.redispatches,
            "tick": self.ticks})
        run.sink_log.setdefault(f"{qc.name}.error", []).append(err)

    def _shed_query(self, run: _PipeRun, pq: PendingQuery, reason: str):
        """Answer an admission-shed request with an explicit client-visible
        error (zero silent drops — the §9 contract): the server's admission
        layer refused the request (rate budget, queue cap, or deadline
        expiry) and already booked the shed on the tenant ledger; here the
        paused frame learns WHY and is freed."""
        qc = pq.client
        err = StreamBuffer(tensors=(), meta={
            "error": "shed", "reason": reason,
            "operation": qc.operation,
            "tenant": getattr(qc, "tenant", None) or DEFAULT_TENANT,
            "tick": self.ticks})
        run.sink_log.setdefault(f"{qc.name}.error", []).append(err)

    def _drain_queries(self, pending: List[Tuple[_PipeRun, PendingQuery]]):
        """Tick-deadline flush: serve every gathered request, resume the
        paused frames with their answers, and repeat for pipelines that
        pause again at a later query client.

        In-flight failover lives here: a frame whose recorded endpoint died
        before answering re-dispatches its retained request buffer to the
        next-ranked survivor (served on the next flush round) or parks until
        a server registers.  A missing answer from a LIVE endpoint is still
        a hard error — that is a serving bug, not a device death.

        Termination: every round each frame is answered, parked, raised on,
        or re-dispatched to a live endpoint different from its dead one —
        and a chain of re-dispatches is bounded by the number of live
        servers (nothing revives mid-drain; revivals are tick events).

        Fused wire path: the round's answers are popped raw and decoded in
        one batched codec dispatch per (codec, structure) group before the
        resumes — bitwise the per-frame decode, minus ``batch × tensors``
        eager dispatches."""
        pending = list(pending)
        while pending:
            for batcher in self._batchers.values():
                batcher.flush()
            nxt: List[Tuple[_PipeRun, PendingQuery]] = []
            answered: List[Tuple[_PipeRun, PendingQuery, StreamBuffer]] = []
            for run, pq in pending:
                qc = pq.client
                ep = pq.endpoint
                raw = qc.recv_answer_raw(ep, want=pq.dseq) \
                    if ep is not None else None
                if raw is None:
                    if ep is not None and ep.alive:
                        b = self._batchers.get(ep.endpoint_id)
                        if b is not None:
                            reason = b.admission.pop_notice(qc.client_id)
                            if reason is not None:
                                # admission refused the request (rate /
                                # queue-full / deadline): the shed is on the
                                # tenant ledger, the client gets an explicit
                                # error — never a silent drop, never a
                                # failover (the server is fine)
                                self._shed_query(run, pq, reason)
                                continue
                            if b.in_flight(qc.client_id):
                                # streaming serve mid-generation, or a QoS
                                # serve budget holding the request queued —
                                # not an error, it needs more ticks.  Leave
                                # the drain (bounding this round) and
                                # re-enter next tick.
                                self._inflight.append((run, pq))
                                continue
                        if self.delivery is not None and \
                                pq.dseq is not None:
                            # lossy transport (§10): a missing answer from a
                            # LIVE server means the request or its answer
                            # is lost/delayed in the network — retransmit on
                            # the backoff clock (same delivery id: the
                            # server dedups and replays a committed answer
                            # bitwise), or wait out the current timeout
                            if self.ticks >= pq.next_retry:
                                pq.retries += 1
                                self.retransmits += 1
                                if self._dispatch_query(pq):
                                    nxt.append((run, pq))
                                else:
                                    self._park(run, pq)
                            else:
                                self._inflight.append((run, pq))
                            continue
                        raise BrokerError(
                            f"{qc.name}: no answer from {qc.operation!r}")
                    if self._dispatch_query(pq):
                        nxt.append((run, pq))
                    else:
                        self._park(run, pq)
                    continue
                answered.append((run, pq, raw))
            answers = self._decode_answers(
                [(pq.client, raw) for _, pq, raw in answered])
            for (run, pq, _), answer in zip(answered, answers):
                res = pq.resume(answer)
                if isinstance(res, PendingQuery):
                    if self._dispatch_query(res):
                        nxt.append((run, res))
                    else:
                        self._park(run, res)
                else:
                    outputs, run.state = res
                    self._finish_frame(run, outputs)
            pending = nxt

    def _decode_answers(self, pairs) -> List[StreamBuffer]:
        """Decode a drain round's raw answers, batched per (codec,
        structure) group on the fused path, per frame on the legacy one."""
        from ..core import compression as comp
        if not self.fused_wire:
            return [comp.decode(raw, qc.codec) for qc, raw in pairs]
        return self._codec_round(pairs, comp.decode_batch)

    # -- burst draining ----------------------------------------------------------
    def _burst_size(self, run: _PipeRun) -> int:
        """Frames to drain this tick: bounded by the runtime burst cap and by
        the shortest queue across the pipeline's subscriber channels."""
        plan = run.pipe.plan
        if self.burst <= 1 or not plan.burstable:
            return 1
        if not plan.all_sources_host_driven:
            # a self-driven source (live camera) mixed in would be
            # fast-forwarded by a burst — stay on the tick cadence
            return 1
        return max(1, min([self.burst] +
                          [s.queued() for s in run.host_srcs]))

    def _deliver_frame(self, run: _PipeRun, frame_outs: Dict[str, StreamBuffer]):
        """Route one frame's outputs: captured host-sink frames replay
        through the element's real apply (encode + channel push + broker
        accounting); app-sink frames land in the log.  Matches _run_once's
        bookkeeping (last_outputs replaced per frame, frames counted)."""
        app_outs = {}
        for name, buf in frame_outs.items():
            elem = run.pipe.elements[name]
            if isinstance(elem, MqttSink):
                elem.apply(run.params.get(name, {}), [buf])
            else:
                app_outs[name] = buf
                run.sink_log.setdefault(name, []).append(buf)
        run.last_outputs = app_outs
        run.frames += 1

    def _run_burst(self, run: _PipeRun, n: int):
        """Drain ``n`` queued frames with one scan-batched dispatch."""
        pulls = {s.name: s.pull_burst(n) for s in run.host_srcs}
        if any(len(v) != n for v in pulls.values()):
            # a channel raced us below n; replay what we got per-frame
            return self._replay_frames(run, pulls)
        try:
            stacked = {k: stack_buffers(v) for k, v in pulls.items()}
        except ValueError:
            # heterogeneous frame structure (e.g. mixed meta after failover):
            # burst stacking needs one treedef — fall back to per-frame
            return self._replay_frames(run, pulls)
        # pub/sub bursts shard only in forced mode: they run off the serving
        # hot path (catch-up drains), so they follow the explicit placement
        # rather than paying their own calibration probes
        mesh = self.mesh if self.shard_mode == "always" else None
        sharded = mesh is not None and \
            run.pipe.plan.shardable_batch(n, run.state, mesh)
        params = run.params
        if sharded:
            if run.mesh_params is None:
                from ..launch.shardings import replicated
                run.mesh_params = jax.device_put(
                    run.params, replicated(mesh, run.params))
            params = run.mesh_params
        step_n = run.pipe.compiled_step_n(hoist_io=True, mesh=mesh)
        outs, run.state = step_n(params, run.state, stacked)
        if sharded:
            # mesh-sharded burst: fetch the stacked outputs in one gather —
            # eager per-frame slicing of SPMD-sharded arrays would pay a
            # cross-device transfer per leaf per frame
            outs = jax.device_get(outs)
        for frame_outs in unstack_buffers(outs, n):
            self._deliver_frame(run, frame_outs)
        run.bursts += 1
        run.burst_frames += n

    def _replay_frames(self, run: _PipeRun, pulls: Dict[str, list]):
        """Per-frame fallback for frames already pulled off the channels.
        The DAG needs every source injected each frame, so only the shortest
        pull count can run; surplus frames are returned to the front of
        their queues (not dropped) for the next tick."""
        n = min(len(v) for v in pulls.values()) if pulls else 0
        for name, frames in pulls.items():
            if len(frames) > n:
                run.pipe.elements[name].unread(frames[n:])
        for i in range(n):
            inputs = {k: v[i] for k, v in pulls.items()}
            outputs, run.state = run.pipe.plan.run(
                run.params, run.state, inputs, hoist_io=True)
            self._deliver_frame(run, outputs)

    def tick(self):
        self.ticks += 1
        if self.fabric is not None:
            # advance the fault clock first: frames the network held
            # (delay/reorder) from earlier ticks land before anything runs,
            # and this tick's scripted partitions take effect
            self.fabric.step(self.ticks)
        self._ntp_ref.advance(self.tick_ns)
        for dev in self.devices:
            dev.clock.advance(self.tick_ns)
        self._heartbeat_and_lease()
        # tick boundary: pending reconfigurations commit (or drain/roll
        # back) BEFORE any frame of this tick starts — a swap never lands
        # under a frame mid-walk
        self.reconfig.step()
        # elastic serving (DESIGN.md §9): autoscalers read the broker's
        # scaling signal AFTER pending reconfigs settled and request their
        # own add/remove reconfigs — which commit through the same §6
        # lifecycle on later ticks (autoscaling is a reconfig, not a new
        # mechanism)
        for scaler in list(self.autoscalers):
            scaler.step()
        self._load_bumps.clear()
        self._expire_parked()
        # frames parked from earlier ticks go first (a server may be back);
        # their pipelines must not start a second concurrent frame
        pending = self._retry_parked()
        # streams mid-generation re-enter the drain: a live server keeps
        # decoding them (one tick = one token per active stream); a dead one
        # routes them through the same dispatch-or-park failover as any
        # in-flight query (prefill replay on the survivor)
        inflight, self._inflight = self._inflight, []
        pending.extend(inflight)
        busy = {id(run) for run, _ in pending} | \
               {id(run) for run, _, _ in self._parked}
        fresh: List[Tuple[_PipeRun, PendingQuery]] = []
        for dev in self.devices:
            if not dev.alive:
                continue  # a dead device runs nothing (chaos harness)
            for run in dev.runs:
                if run.retired:
                    continue  # decommissioned by a reconfiguration
                if any(isinstance(e, TensorQueryServerSrc)
                       for e in run.pipe.elements.values()):
                    continue  # servers run batched/inline, driven by clients
                if id(run) in busy:
                    run.skipped += 1  # frame still in flight from a past tick
                    continue
                if not self._ready(run):
                    run.skipped += 1
                    continue
                if run.pipe.plan.has_query_clients and self.batching.enabled:
                    paused = self._begin_deferred(run)
                    if paused is not None:
                        fresh.append(paused)
                    continue
                n = self._burst_size(run)
                if n > 1:
                    self._run_burst(run, n)
                else:
                    self._run_once(run)
        # the whole round's request encodes batch into one codec dispatch
        # per group before anything ships (fused path; arrival order kept)
        pending.extend(self._dispatch_round(fresh))
        self._drain_queries(pending)

    def run(self, n_ticks: int):
        for _ in range(n_ticks):
            self.tick()
        return self

    # -- stats --------------------------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        out = {}
        for dev in self.devices:
            for i, run in enumerate(dev.runs):
                key = f"{dev.name}/p{i}"
                # carried_drops: backlogs of elements a reconfiguration
                # removed — their frames left the topology accounted, and
                # conservation (published == consumed + drops + queued)
                # must survive the swap
                drops = run.carried_drops
                for e in run.pipe.elements.values():
                    if isinstance(e, MqttSrc):
                        drops += e.drops   # across every publisher bound
                    elif isinstance(e, MqttSink):
                        drops += e.channel.drops
                out[key] = {"frames": run.frames, "skipped": run.skipped,
                            "bursts": run.bursts,
                            "burst_frames": run.burst_frames,
                            "drops": drops}
        out["broker"] = {"relay_msgs": self.broker.relay_msgs,
                         "relay_bytes": self.broker.relay_bytes,
                         "lease_expiries": self.broker.expiries,
                         "suspicions": self.broker.suspicions,
                         "heals": self.broker.heals}
        out["failover"] = {"redispatches": self.redispatches,
                           "parked_total": self.parked_total,
                           "parked_now": len(self._parked),
                           "inflight_now": len(self._inflight),
                           "parked_expired": self.parked_expired,
                           "orphaned_requests": self.orphaned_requests}
        out["reconfig"] = self.reconfig.stats()
        agg = {"flushes": 0, "batches": 0, "batched_frames": 0,
               "sequential_frames": 0, "sharded_batches": 0,
               "sharded_frames": 0, "fused_batches": 0, "fused_frames": 0,
               "flush_orphans": 0}
        for b in self._batchers.values():
            # streaming batchers report extra keys (prefills, token
            # conservation lanes, ...) — aggregate whatever each reports,
            # with the stateless keys always present
            for k, v in b.stats().items():
                agg[k] = agg.get(k, 0) + v
        out["query_batching"] = {"max_batch": self.batching.max_batch, **agg}
        # unified per-tenant SLO accounting (DESIGN.md §9): live batcher
        # ledgers + retired-replica archive + runtime-owned sheds (park
        # expiries), with exact tick-latency percentiles — and the
        # conservation law asserted over the merged whole
        tenants: Dict[str, Dict] = {}
        for b in self._batchers.values():
            merge_tenant_stats(tenants, b.tenant_stats())
        merge_tenant_stats(tenants, self._tenant_archive)
        merge_tenant_stats(tenants, self._tenant_shed)
        for tid, t in tenants.items():
            t["p50_ticks"] = percentile_from_hist(t["latency_hist"], 0.50)
            t["p99_ticks"] = percentile_from_hist(t["latency_hist"], 0.99)
            assert t["admitted"] == t["served"] + t["shed"] + \
                t["queued"] + t["in_flight"], \
                f"tenant {tid!r} leaks requests: {t}"
        out["tenants"] = tenants
        if self.delivery is not None:
            d = {"retransmits": self.retransmits, "accepted": 0,
                 "deduped": 0, "rejected_corrupt": 0, "replayed": 0,
                 "answer_drops": 0, "client_answer_dups": 0,
                 "client_answer_corrupt": 0, "client_push_drops": 0}
            for b in self._batchers.values():
                if b.guard is not None:
                    for k, v in b.guard.stats().items():
                        d[k] += v
            for dev in self.devices:
                for run in dev.runs:
                    for e in run.pipe.elements.values():
                        if isinstance(e, TensorQueryClient):
                            d["client_answer_dups"] += e.answer_dups
                            d["client_answer_corrupt"] += e.answer_corrupt
                            d["client_push_drops"] += e.push_drops
                        elif getattr(e, "is_query_sink", False):
                            d["answer_drops"] += e.answer_drops
            out["delivery"] = d
        if self.fabric is not None:
            out["netfault"] = self.fabric.stats()
        if self.autoscalers:
            out["autoscale"] = [s.stats() for s in self.autoscalers]
        return out
