"""Elastic server fleets — autoscaling as a reconfiguration (DESIGN.md §9).

The ROADMAP's production-traffic item asks for "the broker spins pipeline
replicas up/down from observed queue depth, reusing the PR-3 lease/rank
machinery as the scaling signal".  This module is deliberately thin: ALL
the hard problems are already solved elsewhere, and the autoscaler only
composes them —

* the **signal** is :meth:`Broker.scaling_signal` — live replica count and
  per-replica load, maintained by the runtime's per-tick heartbeat from
  each endpoint's queue depth + admission backlog + active decode slots;
* **scale-up** is a §6 reconfiguration: a fresh device gets an EMPTY
  placeholder run (retired — the scheduler skips it), and a single
  ``add``/``link`` edit script grows the replica pipeline into it through
  ``ReconfigManager``'s prepare → warm → commit lifecycle.  The replica
  registers its endpoints inside the commit, so it becomes discoverable
  and runnable atomically; clients rebalance through the broker's ordinary
  win-back + the runtime's QoS join-shortest-queue dispatch.  A replica
  whose device dies mid-warm ROLLS BACK through the same ``target-dead``
  path any planned reconfig uses — the chaos pin for elastic serving.
* **scale-down** is a remove-all reconfiguration of an IDLE replica (no
  queued requests, no admission backlog, no active streams — checked at
  request time and re-checked by the §6 drain guard), so draining a
  replica can lose nothing by construction; its per-tenant ledgers fold
  into the runtime's archive at retire time.

Autoscaling is a reconfig, not a new mechanism: there is no new failure
mode to pin, because every transition IS one of the already-pinned §6
transitions.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax

from ..core.pipeline import Pipeline
from .scheduler import Device, Runtime

__all__ = ["Autoscaler"]


class Autoscaler:
    """Queue-depth driven replica controller for one serve topic.

    ``factory(index)`` builds a FRESH replica pipeline (same model preset,
    same topic) — e.g. ``lambda i: serve_pipeline(model, operation=op)``.
    ``rng`` seeds every replica's params (same seed => replicas answer
    bitwise identically, so rebalancing never changes numerics).

    Thresholds are in heartbeat-load units (requests + backlog + active
    slots): scale up when the topic's MEAN load per replica crosses
    ``high_load`` with every replica ALSO above ``low_load`` (one hot
    replica next to idle ones is a dispatch-balance problem, not a
    capacity problem); scale down when the mean drops to ``low_load`` and
    one of OUR replicas is drained idle.  ``cooldown_ticks`` separates
    actions so a reconfig in flight is never raced by the next decision.
    """

    def __init__(self, runtime: Runtime, topic: str,
                 factory: Callable[[int], Pipeline],
                 high_load: float = 8.0, low_load: float = 0.5,
                 max_replicas: int = 4, min_replicas: int = 1,
                 cooldown_ticks: int = 8, warm_ticks: int = 1,
                 rng=None):
        self.rt = runtime
        self.topic = topic
        self.factory = factory
        self.high_load = float(high_load)
        self.low_load = float(low_load)
        self.max_replicas = int(max_replicas)
        self.min_replicas = int(min_replicas)
        self.cooldown_ticks = int(cooldown_ticks)
        self.warm_ticks = int(warm_ticks)
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        #: replicas THIS controller grew: list of {"device", "run"}
        self.replicas: List[Dict] = []
        self._pending: Optional[Dict] = None     # in-flight reconfig
        self._next_index = 0
        self._last_action_tick = -(10 ** 9)
        self.scale_ups = 0
        self.scale_downs = 0
        self.rollbacks = 0
        runtime.autoscalers.append(self)

    # -- the per-tick decision -------------------------------------------------
    def step(self):
        """Called by ``Runtime.tick`` right after pending reconfigs settle:
        reap the in-flight transition, then decide at most ONE action."""
        self._reap_pending()
        if self._pending is not None:
            return
        if self.rt.ticks - self._last_action_tick < self.cooldown_ticks:
            return
        sig = self.rt.broker.scaling_signal(self.topic).get(self.topic)
        if sig is None or sig["replicas"] <= 0:
            return
        if sig["replicas"] < self.max_replicas and \
                sig["mean_load"] >= self.high_load:
            self._scale_up()
        elif sig["replicas"] > max(self.min_replicas, 1) and \
                sig["mean_load"] <= self.low_load:
            victim = self._idle_replica()
            if victim is not None:
                self._scale_down(victim)

    def _reap_pending(self):
        p = self._pending
        if p is None:
            return
        status = p["handle"].status
        if status not in ("committed", "rolled_back"):
            return
        self._pending = None
        self._last_action_tick = self.rt.ticks
        if status == "committed":
            if p["kind"] == "up":
                self.replicas.append({"device": p["device"],
                                      "run": p["run"]})
                self.scale_ups += 1
            else:
                self.replicas = [r for r in self.replicas
                                 if r["run"] is not p["run"]]
                self.scale_downs += 1
        else:
            # rolled back (target died mid-warm, prepare failed): the
            # placeholder run stays retired, the fleet stays as it was —
            # the §6 lifecycle guarantees no half-replica ever serves
            self.rollbacks += 1

    # -- transitions (both are §6 reconfigs) -----------------------------------
    def _scale_up(self):
        idx = self._next_index
        self._next_index += 1
        template = self.factory(idx)
        dev = Device(f"{self.topic.replace('/', '-')}-replica{idx}")
        run = dev.add_pipeline(Pipeline(name=f"replica{idx}"), jit=False)
        run.retired = True          # nothing to run until the commit
        self.rt.add_device(dev)

        def edit(plan):
            for elem in template.elements.values():
                plan.add(elem)
            for link in template.links:
                plan.link(link.src.name, link.dst.name,
                          link.src_pad, link.dst_pad)
        handle = self.rt.reconfigure(run, edit, warm_ticks=self.warm_ticks,
                                     rng=self.rng)
        self._pending = {"kind": "up", "handle": handle, "device": dev,
                         "run": run}

    def _idle_replica(self) -> Optional[Dict]:
        """A replica of OURS that is fully drained: empty request channel,
        empty admission queue, no live streams, no occupied decode slots —
        removing it can lose nothing by construction."""
        for rep in self.replicas:
            run = rep["run"]
            if run.retired or not rep["device"].alive:
                continue
            if self._replica_idle(run):
                return rep
        return None

    def _replica_idle(self, run) -> bool:
        for e in run.pipe.elements.values():
            ep = getattr(e, "endpoint", None)
            if ep is None or not hasattr(ep, "requests"):
                continue
            batcher = self.rt._batchers.get(ep.endpoint_id)
            if len(ep.requests):
                return False
            if batcher is not None:
                if len(batcher.admission):
                    return False
                if getattr(batcher, "active_streams", None) is not None \
                        and batcher.active_streams():
                    return False
            if getattr(e, "is_stream_serve", False) and \
                    hasattr(e, "active_slots") and \
                    e.active_slots(run.state):
                return False
        return True

    def _scale_down(self, rep: Dict):
        run = rep["run"]

        def edit(plan):
            for name in list(run.pipe.elements):
                plan.remove(name)
        handle = self.rt.reconfigure(run, edit,
                                     warm_ticks=self.warm_ticks)
        self._pending = {"kind": "down", "handle": handle,
                         "device": rep["device"], "run": run}

    # -- introspection ---------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return {"topic": self.topic,
                "managed_replicas": len(self.replicas),
                "scale_ups": self.scale_ups,
                "scale_downs": self.scale_downs,
                "rollbacks": self.rollbacks,
                "pending": (self._pending or {}).get("kind")}
