"""Production mesh definitions.

Single pod = one TPU v5e pod slice, 16×16 = 256 chips, axes (data, model).
Multi-pod = 2 pods = 512 chips, axes (pod, data, model): the ``pod`` axis is
the *among-device* axis — the paper's device boundary.  Training replicates
across it (gradient all-reduce = the only pod-crossing collective); serving
crosses it with query offloading (client pod -> server pod ppermute).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

V5E_PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
V5E_HBM_BW = 819e9           # bytes/s per chip
V5E_ICI_BW = 50e9            # bytes/s per link (~per-direction)


def set_mesh(mesh):
    """Ambient-mesh context, version-compatible: ``jax.set_mesh`` landed
    after 0.4.x; older jax sets the thread-local mesh by entering the Mesh
    itself.  Both make bare-PartitionSpec constraints resolve."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever fits the local devices — CPU tests and the e2e example."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes carrying the batch dimension (pod + data when multi-pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_axis_size(mesh) -> int:
    """Total device count along the batch-carrying axes."""
    sizes = mesh_axis_sizes(mesh)
    n = 1
    for a in data_axes(mesh):
        n *= sizes[a]
    return n


def batch_spec(mesh):
    """PartitionSpec entry for a leading batch/frame axis laid out along the
    mesh's data axes (None when the mesh has no data axes)."""
    dp = data_axes(mesh)
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def mesh_fingerprint(mesh) -> Tuple:
    """Hashable identity of a mesh for executable-cache keys: axis names,
    axis sizes, and the physical device assignment.  Two Mesh objects over
    the same devices in the same layout share executables; reconnecting
    after failover with the same mesh therefore never retraces."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))
