"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

On this CPU box you train the reduced (--smoke) variants; on a TPU slice the
same entry point runs the full config on the production mesh (the step
builder and shardings are identical to the dry-run's).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import latest_step, load_checkpoint, save_checkpoint
from ..configs import ARCH_IDS, get_config
from ..data import make_train_iterator
from ..models.model import build_model
from ..optim import adamw_init
from . import steps as ST
from .mesh import make_host_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced variant of the same family (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 pod mesh (requires 256 devices)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()

    stacked = model.supports_stacked
    step_fn = ST.make_train_step(model, mesh, lr=args.lr,
                                 total_steps=args.steps, stacked=stacked)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    rng = jax.random.PRNGKey(0)
    init = model.init_stacked if stacked else model.init
    params = init(rng)
    opt = adamw_init(params)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start, restored = load_checkpoint(args.ckpt_dir,
                                          like={"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"[train] resumed from step {start}")

    n_params = model.param_count(params)
    print(f"[train] {cfg.name} ({'smoke' if args.smoke else 'full'}) "
          f"params={n_params / 1e6:.1f}M mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    it = make_train_iterator(vocab=cfg.vocab, global_batch=args.batch,
                             seq=args.seq)
    losses = []
    t0 = time.time()
    for i in range(start, args.steps):
        raw = next(it)
        batch = {"tokens": jnp.asarray(raw["tokens"])}
        if cfg.enc_dec:
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, cfg.enc_seq, cfg.d_model))
        if cfg.frontend == "vision":
            batch["patches"] = jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, cfg.n_patches, cfg.d_model))
        params, opt, metrics = jstep(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            tok_s = args.batch * args.seq / dt
            print(f"[train] step {i + 1:5d} loss={losses[-1]:.4f} "
                  f"ce={float(metrics['ce']):.4f} gnorm={float(metrics['grad_norm']):.3f} "
                  f"{dt * 1e3:.0f} ms/step {tok_s:.0f} tok/s", flush=True)
            t0 = time.time()
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1,
                            {"params": params, "opt": opt})
    if losses:
        print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"over {len(losses)} steps")
    return losses


if __name__ == "__main__":
    main()
