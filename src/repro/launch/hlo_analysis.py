"""HLO-level analysis for the roofline report: collective byte counting and
the three roofline terms (cost_analysis has FLOPs/bytes; collective traffic
must be parsed out of the lowered/compiled HLO text).
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

from .mesh import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,512,1024]{2,1,0}
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\(?)([a-z0-9\[\],{}\- ()]*?)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.MULTILINE)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in (compiled) HLO text.
    '-start' variants are counted once ('-done' carries no shape payload of
    its own in the result position we match)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(1)
        for coll in _COLLECTIVES:
            # match `<shape> <coll>(` or `(<tuple shapes>) <coll>-start(`
            idx = rhs.find(f" {coll}(")
            sidx = rhs.find(f" {coll}-start(")
            use = idx if idx >= 0 else sidx
            if use < 0:
                continue
            shape_part = rhs[:use]
            out[coll] += _shape_bytes(shape_part)
            break
    return out


def roofline_terms(cost: Dict, colls: Dict[str, int], n_chips: int,
                   per_device: bool = True) -> Dict[str, float]:
    """Three roofline terms in seconds.

    cost: compiled.cost_analysis() (flops + bytes accessed are PER DEVICE for
    an SPMD executable). colls: collective_bytes() of the compiled module
    (also per device)."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(colls.values()))
    if not per_device:
        flops /= n_chips
        bytes_hbm /= n_chips
        coll_total /= n_chips
    return {
        "compute_s": flops / V5E_PEAK_FLOPS,
        "memory_s": bytes_hbm / V5E_HBM_BW,
        "collective_s": coll_total / V5E_ICI_BW,
        "flops_per_device": flops,
        "hbm_bytes_per_device": bytes_hbm,
        "collective_bytes_per_device": coll_total,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    three = {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")}
    return max(three, key=three.get)
