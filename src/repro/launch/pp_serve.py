"""Among-device serving across the pod axis: pipeline-parallel decode.

This is the paper's Fig. 2 realized at pod scale: the "client" pod owns the
first half of the model's layers, the "server" pod the second half; the
residual stream is the query payload, shipped by ``ppermute`` across the
`pod` axis (the ICI link standing in for the paper's TCP/MQTT-hybrid data
plane).  Microbatches pipeline GPipe-style so both pods do useful work in
the steady state (bubble = (P-1)/(2P-1) for one decode step).

Implementation: shard_map manual over {"pod"} only — data/model stay under
GSPMD (auto axes), so each stage's layers still run tensor-parallel inside
the pod.  The stacked layer dim is pod-sharded: params.stack [R, ...] →
[R/P, ...] per pod; decode caches likewise.

Restrictions (checked): decoder-only, single-period layer pattern, no
prefix/tail, repeats % n_pods == 0 — i.e. the uniform dense archs
(qwen, granite, stablelm, internvl2-LM).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from ..jaxcompat import pvary, shard_map
from jax.sharding import PartitionSpec as P

from ..models import layers as L
from ..models.model import Model
from ..models.sharding import sharding_rules
from ..models.transformer import block_decode, layer_plan
from . import shardings as SH


def pp_applicable(model: Model, mesh) -> bool:
    cfg = model.cfg
    if "pod" not in mesh.axis_names or cfg.enc_dec:
        return False
    prefix, period, repeats, tail = layer_plan(cfg)
    return (not prefix and not tail and period == 1
            and repeats % mesh.shape["pod"] == 0)


def make_pp_serve_step(model: Model, mesh, shard_kv_seq: bool = False
                       ) -> Callable:
    cfg = model.cfg
    assert pp_applicable(model, mesh)
    n_pods = mesh.shape["pod"]
    kind = cfg.kind(0)
    # batch shards over `data` only — `pod` is the stage axis here
    rules = SH.activation_rules(cfg, mesh, shard_kv_seq=shard_kv_seq)
    rules["batch"] = "data"
    rules["__mesh__"] = mesh

    def _pad(spec_len):
        return P(*([None] * spec_len))

    def serve_step(params, token, cache):
        with sharding_rules(**rules):
            b = token.shape[0]
            mb = b // n_pods
            pos = cache["pos"]
            # one-hot embed: XLA's gather partitioner CHECK-fails under the
            # partial-manual pod submesh; a dot partitions cleanly (decode is
            # one row per token — cost negligible)
            onehot_tok = jax.nn.one_hot(token[:, None], cfg.vocab,
                                        dtype=params["embed"]["tok"].dtype)
            x = onehot_tok @ params["embed"]["tok"]              # [B,1,d]

            stack = params["stack"][0]
            groups = cache["groups"][0]

            def body(stack_l, caches_l, x_all, stage_ids):
                # stage id arrives as a pod-sharded iota input rather than
                # jax.lax.axis_index: under a partial-manual submesh the
                # latter lowers to PartitionId, which the SPMD partitioner
                # rejects (and old jax cannot express at all)
                stage = stage_ids[0]
                d = x_all.shape[-1]
                mbs = x_all.reshape(n_pods, mb, 1, d)
                outs = jnp.zeros_like(mbs)
                buf = jnp.zeros((mb, 1, d), x_all.dtype)
                buf = pvary(buf, ("pod",))
                outs = pvary(outs, ("pod",))
                new_caches = caches_l
                perm = [(i, i + 1) for i in range(n_pods - 1)]

                def scan_layers(c_slice, inp):
                    def unit(xc, scanned):
                        p_l, c_l = scanned
                        y, nc = block_decode(p_l, cfg, kind, xc, c_l, pos)
                        return y, nc
                    y, ncs = jax.lax.scan(unit, inp, (stack_l, c_slice))
                    return y, ncs

                for t in range(2 * n_pods - 1):
                    mb_idx = t - stage                  # traced
                    valid = (mb_idx >= 0) & (mb_idx < n_pods)
                    safe_idx = jnp.clip(mb_idx, 0, n_pods - 1)
                    # static microbatch index for stage 0 (t is a python int)
                    inp = jnp.where(stage == 0, mbs[min(t, n_pods - 1)], buf)

                    # STATIC slices + select: a traced-start dynamic-slice
                    # over the data-sharded batch dim makes GSPMD all-gather
                    # the whole cache (measured 2.9 TB/dev) — static starts
                    # partition cleanly
                    def slice_mb(c):
                        parts = [jax.lax.slice_in_dim(c, m2 * mb,
                                                      (m2 + 1) * mb, axis=1)
                                 for m2 in range(n_pods)]
                        out = parts[0]
                        for m2 in range(1, n_pods):
                            out = jnp.where(safe_idx == m2, parts[m2], out)
                        return out

                    c_slice = jax.tree_util.tree_map(slice_mb, new_caches)
                    y, nc = scan_layers(c_slice, inp)

                    def update_mb(old, new_s):
                        out = old
                        for m2 in range(n_pods):
                            upd = jax.lax.dynamic_update_slice_in_dim(
                                old, new_s, m2 * mb, 1)   # static start
                            out = jnp.where((safe_idx == m2) & valid, upd, out)
                        return out

                    new_caches = jax.tree_util.tree_map(update_mb,
                                                        new_caches, nc)
                    # mask-based accumulation (scatter with a traced index
                    # crashes XLA's partial-manual gather partitioner)
                    onehot = (jnp.arange(n_pods) == safe_idx)
                    sel = ((stage == n_pods - 1) & valid)
                    outs = outs + jnp.where(
                        (onehot & sel)[:, None, None, None], y[None], 0.0
                    ).astype(outs.dtype)
                    buf = jax.lax.ppermute(y, "pod", perm)
                # replicate the last stage's outputs to every pod
                outs = jax.lax.psum(
                    jnp.where(stage == n_pods - 1, outs, jnp.zeros_like(outs)),
                    "pod")
                return outs.reshape(b, 1, d), new_caches

            nd = {leaf.ndim for leaf in jax.tree_util.tree_leaves(stack)}
            stack_specs = jax.tree_util.tree_map(
                lambda leaf: P("pod", *([None] * (leaf.ndim - 1))), stack)
            cache_specs = jax.tree_util.tree_map(
                lambda leaf: P("pod", *([None] * (leaf.ndim - 1))), groups)
            x_out, new_groups = shard_map(
                body, mesh=mesh,
                in_specs=(stack_specs, cache_specs, P(None, None, None),
                          P("pod")),
                out_specs=(P(None, None, None), cache_specs),
                axis_names={"pod"}, check_vma=False,
            )(stack, groups, x, jnp.arange(n_pods, dtype=jnp.int32))

            h = L.apply_norm(params["final_norm"], x_out, cfg)
            logits = L.unembed(params["embed"], cfg, h)[:, 0]
            next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            new_cache = {"pos": pos + 1, "prefix": cache["prefix"],
                         "groups": [new_groups], "tail": cache["tail"]}
        return next_token, new_cache

    return serve_step
