"""Sharding rules: parameter PartitionSpecs by pytree path + logical
activation rules for models.sharding.

Strategy (see DESIGN.md §5):
* tensor-parallel over ``model``: attention q/o on the flattened head dim,
  ff hidden, MoE experts (expert-parallel when E % model == 0, else
  per-expert ff TP), vocab for embed/head;
* data-parallel over ``data`` (+ ``pod``): batch dim of activations, KV
  caches, token streams;
* long-context decode: KV sequence sharded over ``data`` (flash-decoding
  style) — enabled by the ``kv_seq`` logical rule;
* divisibility-guarded: any rule whose dim doesn't divide the mesh axis
  falls back to replication (e.g. gemma3's 8 heads on a 16-way model axis —
  its ff/vocab still shard).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from .mesh import data_axes, mesh_axis_sizes


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def activation_rules(cfg: ModelConfig, mesh: Mesh,
                     shard_kv_seq: bool = False) -> Dict[str, Any]:
    ax = mesh_axis_sizes(mesh)
    model = ax.get("model", 1)
    dp = data_axes(mesh)
    rules: Dict[str, Any] = {
        # long-context mode (shard_kv_seq) is batch=1 by construction: the
        # data axis carries the KV sequence instead of the batch
        "batch": None if shard_kv_seq else (
            dp if len(dp) > 1 else (dp[0] if dp else None)),
        # attention-free archs under sequence-parallel SSD keep the WHOLE
        # residual stream sequence-sharded on `model` (norms/MLP/embed are
        # position-local), so shard_map boundaries don't reshard
        "seq": "model" if (cfg.ssm_seq_parallel and cfg.attention_free)
        else None,
        "vocab": "model" if _div(cfg.vocab, model) else None,
        "ff": "model",
        "experts": "model" if _div(cfg.n_experts or model, model) else None,
        "heads": "model" if _div(cfg.n_heads or model, model) else None,
        "kv_heads": "model" if _div(cfg.n_kv_heads or model, model) else None,
        # decode KV sequence: long-context mode shards it on data; otherwise,
        # when kv heads can't cover the model axis (GQA kv < model, or MLA's
        # headless latent), the cache sequence shards on model instead
        "kv_seq": ("data" if shard_kv_seq else
                   ("model" if (cfg.mla or not _div(cfg.n_kv_heads or model,
                                                    model)) else None)),
    }
    return rules


# --- parameter specs by path --------------------------------------------------

def _param_spec(cfg: ModelConfig, path: str, shape: Tuple[int, ...],
                model: int) -> P:
    def ok(dim_idx: int) -> bool:
        return _div(shape[dim_idx], model)

    # embeddings
    if path.endswith("embed/tok"):
        return P("model", None) if ok(0) else P()
    if path.endswith("embed/head"):
        return P(None, "model") if ok(1) else P()
    if "pos_enc" in path or "pos_dec" in path:
        return P()
    # norms / scalars
    if "norm" in path or path.endswith(("A_log", "D", "dt_bias", "lam")):
        return P()
    # Mamba-2 mixer: w_in packs [z|x|B|C|dt] whose split boundaries don't
    # align with a model-axis sharding of the channel dim — GSPMD emits halo
    # collective-permutes every layer (measured: the only collective-bound
    # arch in the baseline sweep).  The SSD state dims (d_inner=2·d_model,
    # N=128) are too small to need TP at 130M scale: replicate the mixer,
    # keep data parallelism.  (§Perf iteration H3, EXPERIMENTS.md.)
    if "/ssm/" in "/" + path:
        return P()
    # MoE experts
    if re.search(r"moe/(w_up|w_gate|w_down)$", path):
        if _div(cfg.n_experts, model):
            return P("model", None, None)                    # expert parallel
        # intra-expert TP: hidden (f) dim sharded on BOTH sides — up/gate
        # col-parallel (out f), down row-parallel (contraction f) — so the
        # [E,C,f] activation stays f-sharded end-to-end (no f all-gather)
        if path.endswith("w_down"):
            return P(None, "model", None) if ok(1) else P()
        return P(None, None, "model") if ok(2) else P()
    if path.endswith("moe/router"):
        return P()
    # MLA factors
    if path.endswith(("w_uk", "w_uv", "w_uq", "w_q")):
        return P(None, "model", None) if ok(1) else P()
    if path.endswith(("w_dkv", "w_dq")):
        return P()
    # attention / generic projections: shard the "wide" dim
    if re.search(r"(attn|xattn)/w[qkv]$", path) or path.endswith(("w_up", "w_gate", "w_in", "w_rec")):
        return P(None, "model") if ok(1) else P()
    if re.search(r"(attn|xattn)/wo$", path) or path.endswith(("w_down", "w_out")):
        return P("model", None) if ok(0) else P()
    if path.endswith(("bq", "bk", "bv")):
        return P("model") if ok(0) else P()
    if path.endswith(("w_r", "w_i")):                         # rg-lru gates
        return P(None, "model") if ok(1) else P()
    if path.endswith("conv"):
        return P(None, "model") if ok(1) else P()
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    model = mesh_axis_sizes(mesh).get("model", 1)

    def spec(path, leaf):
        s = _param_spec(cfg, _path_str(path), leaf.shape, model)
        # stacked (scanned) params have a leading layer dim; shift the spec
        nd = len(leaf.shape)
        if len(s) > nd:
            s = P(*list(s)[:nd])
        if len(s) < nd:
            s = P(*([None] * (nd - len(s)) + list(s)))
        return NamedSharding(mesh, s)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def stacked_param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    """Same rules, but leaves carry a leading [layers] stack dim (scan layout):
    the path-matched spec applies to dims 1..n."""
    model = mesh_axis_sizes(mesh).get("model", 1)

    def spec(path, leaf):
        pstr = _path_str(path)
        # embed/final_norm are not stacked; leaves under stack/ carry a
        # leading [repeats] dim (prefix/ and tail/ do not)
        stacked = pstr.startswith("stack/") or "/stack/" in pstr
        base_shape = leaf.shape[1:] if stacked else leaf.shape
        s = _param_spec(cfg, pstr, base_shape, model)
        s_list = list(s)[: len(base_shape)]
        s_list += [None] * (len(base_shape) - len(s_list))
        if stacked:
            s_list = [None] + s_list
        return NamedSharding(mesh, P(*s_list))

    return jax.tree_util.tree_map_with_path(spec, params_shape)


# --- batch / cache specs --------------------------------------------------------

def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_shape) -> Any:
    ax = mesh_axis_sizes(mesh)
    dp = data_axes(mesh)
    dsize = int(np.prod([ax[a] for a in dp])) if dp else 1
    dspec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec(path, leaf):
        if not leaf.shape or not _div(leaf.shape[0], dsize):
            return NamedSharding(mesh, P(*([None] * len(leaf.shape))))
        s = [dspec] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shape,
                    shard_kv_seq: bool = False) -> Any:
    """KV caches: [.., B, S, kv, hd] batch on data (if divisible), kv heads on
    model; long-context mode shards S on data instead of batch."""
    ax = mesh_axis_sizes(mesh)
    model, data = ax.get("model", 1), ax.get("data", 1)
    dp = data_axes(mesh)
    dsize = int(np.prod([ax[a] for a in dp])) if dp else 1
    dspec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        name = pstr.rsplit("/", 1)[-1]
        if name.isdigit() and "/" in pstr:  # list leaves: cross_k/0 etc.
            name = pstr.split("/")[-2]
        name = {"cross_k": "k", "cross_v": "v"}.get(name, name)
        if name == "pos" or nd == 0:
            return NamedSharding(mesh, P())
        s = [None] * nd
        b = 1 if "groups" in pstr else 0          # stacked caches: [L, B, ...]
        if b >= nd:
            return NamedSharding(mesh, P())
        if _div(shape[b], dsize):
            s[b] = dspec
        elif shard_kv_seq and name in ("k", "v", "c_kv", "k_rope") \
                and nd > b + 1 and _div(shape[b + 1], data):
            s[b + 1] = "data"                      # flash-decoding KV shard
        if name in ("k", "v") and nd > b + 2:
            if _div(shape[b + 2], model):
                s[b + 2] = "model"                 # kv heads
            elif s[b + 1] is None and _div(shape[b + 1], model):
                # kv heads don't divide the model axis (GQA kv < 16): shard
                # the cache SEQUENCE over model instead — attention reduces
                # over partial-seq shards (flash-decoding style); without
                # this, a 110B 128x32k decode cache is 99 GB/device.
                s[b + 1] = "model"
        if name in ("c_kv", "k_rope") and nd > b + 1 and s[b + 1] is None \
                and _div(shape[b + 1], model):
            s[b + 1] = "model"                     # MLA latent: seq on model
        if name == "h" and nd > b + 1 and _div(shape[b + 1], model):
            s[b + 1] = "model"                     # recurrent state width/heads
        if name == "conv" and nd > b + 2 and _div(shape[b + 2], model):
            s[b + 2] = "model"
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def replicated(mesh: Mesh, tree) -> Any:
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)
