"""Serving launcher: an among-device inference service.

The LM runs as a *query server pipeline* (the paper's Fig. 2 server); any
number of clients — pipelines, NNStreamer-Edge processes — offload token
generation to it through the broker-discovered query protocol.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
        --requests 8 --prompt-len 16 --gen 12

Each request is (prompt tokens) -> greedy continuation; the server batches
concurrent requests into one prefill + decode loop (continuous batching at
frame granularity).
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..core import Broker, Caps, StreamBuffer
from ..core.query import QueryServerEndpoint
from ..models.model import build_model
from .mesh import make_host_mesh
from . import steps as ST


class LMQueryServer:
    """A query-protocol server whose payload is full LM generation."""

    def __init__(self, model, params, broker: Broker, operation: str,
                 max_seq: int, gen: int):
        self.model = model
        self.params = params
        self.endpoint = QueryServerEndpoint(operation,
                                            {"inline_runner": self.serve_pending})
        self.registration = broker.register(
            f"query/{operation}", Caps.ANY, self.endpoint,
            model=model.cfg.name, version="1")
        self.max_seq = max_seq
        self.gen = gen
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, self.max_seq))
        self._decode = jax.jit(model.decode_step)
        self.served = 0

    def serve_pending(self):
        """Drain queued requests as one batch (continuous batching)."""
        reqs: List[StreamBuffer] = []
        while True:
            r = self.endpoint.requests.pop()
            if r is None:
                break
            reqs.append(r)
        if not reqs:
            return
        prompts = jnp.stack([r.tensor for r in reqs])          # [B, S]
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        tok = jnp.argmax(logits, -1)
        out = [tok]
        for _ in range(self.gen - 1):
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, -1)
            out.append(tok)
        gen = jnp.stack(out, axis=1)                           # [B, gen]
        for i, r in enumerate(reqs):
            ans = r.with_(tensors=(gen[i],))
            self.endpoint.client_channel(r.meta["client_id"]).push(ans)
            self.served += 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.enc_dec or cfg.frontend == "vision":
        raise SystemExit("serve.py drives text-only archs; whisper/internvl "
                         "serve via examples/multicam_pubsub.py-style graphs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name} ({'smoke' if args.smoke else 'full'}) "
          f"params={model.param_count(params) / 1e6:.1f}M")

    broker = Broker()
    server = LMQueryServer(model, params, broker, "lm/generate",
                           max_seq=args.prompt_len + args.gen + 1,
                           gen=args.gen)

    # clients discover by capability, not address (R3)
    from ..edge import EdgeQueryClient
    rng = np.random.default_rng(0)
    t0 = time.time()
    clients = [EdgeQueryClient(broker, "lm/generate")
               for _ in range(args.requests)]
    # enqueue all requests first (they batch), then serve
    for c in clients:
        prompt = rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
        buf = StreamBuffer(tensors=(jnp.asarray(prompt),),
                           meta={"client_id": c.client_id, "codec": "none"})
        server.endpoint.requests.push(buf)
    server.serve_pending()
    ok = 0
    for c in clients:
        out = server.endpoint.client_channel(c.client_id).pop()
        assert out is not None and out.tensor.shape == (args.gen,)
        ok += 1
    dt = time.time() - t0
    total_tokens = args.requests * args.gen
    print(f"[serve] {ok}/{args.requests} requests answered, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s batched)")
    return ok


if __name__ == "__main__":
    main()
