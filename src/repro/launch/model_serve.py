"""Model-serving launch helpers: real networks behind the query fabric.

Registers the tier-1 serve presets (SERVE_MODELS keys the ``model_serve``
element resolves) and provides the gst-launch-style builders tests and
benchmarks share, plus the per-request SEQUENTIAL decode reference the
continuous-batching parity pins compare against (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from ..core import parse_launch
from ..core.modelserve import SERVE_MODELS, register_serve_model
from ..models.config import ModelConfig

__all__ = ["serve_pipeline", "client_pipeline", "sequential_decode",
           "stage_pipeline", "staged_serve_pipelines", "SERVE_MODELS",
           "three_tier_qos"]


def _stablelm_smoke_flash() -> ModelConfig:
    """Small dense transformer with flash attention on BOTH serve paths
    (prefill via attn_train's flash gate, decode via flash_decode_step)."""
    from ..configs import stablelm_1_6b
    return dataclasses.replace(stablelm_1_6b.config().smoke(),
                               use_flash_attn=True)


def _stablelm_smoke() -> ModelConfig:
    from ..configs import stablelm_1_6b
    return stablelm_1_6b.config().smoke()


def _recurrentgemma_smoke() -> ModelConfig:
    """rGLRU hybrid (R,R,L pattern): recurrent state + windowed-attention
    ring caches as plan state — the SSM-side pin of the stateful contract."""
    from ..configs import recurrentgemma_9b
    return recurrentgemma_9b.config().smoke()


def _stablelm_smoke_4l() -> ModelConfig:
    """4-layer smoke variant: the pipeline-parallel staging testbed — its
    layer count divides evenly into 2 and 4 stages (DESIGN.md §8)."""
    from ..configs import stablelm_1_6b
    return dataclasses.replace(stablelm_1_6b.config().smoke(), n_layers=4)


register_serve_model("stablelm-smoke-flash", _stablelm_smoke_flash)
register_serve_model("stablelm-smoke", _stablelm_smoke)
register_serve_model("recurrentgemma-smoke", _recurrentgemma_smoke)
register_serve_model("stablelm-smoke-4l", _stablelm_smoke_4l)


def serve_pipeline(operation: str = "lm", model: str = "stablelm-smoke-flash",
                   slots: int = 8, max_seq: int = 32):
    """Server pipeline: serversrc ! model_serve ! serversink, sink paired."""
    ps = parse_launch(
        f"tensor_query_serversrc operation={operation} name=ssrc ! "
        f"model_serve model={model} slots={slots} max_seq={max_seq} "
        f"name=lm ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    return ps


def stage_pipeline(operation: str = "lm", model: str = "stablelm-smoke-4l",
                   slots: int = 8, max_seq: int = 32, stage: int = 0,
                   n_stages: int = 2):
    """ONE hop of an among-device pipeline-parallel chain (DESIGN.md §8).

    Stage 0 serves the client-facing operation topic (clients need no idea
    the model is staged); downstream stages serve ``{operation}/s{k}`` —
    the topic the coordinator's per-stage bindings subscribe, with
    ``stage`` declared as a ranking spec so a wildcard never binds a hop
    to the wrong layer slice."""
    topic = operation if stage == 0 else f"{operation}/s{stage}"
    ps = parse_launch(
        f"tensor_query_serversrc operation={topic} stage={stage} "
        f"name=ssrc ! "
        f"model_serve_stage model={model} slots={slots} max_seq={max_seq} "
        f"stage={stage} n_stages={n_stages} name=lm ! "
        f"tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    return ps


def staged_serve_pipelines(operation: str = "lm",
                           model: str = "stablelm-smoke-4l",
                           slots: int = 8, max_seq: int = 32,
                           n_stages: int = 2):
    """The full N-hop chain: one ``stage_pipeline`` per layer slice.

    Deploy each on its own Device: stage k's per-slot boundary activations
    stream to stage k+1 over the same query fabric clients use, so broker
    discovery ranks stages, leases detect stage death, and §6 reconfig
    covers stage swap — among-device hops, not intra-process shards."""
    return [stage_pipeline(operation, model, slots, max_seq, k, n_stages)
            for k in range(n_stages)]


def client_pipeline(operation: str = "lm", prompts: str = "1,2,3",
                    gens: str = "4", codec: str = "none",
                    tenant: Optional[str] = None):
    """Streaming client: one prompt request per frame, cycling prompts/gens.

    ``tenant`` tags every request with a tenant id so the serve side's
    admission layer can schedule it under that tenant's QoS contract;
    ``None`` keeps the pre-QoS wire format byte-identical."""
    tenant_prop = f" tenant={tenant}" if tenant is not None else ""
    return parse_launch(
        f"token_prompt_src prompts={prompts} gens={gens} ! "
        f"tensor_query_client operation={operation} codec={codec}"
        f"{tenant_prop} name=qc ! appsink name=res")


def three_tier_qos(rate: Optional[int] = None,
                   deadline_ticks: Optional[int] = None,
                   max_queue: Optional[int] = None,
                   serve_per_tick: Optional[int] = None):
    """The canonical three-tenant serving contract (DESIGN.md §9).

    * ``realtime``    — priority 0, strict per-tick deadline, never sheds
      for rate (interactive traffic is assumed pre-shaped upstream);
    * ``standard``    — priority 1, rate-limited to ``rate`` req/tick with
      a matching burst, bounded queue;
    * ``best-effort`` — priority 2, same rate budget, shortest deadline and
      smallest queue: the tier that sheds FIRST under overload, explicitly.

    Unknown tenant ids fall into ``best-effort`` (the ``default`` spec), so
    an unregistered tenant can never crowd out paying tiers."""
    from ..core.admission import QoSConfig, TenantSpec
    best_effort = TenantSpec("best-effort", priority=2, rate=rate,
                             deadline_ticks=deadline_ticks, max_queue=max_queue)
    return QoSConfig(
        tenants=(
            TenantSpec("realtime", priority=0,
                       deadline_ticks=deadline_ticks),
            TenantSpec("standard", priority=1, rate=rate,
                       deadline_ticks=(None if deadline_ticks is None
                                       else 2 * deadline_ticks),
                       max_queue=(None if max_queue is None
                                  else 2 * max_queue)),
            best_effort,
        ),
        default=best_effort,
        serve_per_tick=serve_per_tick)


def sequential_decode(params, cfg: ModelConfig, prompt, gen: int,
                      max_seq: int) -> List[int]:
    """Per-request sequential greedy decode — the parity reference.

    One jitted b=1 prefill then ``gen - 1`` jitted b=1 decode steps: the
    exact program each slot of the continuous batch runs, dispatched the
    pre-batching way.  Continuous-batched serving must reproduce this
    token-for-token (bitwise) for every request, whatever the join/leave
    interleaving."""
    from ..models import transformer

    @jax.jit
    def prefill(p, toks):
        logits, cache = transformer.lm_prefill(p, cfg, toks[None], max_seq)
        return jnp.argmax(logits[0], axis=-1).astype(jnp.int32), cache

    @jax.jit
    def decode(p, tok, cache):
        logits, cache = transformer.lm_decode(p, cfg, tok[None], cache)
        return jnp.argmax(logits[0], axis=-1).astype(jnp.int32), cache

    tok, cache = prefill(params, jnp.asarray(prompt, jnp.int32))
    out = [int(tok)]
    for _ in range(max(0, gen - 1)):
        tok, cache = decode(params, tok, cache)
        out.append(int(tok))
    return out
