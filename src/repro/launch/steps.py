"""Step builders: jitted train/prefill/decode steps with production
shardings.  These are what both the real launcher (train.py/serve.py) and
the dry-run lower.

All steps consume the *stacked* (scan) parameter layout for decoder-only
archs — an 80-layer model lowers as one scanned pattern-unit — and the list
layout for enc-dec (whisper: 32+32 unrolled blocks of a small d_model).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model import Model
from ..models.sharding import sharding_rules
from ..optim import adamw_init, adamw_update, linear_warmup_cosine
from . import shardings as SH
from .mesh import data_axes

# input shapes assigned to this paper (brief):
SHAPES: Dict[str, Dict] = {
    "train_4k": {"mode": "train", "seq": 4096, "global_batch": 256},
    "prefill_32k": {"mode": "prefill", "seq": 32_768, "global_batch": 32},
    "decode_32k": {"mode": "decode", "seq": 32_768, "global_batch": 128},
    "long_500k": {"mode": "decode", "seq": 524_288, "global_batch": 1},
}

# archs allowed to run long_500k (sub-quadratic decode state; DESIGN.md §4)
LONG_OK = {"mamba2-130m", "recurrentgemma-9b", "gemma3-4b", "mixtral-8x22b"}


def shape_applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and cfg.name not in LONG_OK:
        return False, "full-attention KV at 500k context (DESIGN.md §4 skip)"
    return True, ""


def make_train_step(model: Model, mesh, lr: float = 3e-4,
                    total_steps: int = 1000, stacked: bool = True
                    ) -> Callable:
    cfg = model.cfg
    rules = {**SH.activation_rules(cfg, mesh), "__mesh__": mesh}
    schedule = linear_warmup_cosine(lr, warmup=min(100, total_steps // 10 + 1),
                                    total_steps=total_steps)
    if stacked and model.supports_stacked:
        loss_fn = model.loss_stacked
    else:
        # per-layer remat to match the scanned production program's profile
        loss_fn = functools.partial(model.loss,
                                    remat=not model.cfg.enc_dec)

    def train_step(params, opt_state, batch):
        with sharding_rules(**rules):
            (loss, parts), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch), has_aux=True)(params)
            new_params, new_opt, info = adamw_update(
                params, grads, opt_state, lr=schedule(opt_state.step))
        metrics = {"loss": loss, **parts, **info,
                   "lr": schedule(opt_state.step)}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model, mesh, max_seq: Optional[int] = None,
                      stacked: bool = True) -> Callable:
    cfg = model.cfg
    rules = {**SH.activation_rules(cfg, mesh), "__mesh__": mesh}
    fn = model.prefill_stacked if (stacked and model.supports_stacked) \
        else model.prefill

    def prefill_step(params, batch):
        with sharding_rules(**rules):
            logits, cache = fn(params, batch, max_seq or batch["tokens"].shape[1])
        return logits, cache

    return prefill_step


def make_decode_step(model: Model, mesh, shard_kv_seq: bool = False,
                     stacked: bool = True) -> Callable:
    cfg = model.cfg
    rules = {**SH.activation_rules(cfg, mesh, shard_kv_seq=shard_kv_seq),
             "__mesh__": mesh}
    fn = model.decode_step_stacked if (stacked and model.supports_stacked) \
        else model.decode_step

    def serve_step(params, token, cache):
        """ONE new token against a seq_len KV cache (the brief's decode)."""
        with sharding_rules(**rules):
            logits, cache = fn(params, token, cache)
            next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, cache

    return serve_step


# ---------------------------------------------------------------------------
# shape/sharding plumbing shared by dryrun + launchers
# ---------------------------------------------------------------------------

def eval_params_shape(model: Model, stacked: bool = True):
    init = model.init_stacked if (stacked and model.supports_stacked) else model.init
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0)))


def eval_cache_shape(model: Model, batch: int, seq: int, stacked: bool = True):
    init = model.init_cache_stacked if (stacked and model.supports_stacked) \
        else model.init_cache
    return jax.eval_shape(lambda: init(batch, seq))


def eval_opt_shape(params_shape):
    return jax.eval_shape(adamw_init, params_shape)


def opt_shardings(mesh, params_sharding, opt_shape):
    """OptState(step scalar, m, v) — m/v mirror params specs (they have the
    same tree shape; dtype differs)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    return type(opt_shape)(
        step=NamedSharding(mesh, P()),
        m=params_sharding,
        v=params_sharding,
    )


def input_specs(model: Model, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a named shape —
    weak-type-correct, shardable, no device allocation."""
    info = SHAPES[shape_name]
    seq = model.clamp_seq(info["seq"])
    return model.input_specs(info["mode"], info["global_batch"], seq)
