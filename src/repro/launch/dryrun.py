import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers and compiles
with coherent shardings — no real hardware, 512 placeholder host devices.
(The XLA_FLAGS assignment above MUST precede every jax import — jax locks
the device count at first init.)

    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per combo it records compiled memory_analysis (bytes/device — proves fit),
cost_analysis (FLOPs/bytes for §Roofline), and the collective schedule
(bytes per collective kind parsed from compiled HLO), appending JSON to
results/dryrun.json for the roofline report.

Roofline terms: XLA's HLO cost analysis counts a while-loop (lax.scan) body
ONCE, so the scanned production program under-reports FLOPs by ~n_layers×.
We therefore derive per-layer costs by compiling UNROLLED 1-unit and 2-unit
variants of each arch (identical shardings, per-layer remat) and
extrapolating layer-linearly:  total = C(1) + (units-1)·(C(2)-C(1)).
cost_analysis is per-device for SPMD executables (verified), so terms are
already per-chip.
"""
import argparse
import json
import time
import traceback
from dataclasses import replace
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..models.model import build_model
from ..models.transformer import layer_plan
from . import hlo_analysis as HA
from . import shardings as SH
from . import steps as ST
from .mesh import make_production_mesh, mesh_axis_sizes, set_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun.json")


def _build_lowered(cfg, model, shape_name: str, mesh, stacked: bool):
    """Lower the step for one combo; returns (lowered, meta)."""
    info = ST.SHAPES[shape_name]
    mode = info["mode"]
    seq = model.clamp_seq(info["seq"])
    batch = info["global_batch"]

    params_shape = ST.eval_params_shape(model, stacked)
    pspec = SH.stacked_param_shardings(cfg, mesh, params_shape) if stacked \
        else SH.param_shardings(cfg, mesh, params_shape)
    specs = ST.input_specs(model, shape_name)
    bspec = SH.batch_shardings(cfg, mesh, specs)

    if mode == "train":
        step = ST.make_train_step(model, mesh, stacked=stacked)
        opt_shape = ST.eval_opt_shape(params_shape)
        ospec = ST.opt_shardings(mesh, pspec, opt_shape)
        jitted = jax.jit(step, in_shardings=(pspec, ospec, bspec),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_shape, opt_shape, specs)
    elif mode == "prefill":
        step = ST.make_prefill_step(model, mesh, max_seq=seq, stacked=stacked)
        jitted = jax.jit(step, in_shardings=(pspec, bspec))
        lowered = jitted.lower(params_shape, specs)
    else:  # decode
        shard_kv = (shape_name == "long_500k")
        step = ST.make_decode_step(model, mesh, shard_kv_seq=shard_kv,
                                   stacked=stacked)
        cache_shape = ST.eval_cache_shape(model, batch, seq, stacked)
        cspec = SH.cache_shardings(cfg, mesh, cache_shape, shard_kv_seq=shard_kv)
        tok_spec = specs["token"]
        tspec = SH.batch_shardings(cfg, mesh, {"token": tok_spec})["token"]
        jitted = jax.jit(step, in_shardings=(pspec, tspec, cspec),
                         donate_argnums=(2,))
        lowered = jitted.lower(params_shape, tok_spec, cache_shape)
    return lowered, {"mode": mode, "seq": seq, "global_batch": batch}


def _cost_and_colls(compiled) -> Dict:
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    colls = HA.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "colls": colls}


def _reduced_cfg(cfg, k: int):
    """cfg with k pattern-units of layers (prefix/tail preserved)."""
    prefix, period, repeats, tail = layer_plan(cfg)
    n_layers = len(prefix) + k * period + (cfg.n_layers - len(prefix)
                                           - repeats * period)
    kw = {"n_layers": n_layers}
    if cfg.enc_dec:
        kw["n_enc_layers"] = k
        kw["n_layers"] = k
    return replace(cfg, **kw), (cfg.n_enc_layers if cfg.enc_dec else repeats)


def extrapolated_roofline(arch: str, shape_name: str, multi_pod: bool,
                          n_chips: int, mesh,
                          overrides: Optional[Dict] = None) -> Dict:
    """Layer-linear extrapolation of the three roofline terms from unrolled
    1-unit and 2-unit compiles."""
    cfg = get_config(arch)
    if overrides:
        cfg = replace(cfg, **overrides)
    measures = {}
    for k in (1, 2):
        cfg_k, units = _reduced_cfg(cfg, k)
        model_k = build_model(cfg_k)
        lowered, _ = _build_lowered(cfg_k, model_k, shape_name, mesh,
                                    stacked=False)
        measures[k] = _cost_and_colls(lowered.compile())
    c1, c2 = measures[1], measures[2]
    _, units = _reduced_cfg(cfg, 1)

    def lin(a, b):
        return a + (units - 1) * (b - a)

    flops = lin(c1["flops"], c2["flops"])
    bytes_ = lin(c1["bytes"], c2["bytes"])
    colls = {k: int(lin(c1["colls"][k], c2["colls"][k])) for k in c1["colls"]}
    terms = HA.roofline_terms({"flops": flops, "bytes accessed": bytes_},
                              colls, n_chips)
    terms["dominant"] = HA.dominant_term(terms)
    terms["units_extrapolated"] = units
    return {"roofline": terms, "collectives": colls,
            "unit_costs": {str(k): m for k, m in measures.items()}}


def lower_combo(arch: str, shape_name: str, multi_pod: bool,
                compile_: bool = True, analysis: bool = True,
                overrides: Optional[Dict] = None, variant: str = "") -> Dict:
    """Full scanned lower+compile (sharding & memory proof) + extrapolated
    roofline terms (single-pod analysis).  ``overrides`` patches ModelConfig
    fields (perf-iteration variants, recorded under ``variant``)."""
    t0 = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = replace(cfg, **overrides)
    model = build_model(cfg)
    ok, why = ST.shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    stacked = model.supports_stacked

    with set_mesh(mesh):
        lowered, meta = _build_lowered(cfg, model, shape_name, mesh, stacked)
        t_lower = time.time() - t0
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "n_chips": n_chips, **meta, "lower_s": round(t_lower, 1),
               "status": "lowered"}
        if variant:
            rec["variant"] = variant
            rec["overrides"] = overrides
        if not compile_:
            return rec
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0 - t_lower, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
        rec["scanned_cost_raw"] = _cost_and_colls(compiled)
        rec["status"] = "compiled"

        if analysis:
            ana = extrapolated_roofline(arch, shape_name, multi_pod, n_chips,
                                        mesh, overrides)
            rec.update(ana)
            # MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (serve)
            toks = meta["global_batch"] * (meta["seq"] if meta["mode"] != "decode" else 1)
            n_active = model.active_param_count()
            mf = (6.0 if meta["mode"] == "train" else 2.0) * n_active * toks
            rec["model_flops_total"] = mf
            hlo_total = rec["roofline"]["flops_per_device"] * n_chips
            rec["model_vs_hlo_flops"] = mf / hlo_total if hlo_total else None
        rec["analysis_s"] = round(time.time() - t0, 1)
        return rec


def append_result(rec: Dict, path: str = RESULTS):
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    data = []
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data = [r for r in data
            if not (r["arch"] == rec["arch"] and r["shape"] == rec["shape"]
                    and r["mesh"] == rec["mesh"]
                    and r.get("variant", "") == rec.get("variant", ""))]
    data.append(rec)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(ST.SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--no-analysis", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override field=value (perf variants)")
    ap.add_argument("--variant", default="",
                    help="label for this perf variant in results json")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, _, v = kv.partition("=")
        overrides[k] = {"0": False, "1": True, "true": True,
                        "false": False}.get(v.lower(), v)

    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(ST.SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = {"single": (False,), "multi": (True,), "both": (False, True)}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch} × {shape} × {'multi' if multi else 'single'}"
                try:
                    rec = lower_combo(arch, shape, multi,
                                      compile_=not args.no_compile,
                                      analysis=not args.no_analysis and not multi,
                                      overrides=overrides or None,
                                      variant=args.variant)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                append_result(rec, args.out)
                status = rec["status"]
                extra = ""
                if "roofline" in rec:
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" compute={r['compute_s']:.2e}s"
                             f" mem={r['memory_s']:.2e}s"
                             f" coll={r['collective_s']:.2e}s"
                             f" model/hlo={rec.get('model_vs_hlo_flops', 0):.2f}")
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
