"""Tenant-aware admission control — the QoS scheduling core (DESIGN.md §9).

The among-device pitch only works at scale if the serving fabric can tell
tenants apart, enforce budgets, and shed load explicitly (arXiv 2210.10514
names exactly this gap: multi-tenant scheduling across heterogeneous
consumer devices).  Before this module every client was equal and the only
overload behavior was the request Channel's leaky drop — an unaccounted,
silent loss.  Now every batcher in ``core/batching.py`` runs its queueing
through ONE :class:`AdmissionQueue`:

* **ingest** — wire requests pop off the endpoint Channel into per-tenant
  session queues (``tenant_id`` rides the routing meta from
  ``tensor_query_client``).  A :class:`TenantSpec` may bound the tenant
  with a token-bucket rate budget (``rate``/``burst``, refilled on the
  scheduler tick clock) and a queue cap (``max_queue``); requests over
  budget are SHED — counted per tenant per reason, and surfaced to the
  client as an explicit error (never a silent drop).
* **take** — the dequeue replacing the implicit channel FIFO.  With no
  :class:`QoSConfig` the queue is a pure FIFO pass-through (global arrival
  order, bitwise the pre-QoS fabric — the load-bearing default).  With QoS
  enabled, scheduling is weighted-fair across PRIORITY CLASSES with
  earliest-deadline-first within a class:

  1. classes (distinct tenant priorities with queued work) are stride-
     scheduled: the class with the lowest virtual pass wins and its pass
     advances by ``1 / weight(class)`` — a non-empty class is never
     starved, its wait is bounded by the total weight in flight;
  2. within the class, the tenant whose HEAD request has the earliest
     ``(deadline, arrival)`` is served — per-tenant FIFO holds by
     construction (only queue heads compete, and a tenant's deadlines are
     monotone in arrival order since the offset is per-spec).

* **expire** — queued requests past their tenant deadline shed with reason
  ``"deadline"``; the deadline clock is the scheduler tick, so it keeps
  running wherever the request waits (including parked frames — the
  runtime applies the same spec to its park ledger).
* **conservation** — every record is exactly one of served / shed /
  queued / in-flight, so ``admitted == served + shed + queued + in_flight``
  at every instant; ``Runtime.stats()`` asserts the law over the merged
  per-tenant ledgers.

Scheduling changes ORDERING and ADMISSION, never answers: a request that
is served flows through the exact serve path it always did, so the
batched/sharded/fused/staged bitwise parity pins are out of scope by
construction (DESIGN.md §9 spells out the contract).
"""
from __future__ import annotations

import itertools
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["TenantSpec", "QoSConfig", "AdmissionRecord", "AdmissionQueue",
           "DEFAULT_TENANT", "percentile_from_hist", "merge_tenant_stats"]

#: tenant every untagged request books under — keeps single-tenant
#: deployments (and the entire pre-QoS test corpus) on one ledger without
#: clients ever naming a tenant
DEFAULT_TENANT = "default"

_INF = float("inf")


@dataclass(frozen=True)
class TenantSpec:
    """Per-tenant admission contract.

    ``priority`` 0 is the most urgent class; ``weight`` is the WFQ share
    (default ``1 / 2**priority`` — each class up weighs twice the one
    below).  ``rate``/``burst`` form a token bucket refilled on the tick
    clock (``rate`` tokens/tick up to ``burst``; None = unmetered).
    ``deadline_ticks`` bounds queue wait (EDF key + expiry);
    ``max_queue`` bounds backlog per endpoint."""

    tenant_id: str = DEFAULT_TENANT
    priority: int = 1
    weight: Optional[float] = None
    rate: Optional[float] = None
    burst: Optional[float] = None
    deadline_ticks: Optional[int] = None
    max_queue: Optional[int] = None

    @property
    def effective_weight(self) -> float:
        if self.weight is not None:
            return max(self.weight, 1e-9)
        return 1.0 / (2.0 ** max(0, self.priority))

    @property
    def effective_burst(self) -> float:
        if self.burst is not None:
            return self.burst
        # default headroom: one tick of rate, floor 1 (a rate under 1/tick
        # still admits singles as the bucket trickles full)
        return max(1.0, self.rate if self.rate is not None else 1.0)


class QoSConfig:
    """Admission policy for a runtime: tenant specs + serve capacity.

    ``serve_per_tick`` caps how many requests ALL tenants may dequeue per
    scheduler tick per endpoint (None = unbounded — the default keeps the
    edge-client serve-before-return contract intact); requests over the
    cap stay queued and are served next tick in QoS order."""

    def __init__(self, tenants: Tuple[TenantSpec, ...] = (),
                 default: Optional[TenantSpec] = None,
                 serve_per_tick: Optional[int] = None):
        self.tenants: Dict[str, TenantSpec] = {t.tenant_id: t
                                               for t in tenants}
        self.default = default or TenantSpec()
        self.serve_per_tick = serve_per_tick

    def spec(self, tenant_id: str) -> TenantSpec:
        return self.tenants.get(tenant_id, self.default)


@dataclass
class AdmissionRecord:
    """One admitted request: the raw wire buffer plus its scheduling key."""

    raw: Any
    tenant: str
    seq: int
    enqueue_tick: int
    deadline: float = _INF          # absolute tick; _INF = no deadline
    priority: int = 1
    client_id: Optional[int] = None

    def order_key(self) -> Tuple:
        """(priority, deadline, arrival) — the slot-admission sort key the
        streaming batcher reuses for its waiting list (DESIGN.md §9)."""
        return (self.priority, self.deadline, self.seq)


class _TenantState:
    __slots__ = ("spec", "queue", "tokens", "last_refill", "admitted",
                 "served", "shed", "shed_reasons", "in_flight", "latency")

    def __init__(self, spec: TenantSpec, now: int):
        self.spec = spec
        self.queue: deque = deque()
        self.tokens = spec.effective_burst
        self.last_refill = now
        self.admitted = 0
        self.served = 0
        self.shed = 0
        self.shed_reasons: Counter = Counter()
        self.in_flight = 0
        #: tick-latency histogram: wait ticks -> count (exact percentiles —
        #: latencies are small ints, a Counter beats reservoir sampling)
        self.latency: Counter = Counter()

    def refill(self, now: int):
        if self.spec.rate is None:
            return
        dt = now - self.last_refill
        if dt > 0:
            self.tokens = min(self.spec.effective_burst,
                              self.tokens + self.spec.rate * dt)
        self.last_refill = now


class AdmissionQueue:
    """The shared queueing/shedding/accounting core behind every batcher.

    ``qos=None`` (the default) is a pure FIFO pass-through: ``take``
    returns global arrival order, nothing is ever shed or reordered, and
    the only cost over the old channel ``pop_n`` is the ledger — the
    bitwise-parity contract rests on this mode being exact.

    ``clock`` is the scheduler tick source (deadline + token-bucket
    clock); standalone use defaults to a monotonic counter so every
    ``take`` round is its own tick."""

    def __init__(self, qos: Optional[QoSConfig] = None,
                 clock: Optional[Callable[[], int]] = None):
        self.qos = qos
        if clock is None:
            counter = itertools.count()
            clock = lambda: next(counter)           # noqa: E731
        self.clock = clock
        self._tenants: Dict[str, _TenantState] = {}
        self._seq = itertools.count()
        self._queued = 0
        self._queued_by_client: Counter = Counter()
        #: client_id -> FIFO of shed reasons awaiting client notification
        #: (the runtime answers each with an explicit error frame)
        self._notices: Dict[Any, deque] = {}
        #: stride-scheduler virtual pass per priority class
        self._class_pass: Dict[int, float] = {}
        #: serve budget bookkeeping (serve_per_tick)
        self._budget_tick: Optional[int] = None
        self._budget_used = 0

    # -- introspection ---------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self.qos is not None

    def __len__(self) -> int:
        return self._queued

    def backlog(self) -> int:
        """Queued + in-flight — the queue-depth half of the broker's
        scaling signal."""
        return self._queued + sum(t.in_flight
                                  for t in self._tenants.values())

    def queued_for(self, client_id) -> int:
        return self._queued_by_client.get(client_id, 0)

    def pop_notice(self, client_id) -> Optional[str]:
        """One shed reason awaiting delivery to ``client_id`` (pop-once);
        None when the client has no pending shed notice."""
        q = self._notices.get(client_id)
        if not q:
            return None
        reason = q.popleft()
        if not q:
            del self._notices[client_id]
        return reason

    def _state(self, tenant_id: str) -> _TenantState:
        ts = self._tenants.get(tenant_id)
        if ts is None:
            spec = (self.qos.spec(tenant_id) if self.qos is not None
                    else TenantSpec(tenant_id))
            ts = self._tenants[tenant_id] = _TenantState(spec, self.clock())
        return ts

    # -- ingest ----------------------------------------------------------------
    def ingest(self, raw) -> Optional[AdmissionRecord]:
        """Admit one wire request into its tenant's session queue, or shed
        it (rate budget / queue cap) with explicit accounting.  Returns the
        record, or None when shed."""
        meta = getattr(raw, "meta", {}) or {}
        tenant_id = meta.get("tenant_id", DEFAULT_TENANT)
        now = self.clock()
        ts = self._state(tenant_id)
        ts.admitted += 1
        client_id = meta.get("client_id")
        if self.enabled:
            spec = ts.spec
            if spec.max_queue is not None and \
                    len(ts.queue) >= spec.max_queue:
                return self._shed_at_ingest(ts, client_id, "queue-full")
            if spec.rate is not None:
                ts.refill(now)
                if ts.tokens < 1.0:
                    return self._shed_at_ingest(ts, client_id, "rate")
                ts.tokens -= 1.0
            deadline = (now + spec.deadline_ticks
                        if spec.deadline_ticks is not None else _INF)
            priority = spec.priority
        else:
            deadline, priority = _INF, 1
        rec = AdmissionRecord(raw=raw, tenant=tenant_id,
                              seq=next(self._seq), enqueue_tick=now,
                              deadline=deadline, priority=priority,
                              client_id=client_id)
        ts.queue.append(rec)
        self._queued += 1
        if client_id is not None:
            self._queued_by_client[client_id] += 1
        return rec

    def ingest_channel(self, channel) -> int:
        """Drain every pending wire request off the endpoint Channel into
        the admission queues (the gather half of queue-gather-flush)."""
        n = 0
        while True:
            raw = channel.pop()
            if raw is None:
                return n
            if self.ingest(raw) is not None:
                n += 1

    def _shed_at_ingest(self, ts: _TenantState, client_id,
                        reason: str) -> None:
        ts.shed += 1
        ts.shed_reasons[reason] += 1
        if client_id is not None:
            self._notices.setdefault(client_id, deque()).append(reason)
        return None

    # -- deadline expiry -------------------------------------------------------
    def expire(self) -> int:
        """Shed queued requests past their tenant deadline (reason
        ``"deadline"``).  Per-tenant deadlines are monotone in arrival
        order (constant offset), so only queue heads need checking."""
        if not self.enabled or self._queued == 0:
            return 0
        now = self.clock()
        expired = 0
        for ts in self._tenants.values():
            while ts.queue and ts.queue[0].deadline <= now and \
                    ts.queue[0].deadline is not _INF and \
                    ts.queue[0].deadline != _INF:
                rec = ts.queue.popleft()
                self._dequeued(rec)
                ts.shed += 1
                ts.shed_reasons["deadline"] += 1
                if rec.client_id is not None:
                    self._notices.setdefault(rec.client_id,
                                             deque()).append("deadline")
                expired += 1
        return expired

    def _dequeued(self, rec: AdmissionRecord):
        self._queued -= 1
        if rec.client_id is not None:
            self._queued_by_client[rec.client_id] -= 1
            if self._queued_by_client[rec.client_id] <= 0:
                del self._queued_by_client[rec.client_id]

    # -- dequeue (the scheduling function) -------------------------------------
    def _budget_left(self) -> float:
        if self.qos is None or self.qos.serve_per_tick is None:
            return _INF
        now = self.clock()
        if now != self._budget_tick:
            self._budget_tick = now
            self._budget_used = 0
        return self.qos.serve_per_tick - self._budget_used

    def take(self, limit: Optional[int] = None) -> List[AdmissionRecord]:
        """Dequeue up to ``limit`` records (None = all available) in
        scheduling order; each moves to in-flight until ``mark_served`` /
        ``mark_shed`` closes it."""
        budget = self._budget_left()
        n = self._queued if limit is None else min(limit, self._queued)
        n = int(min(n, budget)) if budget != _INF else n
        if n <= 0:
            return []
        out: List[AdmissionRecord] = []
        if not self.enabled:
            # pure FIFO pass-through: global arrival order, exactly the
            # channel semantics the parity pins were built on
            while len(out) < n:
                ts = min((t for t in self._tenants.values() if t.queue),
                         key=lambda t: t.queue[0].seq)
                out.append(self._pop_head(ts))
        else:
            while len(out) < n:
                classes: Dict[int, List[_TenantState]] = {}
                for t in self._tenants.values():
                    if t.queue:
                        classes.setdefault(t.spec.priority, []).append(t)
                if not classes:
                    break
                cls = self._pick_class(classes)
                ts = min(classes[cls],
                         key=lambda t: (t.queue[0].deadline,
                                        t.queue[0].seq))
                out.append(self._pop_head(ts))
        self._budget_used += len(out)
        return out

    def _pick_class(self, classes: Dict[int, List[_TenantState]]) -> int:
        """Stride scheduling across priority classes: min virtual pass
        wins, pass advances by the inverse class weight.  A class entering
        with work starts at the current minimum pass (it earns service at
        once but cannot claim retroactive credit), so no non-empty class
        ever waits more than ``total_weight / weight`` dequeues."""
        floor = min((self._class_pass[c] for c in classes
                     if c in self._class_pass), default=0.0)
        for c in classes:
            self._class_pass[c] = max(self._class_pass.get(c, floor), floor)
        cls = min(classes, key=lambda c: (self._class_pass[c], c))
        w = sum(t.spec.effective_weight for t in classes[cls])
        self._class_pass[cls] += 1.0 / max(w, 1e-9)
        return cls

    def _pop_head(self, ts: _TenantState) -> AdmissionRecord:
        rec = ts.queue.popleft()
        self._dequeued(rec)
        ts.in_flight += 1
        return rec

    # -- closing the ledger ----------------------------------------------------
    def mark_served(self, rec: AdmissionRecord):
        ts = self._state(rec.tenant)
        ts.in_flight -= 1
        ts.served += 1
        ts.latency[max(0, self.clock() - rec.enqueue_tick)] += 1

    def mark_shed(self, rec: AdmissionRecord, reason: str,
                  notify: bool = True):
        """Close an in-flight record as shed.  ``notify=False`` for sheds
        the failover fabric already answers (a dead endpoint's requests
        re-dispatch from their PendingQuery records — the client gets a
        real answer elsewhere, not an error)."""
        ts = self._state(rec.tenant)
        ts.in_flight -= 1
        ts.shed += 1
        ts.shed_reasons[reason] += 1
        if notify and rec.client_id is not None:
            self._notices.setdefault(rec.client_id,
                                     deque()).append(reason)

    def shed_queued(self, reason: str, notify: bool = False,
                    on_shed=None) -> int:
        """Shed EVERYTHING still queued (endpoint death: requests already
        ingested are invisible to the down event's channel purge and must
        reach the ledger explicitly).  ``on_shed(rec)``, when given, fires
        per record — the delivery guard uses it to forget a shed request's
        dedup id so its failover re-dispatch is admittable (§10)."""
        total = 0
        for ts in self._tenants.values():
            while ts.queue:
                rec = ts.queue.popleft()
                self._dequeued(rec)
                ts.shed += 1
                ts.shed_reasons[reason] += 1
                if notify and rec.client_id is not None:
                    self._notices.setdefault(rec.client_id,
                                             deque()).append(reason)
                if on_shed is not None:
                    on_shed(rec)
                total += 1
        return total

    # -- stats -----------------------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        """Per-tenant ledgers: the conservation counters plus the raw
        latency histogram (merged and percentiled by ``Runtime.stats``)."""
        out: Dict[str, Dict] = {}
        for tid, ts in self._tenants.items():
            out[tid] = {
                "priority": ts.spec.priority,
                "admitted": ts.admitted,
                "served": ts.served,
                "shed": ts.shed,
                "queued": len(ts.queue),
                "in_flight": ts.in_flight,
                "shed_reasons": dict(ts.shed_reasons),
                "latency_hist": dict(ts.latency),
            }
        return out


# ---------------------------------------------------------------------------
# Stats plumbing shared by Runtime.stats, the benchmark, and the example
# ---------------------------------------------------------------------------

def percentile_from_hist(hist: Dict[int, int], q: float) -> float:
    """Exact q-quantile (0..1) of a ``value -> count`` histogram; 0.0 when
    empty (nothing measured is nothing late)."""
    total = sum(hist.values())
    if total == 0:
        return 0.0
    rank = q * (total - 1)
    seen = 0
    for value in sorted(hist):
        seen += hist[value]
        if seen > rank:
            return float(value)
    return float(max(hist))


def merge_tenant_stats(into: Dict[str, Dict], part: Dict[str, Dict]):
    """Fold one admission queue's per-tenant ledgers into an aggregate
    (counters add, histograms add, priority keeps the first seen)."""
    for tid, st in part.items():
        agg = into.setdefault(tid, {
            "priority": st.get("priority", 1), "admitted": 0, "served": 0,
            "shed": 0, "queued": 0, "in_flight": 0, "shed_reasons": {},
            "latency_hist": {}})
        for k in ("admitted", "served", "shed", "queued", "in_flight"):
            agg[k] += st.get(k, 0)
        for r, n in st.get("shed_reasons", {}).items():
            agg["shed_reasons"][r] = agg["shed_reasons"].get(r, 0) + n
        for v, n in st.get("latency_hist", {}).items():
            agg["latency_hist"][v] = agg["latency_hist"].get(v, 0) + n
    return into
