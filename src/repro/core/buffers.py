"""Stream buffers: the unit of data flowing through pipelines.

A ``StreamBuffer`` mirrors a GstBuffer: tensor payload(s) + presentation
timestamp (pts, nanoseconds) + metadata dict (client-id tags, topic, etc.).
Buffers are JAX pytrees so whole pipelines jit/vmap over them.

FLEXIBLE frames additionally carry a ``FlexHeader`` per tensor — the
per-frame schema header of the paper's dynamic format.  SPARSE frames carry
``SparsePayload`` COO triples produced by ``tensor_sparse_enc``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import MAX_RANK, TensorFormat, TensorSpec, dtype_to_tag, tag_to_dtype

__all__ = ["FlexHeader", "Quant8Payload", "SparsePayload", "StreamBuffer",
           "flex_wrap", "flex_unwrap", "stack_buffers", "unstack_buffers",
           "structure_key"]


def structure_key(tree) -> Tuple:
    """Hashable (treedef, leaf shapes/dtypes) key: two pytrees with equal
    keys stack into one batch (same structure AND same trace signature).
    The grouping key of the query batcher, the scheduler's codec rounds,
    and the pub/sub burst decoder."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (treedef, tuple((getattr(l, "shape", ()),
                            str(getattr(l, "dtype", type(l))))
                           for l in leaves))


@jax.tree_util.register_pytree_node_class
@dataclass
class FlexHeader:
    """Per-frame dynamic-schema header (dims padded to MAX_RANK, dtype tag,
    number of valid elements)."""

    dims: jnp.ndarray      # int32[MAX_RANK]
    dtype_tag: jnp.ndarray  # int32 scalar
    valid: jnp.ndarray     # int32 scalar, number of valid elements

    def tree_flatten(self):
        return (self.dims, self.dtype_tag, self.valid), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclass
class SparsePayload:
    """Fixed-capacity COO: values[max_nnz], flat indices[max_nnz], nnz count."""

    values: jnp.ndarray   # [max_nnz] dtype of source
    indices: jnp.ndarray  # int32[max_nnz] flattened coordinates
    nnz: jnp.ndarray      # int32 scalar
    dense_shape: Tuple[int, ...] = field(default=())  # static aux

    def tree_flatten(self):
        return (self.values, self.indices, self.nnz), self.dense_shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, dense_shape=aux)

    @property
    def wire_nbytes(self) -> int:
        """Bytes actually transmitted (capacity-bounded COO framing)."""
        return int(self.values.size * self.values.dtype.itemsize
                   + self.indices.size * 4 + 4)


@jax.tree_util.register_pytree_node_class
@dataclass
class Quant8Payload:
    """quant8 wire form: int8 tiles + per-(32,128)-tile f32 scales.

    A proper pytree (arrays as children, framing header as static aux) so
    WIRE buffers trace through jitted serving — the fused batched wire path
    decodes requests and re-encodes answers inside one compiled dispatch.
    ``__getitem__`` keeps the legacy dict-style field access."""

    q: jnp.ndarray        # int8 [Mp, Np] padded tile layout
    scale: jnp.ndarray    # f32  [Mp/32, Np/128]
    dtype: str = "float32"                       # static aux: source dtype
    shape: Tuple[int, ...] = field(default=())   # static aux: source shape
    view2d: Tuple[int, int] = (1, 1)             # static aux: logical 2d view

    def tree_flatten(self):
        return (self.q, self.scale), (self.dtype, self.shape, self.view2d)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, dtype=aux[0], shape=aux[1], view2d=aux[2])

    def __getitem__(self, key):
        return getattr(self, key)

    @property
    def wire_nbytes(self) -> int:
        """Bytes actually transmitted: 1 per LOGICAL element + 4 per scale
        (the padded tile layout is a kernel-side detail, not wire format).
        Static — derivable with no device sync, even on traced payloads."""
        n = 1
        for d in self.shape:
            n *= int(d)
        return n + int(np.prod(self.scale.shape)) * 4


@jax.tree_util.register_pytree_node_class
@dataclass
class StreamBuffer:
    """One frame on a pad. ``tensors`` maps 1:1 onto the pad caps' TensorSpecs.

    ``pts`` is the presentation timestamp in ns relative to the owning
    pipeline's base time (GStreamer running-time); ``meta`` is a *static*
    python dict (topic, client_id routing tags, sync info) — it is aux data,
    not traced.
    """

    tensors: Tuple[Any, ...]                 # arrays / SparsePayload
    pts: jnp.ndarray = None                  # int64 ns scalar
    headers: Optional[Tuple[FlexHeader, ...]] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.pts is None:
            self.pts = jnp.int64(0) if jax.config.jax_enable_x64 else jnp.int32(0)

    def tree_flatten(self):
        return (self.tensors, self.pts, self.headers), tuple(sorted(self.meta.items()))

    @classmethod
    def tree_unflatten(cls, aux, children):
        tensors, pts, headers = children
        return cls(tensors=tensors, pts=pts, headers=headers, meta=dict(aux))

    # -- convenience ---------------------------------------------------------
    @property
    def tensor(self):
        assert len(self.tensors) == 1, "buffer has multiple tensors"
        return self.tensors[0]

    def with_(self, **kw) -> "StreamBuffer":
        d = dict(tensors=self.tensors, pts=self.pts, headers=self.headers)
        d.update(kw)
        if "meta" not in kw:
            d["meta"] = dict(self.meta)
        return StreamBuffer(**d)

    def nbytes(self) -> int:
        n = 0
        for t in self.tensors:
            if isinstance(t, (SparsePayload, Quant8Payload)):
                n += t.wire_nbytes
            else:
                n += t.size * t.dtype.itemsize
        return n


def stack_buffers(bufs) -> Any:
    """Stack N structurally identical pytrees (StreamBuffers, outputs dicts)
    along a new leading axis — the frame axis a burst ``step_n`` scans over.

    All items must share one treedef (same tensor count, headers, *and*
    static meta); raises ``ValueError`` on mismatch so callers can fall back
    to per-frame stepping.
    """
    bufs = list(bufs)
    if not bufs:
        raise ValueError("stack_buffers needs at least one buffer")
    ref = jax.tree_util.tree_structure(bufs[0])
    for b in bufs[1:]:
        td = jax.tree_util.tree_structure(b)
        if td != ref:
            raise ValueError(
                f"cannot stack buffers with differing structure: {ref} vs {td}")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *bufs)


def unstack_buffers(stacked, n: Optional[int] = None) -> list:
    """Inverse of :func:`stack_buffers`: split a leading frame axis back into
    a list of per-frame pytrees (e.g. to replay captured sink frames)."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    if n is None:
        if not leaves:
            raise ValueError("cannot infer burst length from a leafless tree")
        n = int(leaves[0].shape[0])
    return [treedef.unflatten([leaf[i] for leaf in leaves])
            for i in range(n)]


def flex_wrap(x: jnp.ndarray, capacity: int) -> Tuple[jnp.ndarray, FlexHeader]:
    """Encode array `x` into a FLEXIBLE frame of element-capacity `capacity`.

    The payload is a flat padded vector; the header records true dims/dtype.
    Shapes stay static (capacity), contents vary per frame — the paper's
    dynamic schema realized under XLA's static-shape constraint.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    if n > capacity:
        raise ValueError(f"frame ({n} elems) exceeds flexible capacity {capacity}")
    payload = jnp.zeros((capacity,), dtype=x.dtype).at[:n].set(flat)
    dims = np.ones((MAX_RANK,), np.int32)
    dims[: x.ndim] = x.shape
    hdr = FlexHeader(
        dims=jnp.asarray(dims),
        dtype_tag=jnp.int32(dtype_to_tag(x.dtype)),
        valid=jnp.int32(n),
    )
    return payload, hdr


def flex_unwrap(payload: jnp.ndarray, header: FlexHeader,
                static_shape: Optional[Tuple[int, ...]] = None) -> jnp.ndarray:
    """Decode a FLEXIBLE frame. If the consumer knows the shape statically
    (downstream caps), pass ``static_shape`` to get a strongly-shaped array;
    otherwise returns the padded flat payload (the consumer must honour
    ``header.valid``)."""
    if static_shape is not None:
        n = int(np.prod(static_shape))
        return payload[:n].reshape(static_shape)
    return payload
