"""Tensor stream data types — the ``other/tensors`` media type (paper §4.1).

NNStreamer extends GStreamer caps with a tensor media type whose ``format``
field is one of ``static``, ``flexible`` (dynamic schema: every frame carries a
header declaring dims/dtype) or ``sparse`` (COO coordinate list).  XLA needs
static shapes, so the TPU-native realization is:

* STATIC   — plain array, schema fixed at caps-negotiation time.
* FLEXIBLE — max-capacity padded array + per-frame header (ndim, dims, dtype
  tag, valid element count) carried as sideband arrays in the same buffer.
* SPARSE   — fixed-capacity COO triple (values, indices, nnz counter); the
  binary layout is *not* consumable by ordinary tensor elements, exactly as in
  the paper, so ``tensor_sparse_enc``/``tensor_sparse_dec`` convert explicitly.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "TensorFormat", "TensorSpec", "Caps", "CapsError",
    "DTYPE_TAGS", "dtype_to_tag", "tag_to_dtype",
]


class TensorFormat(enum.Enum):
    STATIC = "static"
    FLEXIBLE = "flexible"
    SPARSE = "sparse"


# Stable on-the-wire dtype tags (NNStreamer's tensor_typedef analogue).
DTYPE_TAGS: Tuple[str, ...] = (
    "int8", "uint8", "int16", "uint16", "int32", "uint32",
    "int64", "uint64", "float16", "float32", "float64", "bfloat16",
)


def dtype_to_tag(dtype) -> int:
    name = jnp.dtype(dtype).name if not isinstance(dtype, str) else dtype
    try:
        return DTYPE_TAGS.index(name)
    except ValueError as e:
        raise CapsError(f"unsupported stream dtype {name!r}") from e


def tag_to_dtype(tag: int):
    return jnp.dtype(DTYPE_TAGS[int(tag)])


class CapsError(ValueError):
    """Raised when caps negotiation between two pads fails (link-time error)."""


# NNStreamer limits tensors to rank<=4 on the wire ("4:20:1:1" style dims).
MAX_RANK = 4


@dataclass(frozen=True)
class TensorSpec:
    """Schema of one tensor in a stream frame.

    ``shape`` is the *frame* shape (no batch dim — a frame is one sample, the
    pipeline may carry batched frames by making the leading dim explicit).
    For FLEXIBLE, ``shape`` is the maximum capacity; actual dims live in the
    per-frame header.  For SPARSE, ``shape`` is the dense logical shape and
    ``max_nnz`` bounds the coordinate list.
    """

    shape: Tuple[int, ...]
    dtype: str = "float32"
    format: TensorFormat = TensorFormat.STATIC
    max_nnz: Optional[int] = None

    def __post_init__(self):
        if len(self.shape) > MAX_RANK:
            raise CapsError(f"rank {len(self.shape)} > {MAX_RANK}: {self.shape}")
        if self.format == TensorFormat.SPARSE and self.max_nnz is None:
            object.__setattr__(self, "max_nnz", int(np.prod(self.shape)))
        dtype_to_tag(self.dtype)  # validate

    @property
    def nelem(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return self.nelem * jnp.dtype(self.dtype).itemsize

    def with_format(self, fmt: TensorFormat) -> "TensorSpec":
        return replace(self, format=fmt)

    def compatible(self, other: "TensorSpec") -> bool:
        """Can a producer of `self` feed a consumer expecting `other`?"""
        if self.format != other.format:
            return False
        if self.format == TensorFormat.FLEXIBLE:
            # flexible: capacity must fit, dtype checked per-frame at run time
            return self.nelem <= other.nelem
        if self.dtype != other.dtype:
            return False
        if self.format == TensorFormat.SPARSE:
            return self.shape == other.shape and self.max_nnz <= (other.max_nnz or 0)
        return self.shape == other.shape

    def describe(self) -> str:
        dims = ":".join(str(d) for d in self.shape) or "1"
        s = f"{dims},{self.dtype}"
        if self.format != TensorFormat.STATIC:
            s += f",format={self.format.value}"
        return s


@dataclass(frozen=True)
class Caps:
    """GStreamer-caps analogue for a pad: media type + per-tensor schemas.

    ``media`` mirrors the paper's MIME strings: "other/tensors",
    "other/flexbuf" (schemaless third-party serialization), "video/x-raw",
    "any" (ANY caps for pass-through elements).
    """

    media: str = "other/tensors"
    tensors: Tuple[TensorSpec, ...] = field(default_factory=tuple)

    ANY: "Caps" = None  # set below

    @property
    def num_tensors(self) -> int:
        return len(self.tensors)

    def is_any(self) -> bool:
        return self.media == "any"

    def intersect(self, other: "Caps") -> "Caps":
        """Link-time negotiation: producer caps ∩ consumer template."""
        if self.is_any():
            return other
        if other.is_any():
            return self
        if self.media != other.media:
            raise CapsError(f"media mismatch: {self.media} vs {other.media}")
        if other.tensors and self.tensors:
            if len(self.tensors) != len(other.tensors):
                raise CapsError(
                    f"num_tensors mismatch: {len(self.tensors)} vs {len(other.tensors)}")
            for i, (a, b) in enumerate(zip(self.tensors, other.tensors)):
                if not a.compatible(b):
                    raise CapsError(
                        f"tensor {i} incompatible: {a.describe()} vs {b.describe()}")
            return self
        return self if self.tensors else other

    def describe(self) -> str:
        if self.is_any():
            return "ANY"
        parts = [self.media, f"num_tensors={self.num_tensors}"]
        parts += [t.describe() for t in self.tensors]
        return ", ".join(parts)


Caps.ANY = Caps(media="any")
