"""Stock pipeline elements — the NNStreamer/GStreamer element set used by the
paper's examples (Listings 1 & 2): converters, transforms, NN filters,
decoders, mux/demux, tee, queue, compositor, tensor_if, sparse enc/dec.

All hot-path math is jnp (jit-safe); properties are static strings parsed at
construction, exactly like gst-launch property strings.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .buffers import FlexHeader, SparsePayload, StreamBuffer, flex_wrap, flex_unwrap
from .element import Element, PipelineContext, register_element
from .formats import Caps, CapsError, TensorFormat, TensorSpec

# ---------------------------------------------------------------------------
# Sources / sinks
# ---------------------------------------------------------------------------


@register_element("appsrc")
class AppSrc(Element):
    """Application-fed source: the pipeline step receives its frame from the
    caller (Pipeline.step inputs dict, keyed by element name)."""

    n_sink_pads = 0

    def __init__(self, name=None, caps: Optional[Caps] = None, **props):
        super().__init__(name=name, **props)
        self.declared_caps = caps or Caps.ANY

    def negotiate(self, in_caps):
        return [self.declared_caps]

    def apply(self, params, inputs, ctx=None):
        return list(inputs)  # pipeline injects the external frame as inputs[0]


@register_element("testsrc")
class TestSrc(Element):
    """videotestsrc analogue: deterministic synthetic frames from the step
    counter (kept in state), so examples run with no camera."""

    n_sink_pads = 0

    def __init__(self, name=None, width=64, height=48, channels=3, **props):
        super().__init__(name=name, **props)
        self.shape = (int(height), int(width), int(channels))

    def negotiate(self, in_caps):
        return [Caps(media="video/x-raw",
                     tensors=(TensorSpec(self.shape, "uint8"),))]

    def init_state(self):
        return {"frame": jnp.int32(0)}

    def apply(self, params, inputs, ctx: PipelineContext = None):
        i = ctx.get_state(self.name)["frame"]
        h, w, c = self.shape
        yy = jnp.arange(h, dtype=jnp.int32)[:, None, None]
        xx = jnp.arange(w, dtype=jnp.int32)[None, :, None]
        cc = jnp.arange(c, dtype=jnp.int32)[None, None, :]
        frame = ((yy * 3 + xx * 5 + cc * 17 + i * 7) % 256).astype(jnp.uint8)
        ctx.set_state(self.name, {"frame": i + 1})
        pts = (i.astype(jnp.int32)) * jnp.int32(16_666_667 // 1000)  # ~60Hz in µs
        return [StreamBuffer(tensors=(frame,), pts=pts)]


@register_element("appsink")
class AppSink(Element):
    """Terminal sink: Pipeline.step returns its input buffer keyed by name."""

    n_src_pads = 0

    def apply(self, params, inputs, ctx=None):
        return list(inputs)


@register_element("fakesink")
class FakeSink(AppSink):
    pass


@register_element("capsfilter")
class CapsFilter(Element):
    """Caps assertion element (the `video/x-raw,width=300,...` strings in
    gst-launch lines)."""

    def __init__(self, name=None, caps: Caps = None, **props):
        super().__init__(name=name, **props)
        self.filter_caps = caps or Caps.ANY

    def negotiate(self, in_caps):
        return [in_caps[0].intersect(self.filter_caps)]

    def apply(self, params, inputs, ctx=None):
        return list(inputs)


# ---------------------------------------------------------------------------
# Video helpers (enough to express the paper's example pipelines)
# ---------------------------------------------------------------------------


@register_element("videoconvert")
class VideoConvert(Element):
    def apply(self, params, inputs, ctx=None):
        return list(inputs)


@register_element("videoscale")
class VideoScale(Element):
    """Combined with a downstream capsfilter this resizes; standalone it is
    pass-through (as in GStreamer, the scale target comes from caps)."""

    def __init__(self, name=None, width=None, height=None, **props):
        super().__init__(name=name, **props)
        self.target = (int(height), int(width)) if width and height else None

    def negotiate(self, in_caps):
        if self.target is None:
            return [in_caps[0]]
        src = in_caps[0].tensors[0]
        h, w = self.target
        c = src.shape[-1] if len(src.shape) == 3 else 1
        return [Caps(media="video/x-raw", tensors=(TensorSpec((h, w, c), src.dtype),))]

    def apply(self, params, inputs, ctx=None):
        if self.target is None:
            return list(inputs)
        buf = inputs[0]
        x = buf.tensor
        h, w = self.target
        y = jax.image.resize(x.astype(jnp.float32), (h, w, x.shape[-1]), "bilinear")
        return [buf.with_(tensors=(y.astype(x.dtype),))]


@register_element("compositor")
class Compositor(Element):
    """Overlay N video frames by zorder; xpos/ypos offsets per sink pad
    (mix.sink_0::xpos=... in Listing 2)."""

    n_sink_pads = None  # request pads

    def __init__(self, name=None, **props):
        super().__init__(name=name, **props)
        self.pad_props = {}  # pad index -> dict

    def set_pad_prop(self, pad: int, key: str, val):
        self.pad_props.setdefault(pad, {})[key] = int(val)

    def negotiate(self, in_caps):
        return [in_caps[0]]

    def apply(self, params, inputs, ctx=None):
        base = inputs[0].tensor.astype(jnp.float32)
        order = sorted(range(len(inputs)),
                       key=lambda i: self.pad_props.get(i, {}).get("zorder", 0))
        h, w = base.shape[0], base.shape[1]
        canvas = jnp.zeros_like(base)
        for i in order:
            frame = inputs[i].tensor.astype(jnp.float32)
            xpos = self.pad_props.get(i, {}).get("xpos", 0)
            ypos = self.pad_props.get(i, {}).get("ypos", 0)
            fh = min(frame.shape[0], h - ypos)
            fw = min(frame.shape[1], w - xpos)
            if fh <= 0 or fw <= 0:
                continue
            canvas = jax.lax.dynamic_update_slice(
                canvas, frame[:fh, :fw], (ypos, xpos, 0))
        out = canvas.astype(inputs[0].tensor.dtype)
        return [inputs[0].with_(tensors=(out,))]


# ---------------------------------------------------------------------------
# Tensor elements
# ---------------------------------------------------------------------------


@register_element("tensor_converter")
class TensorConverter(Element):
    """media stream -> other/tensors.  video/x-raw HWC frames become a single
    tensor; other/flexbuf (schemaless) frames are decoded via their header."""

    def negotiate(self, in_caps):
        src = in_caps[0]
        if src.media == "other/flexbuf" or (
                src.tensors and src.tensors[0].format == TensorFormat.FLEXIBLE):
            specs = tuple(t.with_format(TensorFormat.FLEXIBLE) for t in src.tensors) \
                or (TensorSpec((0,), "float32", TensorFormat.FLEXIBLE),)
            return [Caps(media="other/tensors", tensors=specs)]
        return [Caps(media="other/tensors", tensors=src.tensors)]

    def apply(self, params, inputs, ctx=None):
        return [inputs[0]]


@register_element("tensor_transform")
class TensorTransform(Element):
    """mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 — the
    TROPT preprocessing string from Listing 1, plus transpose/clamp modes."""

    def __init__(self, name=None, mode="arithmetic", option="", **props):
        super().__init__(name=name, **props)
        self.mode = mode
        self.ops = [tok for tok in str(option).split(",") if tok]

    def _arith(self, x):
        for op in self.ops:
            kind, _, arg = op.partition(":")
            if kind == "typecast":
                x = x.astype(jnp.dtype(arg))
            elif kind == "add":
                x = x + float(arg)
            elif kind == "sub":
                x = x - float(arg)
            elif kind == "mul":
                x = x * float(arg)
            elif kind == "div":
                x = x / float(arg)
            elif kind == "clamp":
                lo, hi = arg.split(":") if ":" in arg else arg.split("-")
                x = jnp.clip(x, float(lo), float(hi))
            else:
                raise ValueError(f"unknown arithmetic op {op!r}")
        return x

    def negotiate(self, in_caps):
        src = in_caps[0]
        if self.mode == "arithmetic" and src.tensors:
            dt = None
            for op in self.ops:
                if op.startswith("typecast:"):
                    dt = op.split(":", 1)[1]
            if dt:
                specs = tuple(TensorSpec(t.shape, dt, t.format, t.max_nnz)
                              for t in src.tensors)
                return [Caps(media="other/tensors", tensors=specs)]
        if self.mode == "transpose" and src.tensors:
            perm = tuple(int(i) for i in self.ops[0].split(":"))
            t0 = src.tensors[0]
            shape = tuple(t0.shape[i] for i in perm)
            return [Caps(media="other/tensors", tensors=(TensorSpec(shape, t0.dtype),))]
        return [src]

    def apply(self, params, inputs, ctx=None):
        buf = inputs[0]
        if self.mode == "arithmetic":
            out = tuple(self._arith(t) for t in buf.tensors)
        elif self.mode == "transpose":
            perm = tuple(int(i) for i in self.ops[0].split(":"))
            out = tuple(jnp.transpose(t, perm) for t in buf.tensors)
        else:
            raise ValueError(f"unknown transform mode {self.mode!r}")
        return [buf.with_(tensors=out)]


# Model registry: tensor_filter model=<key> resolves through here, so pipeline
# descriptions stay strings (like model file paths in NNStreamer).
MODEL_REGISTRY = {}


def register_model(key: str, init_fn: Callable, apply_fn: Callable,
                   out_specs: Sequence[TensorSpec] = ()):
    MODEL_REGISTRY[key] = (init_fn, apply_fn, tuple(out_specs))


@register_element("tensor_filter")
class TensorFilter(Element):
    """The NN inference element.  ``model`` is a registry key (or a callable
    pair passed programmatically).  framework= is recorded for fidelity but on
    TPU there is exactly one framework (XLA)."""

    def __init__(self, name=None, model=None, framework="jax",
                 apply_fn=None, init_fn=None, out_specs=(), **props):
        super().__init__(name=name, framework=framework, **props)
        if apply_fn is not None:
            self._init_fn, self._apply_fn, self._out_specs = init_fn, apply_fn, tuple(out_specs)
            self.model_key = name
        else:
            if model not in MODEL_REGISTRY:
                raise KeyError(f"tensor_filter model={model!r} not registered; "
                               f"known: {sorted(MODEL_REGISTRY)}")
            self._init_fn, self._apply_fn, self._out_specs = MODEL_REGISTRY[model]
            self.model_key = model

    def plan_signature_extra(self):
        # model behavior lives in callables, not attributes; registry models
        # share function objects so identical keys still share executables
        return (self.model_key, id(self._apply_fn), id(self._init_fn))

    def negotiate(self, in_caps):
        if self._out_specs:
            return [Caps(media="other/tensors", tensors=self._out_specs)]
        return [Caps(media="other/tensors")]

    def init_params(self, rng):
        return self._init_fn(rng) if self._init_fn else {}

    def apply(self, params, inputs, ctx=None):
        buf = inputs[0]
        outs = self._apply_fn(params, *buf.tensors)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        return [buf.with_(tensors=tuple(outs))]


@register_element("tensor_decoder")
class TensorDecoder(Element):
    """NN output -> media. Modes: direct_video (tensor -> displayable frame),
    bounding_boxes (SSD-style box overlay), classification (argmax)."""

    def __init__(self, name=None, mode="direct_video", **props):
        super().__init__(name=name, **props)
        self.mode = mode
        self.opts = {k: v for k, v in props.items() if k.startswith("option")}

    def negotiate(self, in_caps):
        if self.mode in ("direct_video", "bounding_boxes"):
            return [Caps(media="video/x-raw")]
        return [Caps(media="other/tensors")]

    def apply(self, params, inputs, ctx=None):
        buf = inputs[0]
        if self.mode == "direct_video":
            x = buf.tensors[0]
            return [buf.with_(tensors=(x.astype(jnp.uint8) if x.dtype != jnp.uint8 else x,))]
        if self.mode == "classification":
            logits = buf.tensors[0]
            return [buf.with_(tensors=(jnp.argmax(logits, axis=-1).astype(jnp.int32),))]
        if self.mode == "bounding_boxes":
            # SSD-style: tensors = (boxes[N,4], scores[N]); rasterize top box
            # outline onto a canvas whose size comes from option4 "W:H".
            wh = self.opts.get("option4", "64:48")
            w, h = (int(v) for v in wh.split(":"))
            boxes, scores = buf.tensors[0], buf.tensors[1]
            best = jnp.argmax(scores)
            box = jnp.clip(boxes[best], 0.0, 1.0)
            x0, y0, x1, y1 = (box[0] * w, box[1] * h, box[2] * w, box[3] * h)
            yy = jnp.arange(h, dtype=jnp.float32)[:, None]
            xx = jnp.arange(w, dtype=jnp.float32)[None, :]
            on_edge = (
                ((jnp.abs(yy - y0) < 1) | (jnp.abs(yy - y1) < 1)) & (xx >= x0) & (xx <= x1)
            ) | (
                ((jnp.abs(xx - x0) < 1) | (jnp.abs(xx - x1) < 1)) & (yy >= y0) & (yy <= y1)
            )
            canvas = jnp.where(on_edge[..., None], 255, 0).astype(jnp.uint8)
            canvas = jnp.broadcast_to(canvas, (h, w, 4))  # RGBA overlay
            return [buf.with_(tensors=(canvas,))]
        raise ValueError(f"unknown decoder mode {self.mode!r}")


@register_element("tensor_mux")
class TensorMux(Element):
    """Merge N single-tensor streams into one multi-tensor buffer, keeping the
    earliest pts (paper §4.2.3: muxing is where cross-device sync matters)."""

    n_sink_pads = None

    def negotiate(self, in_caps):
        specs = tuple(t for c in in_caps for t in c.tensors)
        return [Caps(media="other/tensors", tensors=specs)]

    def apply(self, params, inputs, ctx=None):
        tensors = tuple(t for b in inputs for t in b.tensors)
        pts = inputs[0].pts
        for b in inputs[1:]:
            pts = jnp.minimum(pts, b.pts)
        meta = {}
        for b in inputs:
            meta.update(b.meta)
        return [StreamBuffer(tensors=tensors, pts=pts, meta=meta)]


@register_element("tensor_demux")
class TensorDemux(Element):
    """Split a multi-tensor buffer into per-tensor streams (dmux.src_N)."""

    n_src_pads = None

    def negotiate(self, in_caps):
        return [Caps(media="other/tensors", tensors=(t,)) for t in in_caps[0].tensors]

    def apply(self, params, inputs, ctx=None):
        buf = inputs[0]
        return [buf.with_(tensors=(t,)) for t in buf.tensors]


@register_element("tee")
class Tee(Element):
    """Fan out one stream to N branches."""

    n_src_pads = None

    def negotiate(self, in_caps):
        return [in_caps[0]]  # grown per request pad by Pipeline

    def apply(self, params, inputs, ctx=None):
        return [inputs[0]] * max(1, len(self.out_caps))


@register_element("queue")
class Queue(Element):
    """leaky=2 drops old buffers when full — crucial for parallelism (paper
    §5.1).  In a compiled (synchronous) pipeline a queue is identity; its
    leaky/backpressure semantics live in runtime.scheduler.LatencyQueue."""

    def __init__(self, name=None, leaky=0, **props):
        # gst: max-size-buffers; accept both hyphen/underscore spellings.
        super().__init__(name=name, **props)
        self.leaky = int(leaky)
        self.max_size = int(props.get("max_size_buffers", props.get("max-size-buffers", 2)))

    def apply(self, params, inputs, ctx=None):
        return list(inputs)


@register_element("queue2")
class Queue2(Queue):
    """Used by the paper to inject latency when testing timestamp sync."""


@register_element("tensor_if")
class TensorIf(Element):
    """Conditional gate (Fig. 5 'DETECT' activation path): compares a scalar
    reduction of the control tensor against a threshold and gates the data
    path via lax.cond-compatible select (data still flows; a gate flag in the
    buffer meta plus zeroing keeps it jit-compatible)."""

    n_sink_pads = 1

    def __init__(self, name=None, compared_value="A1", operator="GE",
                 threshold=0.5, **props):
        super().__init__(name=name, **props)
        self.threshold = float(threshold)
        self.operator = operator

    def apply(self, params, inputs, ctx=None):
        buf = inputs[0]
        score = jnp.max(buf.tensors[0].astype(jnp.float32))
        ok = {"GE": score >= self.threshold, "GT": score > self.threshold,
              "LE": score <= self.threshold, "LT": score < self.threshold,
              "EQ": score == self.threshold}[self.operator]
        gated = tuple(jnp.where(ok, t, jnp.zeros_like(t)) for t in buf.tensors)
        out = buf.with_(tensors=gated)
        out.meta["gate_open"] = None  # key presence documents gating; value is traced below
        return [out.with_(tensors=gated + (ok.astype(jnp.int32),))]


# ---------------------------------------------------------------------------
# Sparse conversion elements (paper §4.1) — thin wrappers over the Pallas
# kernels in repro.kernels (imported lazily to keep core importable alone).
# ---------------------------------------------------------------------------


@register_element("tensor_sparse_enc")
class TensorSparseEnc(Element):
    def __init__(self, name=None, max_nnz=None, threshold=0.0, **props):
        super().__init__(name=name, **props)
        self.max_nnz = int(max_nnz) if max_nnz else None
        self.threshold = float(threshold)

    def negotiate(self, in_caps):
        t0 = in_caps[0].tensors[0]
        nnz = self.max_nnz or max(1, t0.nelem // 4)
        return [Caps(media="other/tensors",
                     tensors=(TensorSpec(t0.shape, t0.dtype, TensorFormat.SPARSE, nnz),))]

    def apply(self, params, inputs, ctx=None):
        from ..kernels import ops as kops
        buf = inputs[0]
        x = buf.tensors[0]
        nnz_cap = self.max_nnz or max(1, x.size // 4)
        values, indices, nnz = kops.sparse_enc(x.reshape(-1), nnz_cap, self.threshold)
        sp = SparsePayload(values=values, indices=indices, nnz=nnz,
                           dense_shape=tuple(x.shape))
        return [buf.with_(tensors=(sp,))]


@register_element("tensor_sparse_dec")
class TensorSparseDec(Element):
    def negotiate(self, in_caps):
        t0 = in_caps[0].tensors[0]
        return [Caps(media="other/tensors", tensors=(TensorSpec(t0.shape, t0.dtype),))]

    def apply(self, params, inputs, ctx=None):
        from ..kernels import ops as kops
        buf = inputs[0]
        sp: SparsePayload = buf.tensors[0]
        n = int(np.prod(sp.dense_shape))
        dense = kops.sparse_dec(sp.values, sp.indices, sp.nnz, n)
        return [buf.with_(tensors=(dense.reshape(sp.dense_shape),))]
