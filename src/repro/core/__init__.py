# The paper's primary contribution: stream-pipeline infrastructure for
# among-device AI — pipe-and-filter pipelines over tensor streams, a
# control-plane broker with capability discovery + failover, pub/sub and
# query (inference offloading) protocols, timestamp synchronization, and
# compressed stream codecs.
from .formats import Caps, CapsError, TensorFormat, TensorSpec
from .buffers import (FlexHeader, SparsePayload, StreamBuffer, flex_wrap,
                      flex_unwrap, stack_buffers, unstack_buffers)
from .element import Element, element_factory, register_element, FACTORY
from .elements import register_model, MODEL_REGISTRY
from .pipeline import Pipeline, parse_launch, parse_caps
from .plan import (ExecutionPlan, PendingQuery, clear_executable_cache,
                   executable_cache_info)
from .admission import (AdmissionQueue, QoSConfig, TenantSpec,
                        DEFAULT_TENANT)
from .batching import BatchingPolicy, QueryBatcher
from .broker import Broker, BrokerError, topic_matches
from .pubsub import Channel, MqttSink, MqttSrc, Transport
from .query import (QueryServerEndpoint, QueryTransport, TensorQueryClient,
                    TensorQueryServerSink, TensorQueryServerSrc)
from .modelserve import (ModelServeElement, TokenPromptSrc, SERVE_MODELS,
                         register_serve_model)
from .reconfig import (ReconfigError, ReconfigManager, ReconfigPlan,
                       Reconfiguration)
from .sync import PipelineClock, SimClock, ntp_offset
from . import compression

__all__ = [
    "Caps", "CapsError", "TensorFormat", "TensorSpec",
    "FlexHeader", "SparsePayload", "StreamBuffer", "flex_wrap", "flex_unwrap",
    "stack_buffers", "unstack_buffers",
    "Element", "element_factory", "register_element", "FACTORY",
    "register_model", "MODEL_REGISTRY",
    "Pipeline", "parse_launch", "parse_caps",
    "ExecutionPlan", "PendingQuery", "clear_executable_cache",
    "executable_cache_info",
    "AdmissionQueue", "QoSConfig", "TenantSpec", "DEFAULT_TENANT",
    "BatchingPolicy", "QueryBatcher",
    "Broker", "BrokerError", "topic_matches",
    "Channel", "MqttSink", "MqttSrc", "Transport",
    "QueryServerEndpoint", "QueryTransport", "TensorQueryClient",
    "TensorQueryServerSink", "TensorQueryServerSrc",
    "ModelServeElement", "TokenPromptSrc", "SERVE_MODELS",
    "register_serve_model",
    "ReconfigError", "ReconfigManager", "ReconfigPlan", "Reconfiguration",
    "PipelineClock", "SimClock", "ntp_offset",
    "compression",
]
