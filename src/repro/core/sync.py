"""Timestamp synchronization across pipelines (paper §4.2.3, Fig. 4).

Mechanism (following nnstreamer's synchronization-in-mqtt-elements doc [21]):

* every pipeline has a local monotonic clock and a *base time* (the clock
  value when the pipeline started); buffer pts are relative to base time
  ("running time");
* publishers send ``base_time_utc`` — their base time converted to universal
  time using an NTP-estimated offset between local clock and UTC;
* subscribers convert incoming pts into their own running time:
  ``pts_local = pts_remote + base_time_utc(remote) - base_time_utc(local)``.

Clock skew between devices is what NTP estimates away: the classic
4-timestamp exchange gives offset = ((t1-t0)+(t2-t3))/2.

Everything here is control-plane (python/numpy); the per-buffer rebase is a
scalar add that rides along in the jitted pipeline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from .buffers import StreamBuffer

__all__ = ["SimClock", "ntp_offset", "PipelineClock"]

NS = 1_000_000_000


class SimClock:
    """A device-local clock with skew + jitter against simulated UTC.

    ``true_utc`` is the hidden reference; devices only see ``now()`` =
    true_utc + skew (+ jitter per read).  Tests drive true time explicitly so
    the NTP estimate is verifiable against ground truth.
    """

    def __init__(self, skew_ns: int = 0, jitter_ns: int = 0, seed: int = 0):
        self.skew_ns = int(skew_ns)
        self.jitter_ns = int(jitter_ns)
        self._true = 0
        self._rng = np.random.default_rng(seed)

    def advance(self, ns: int):
        self._true += int(ns)

    @property
    def true_utc(self) -> int:
        return self._true

    def now(self) -> int:
        j = int(self._rng.integers(-self.jitter_ns, self.jitter_ns + 1)) \
            if self.jitter_ns else 0
        return self._true + self.skew_ns + j


def ntp_offset(client: SimClock, server: SimClock,
               network_delay_ns: int = 500_000, rounds: int = 8) -> int:
    """Estimate (server - client) clock offset with NTP's 4-timestamp
    exchange, taking the minimum-delay round (standard NTP filtering)."""
    best: Optional[Tuple[int, int]] = None  # (delay, offset)
    for _ in range(rounds):
        t0 = client.now()
        client.advance(network_delay_ns)
        server.advance(network_delay_ns)
        t1 = server.now()
        t2 = server.now()
        client.advance(network_delay_ns)
        server.advance(network_delay_ns)
        t3 = client.now()
        delay = (t3 - t0) - (t2 - t1)
        offset = ((t1 - t0) + (t2 - t3)) // 2
        if best is None or delay < best[0]:
            best = (delay, offset)
    return best[1]


@dataclass
class PipelineClock:
    """Per-pipeline clock: local SimClock + NTP offset to UTC + base time."""

    clock: SimClock
    utc_offset_ns: int = 0     # estimated (utc - local); NTP-calibrated
    base_time_local: int = 0   # local clock at pipeline start

    def start(self):
        self.base_time_local = self.clock.now()
        return self

    def calibrate(self, reference: SimClock, **kw):
        """NTP against a reference (broker-adjacent NTP server)."""
        self.utc_offset_ns = ntp_offset(self.clock, reference, **kw)
        return self

    def base_time_utc(self) -> int:
        return self.base_time_local + self.utc_offset_ns

    def running_time(self) -> int:
        return self.clock.now() - self.base_time_local

    def rebase(self, buf: StreamBuffer) -> StreamBuffer:
        """Convert a remote buffer's pts into this pipeline's running time."""
        remote_base_utc = buf.meta["base_time_utc"]
        delta = remote_base_utc - self.base_time_utc()
        return buf.with_(pts=buf.pts + delta,
                         meta={k: v for k, v in buf.meta.items()
                               if k != "base_time_utc"})


def max_pairwise_skew(timestamps_ns: List[int]) -> int:
    return int(max(timestamps_ns) - min(timestamps_ns)) if timestamps_ns else 0
