"""Compiled pipeline execution engine.

``ExecutionPlan`` turns a realized :class:`~repro.core.pipeline.Pipeline`
into a *plan*: the topo order and link wiring are flattened once, at
``realize()`` time, into a static slot-indexed schedule, so stepping a frame
no longer re-sorts links or rebuilds dicts per step (the host-side dispatch
cost NNStreamer avoids by compiling the graph once — arXiv 2101.06371).

Three execution tiers, all bitwise-identical to the seed interpreter:

* ``plan.run(params, state, inputs)`` — one frame through the static
  schedule; pure and jittable.
* ``plan.compiled_step()`` — a jitted executable, cached in a process-wide
  registry keyed by the plan's **topology fingerprint** (element configs +
  link wiring + negotiated caps).  Reconnecting a structurally identical
  pipeline after failover reuses the executable and never retraces; per
  fingerprint, XLA's own jit cache covers the (input shapes/dtypes) axis.
* ``plan.step_n(params, state, inputs, n)`` — an N-frame **burst**: one
  ``lax.scan`` dispatch runs the whole DAG N times over stacked
  :class:`StreamBuffer` frames, amortizing Python/jit dispatch to ~1/N per
  frame.  The runtime scheduler uses this to drain queued Channel frames.

Host-impure elements (mqtt sources/sinks) cannot be traced; ``hoist_io=True``
runs the plan in *hoisted* mode: host-driven sources must be injected through
``inputs`` (the scheduler pulls & decodes at host level) and host sinks
capture their input frame into the outputs dict instead of pushing, so the
scheduler can replay the captured frames through the real (impure)
``apply`` after the burst returns.

Donation: compiled executables donate the ``state`` argument when requested
(``donate=True``) or automatically on gpu/tpu backends (``donate=None``) —
state buffers are overwritten in place across frames.  Donation stays off on
CPU where XLA does not implement it (it would only emit warnings).

Mesh sharding (DESIGN.md §4): ``step_n`` and ``serve_batch`` accept a jax
``Mesh``.  When the stacked frame axis divides the mesh's data-axis extent
and the pipeline threads **no cross-frame state** (the state pytree has no
leaves), the burst is laid out along the data axes with ``shard_map``: each
device scans its own contiguous slice of the frame axis with the exact
per-frame program the single-device scan runs, params/state replicated
(``in_specs=P()``), so the answers are bitwise those of single-device
serving.  Stateful plans, indivisible batch sizes, and 1-device meshes fall
back to the single-device scan — sharding never changes semantics, only
where frames execute.  Compiled executables are cached per (fingerprint,
mesh identity): reconnecting after failover with the same mesh never
retraces.

Fused wire path (DESIGN.md §5): ``serve_batch_wire`` serves a batch of
WIRE-form requests — per-request decode, stacked scan, and per-frame
re-encode of the answers all inside ONE jit (``compiled_serve_batch(codec=
...)``), with the codec as a static trace parameter.  The executable-cache
key carries the codec fingerprint, so codec-fused and plain executables
never collide.  ``run_deferred_compiled`` is the client-side counterpart:
pipelines whose only impure elements are their query clients run each
deferred SEGMENT (start → first client, client → client, client → end) as
one jitted dispatch instead of an interpreted per-element walk — bitwise
the interpreted deferral, minus the per-element dispatch overhead that made
batched e2e ticks slower than sequential ones.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .buffers import StreamBuffer
from .element import Element, PipelineContext

__all__ = ["ExecutionPlan", "PendingQuery", "PlanOp",
           "clear_executable_cache", "executable_cache_info"]


class PlanOp:
    """One scheduled element: static wiring resolved to value slots."""

    __slots__ = ("elem", "name", "in_slots", "out_slots", "injectable",
                 "is_sink", "is_host_sink", "is_query_src", "is_query_sink",
                 "is_query_client")

    def __init__(self, elem: Element, in_slots: Tuple[int, ...],
                 out_slots: Tuple[int, ...], injectable: bool,
                 is_sink: bool, is_host_sink: bool):
        self.elem = elem
        self.name = elem.name
        self.in_slots = in_slots
        self.out_slots = out_slots
        self.injectable = injectable
        self.is_sink = is_sink
        self.is_host_sink = is_host_sink
        self.is_query_src = getattr(elem, "is_query_source", False)
        self.is_query_sink = getattr(elem, "is_query_sink", False)
        self.is_query_client = getattr(elem, "is_query_client", False)


# Process-wide executable registry: fingerprint -> (owning plan, jitted fns).
# Two plans with equal fingerprints are behaviorally identical (the
# fingerprint covers element class, static config, wiring and negotiated
# caps), so the first plan's jitted functions serve all of them.
#
# The jitted fns close over the owning plan's element graph, pinning it
# alive; to keep a long-running process that churns through many distinct
# topologies bounded, the registry is LRU-capped — evicting a fingerprint
# only costs a retrace if that topology ever comes back.
_EXEC_CACHE: "OrderedDict[Any, Dict[str, Any]]" = OrderedDict()
_EXEC_CACHE_MAX = 128


def clear_executable_cache():
    _EXEC_CACHE.clear()


def executable_cache_info() -> Dict[str, int]:
    return {"fingerprints": len(_EXEC_CACHE),
            "executables": sum(len(e["fns"]) for e in _EXEC_CACHE.values())}


class ExecutionPlan:
    """Static schedule + executable cache for one realized pipeline."""

    def __init__(self, pipeline):
        from .elements import AppSink, AppSrc  # cycle-free: elements<-element

        order: List[Element] = pipeline._order
        links = pipeline.links
        # slot assignment: every (producer, src_pad) that any link consumes
        slot_of: Dict[Tuple[str, int], int] = {}
        for l in links:
            key = (l.src.name, l.src_pad)
            if key not in slot_of:
                slot_of[key] = len(slot_of)
        self.n_slots = len(slot_of)

        in_links: Dict[str, list] = {e.name: [] for e in order}
        for l in links:
            in_links[l.dst.name].append(l)

        ops: List[PlanOp] = []
        for elem in order:
            lk = sorted(in_links[elem.name], key=lambda l: l.dst_pad)
            in_slots = tuple(slot_of[(l.src.name, l.src_pad)] for l in lk)
            max_pad = max((l.src_pad for l in links if l.src is elem),
                          default=-1)
            out_slots = tuple(slot_of.get((elem.name, p), -1)
                              for p in range(max_pad + 1))
            injectable = isinstance(elem, AppSrc) or \
                getattr(elem, "is_host_source", False)
            ops.append(PlanOp(elem, in_slots, out_slots,
                              injectable=injectable,
                              is_sink=isinstance(elem, AppSink),
                              is_host_sink=getattr(elem, "is_host_sink",
                                                   False)))
        self.ops = ops
        self.host_sources = [op.elem for op in ops
                             if getattr(op.elem, "is_host_source", False)]
        self.host_sinks = [op.elem for op in ops if op.is_host_sink]
        impure = [op.elem for op in ops
                  if getattr(op.elem, "host_impure", False)]
        #: no host-impure elements at all — safe to jit as-is
        self.pure = not impure
        #: every impure element is a hoistable source or terminal sink, so
        #: the DAG between them is traceable and scan-batched bursts apply
        self.burstable = all(
            getattr(e, "is_host_source", False) or
            getattr(e, "is_host_sink", False) for e in impure)
        #: every graph source is host-driven: a burst replays only queued
        #: frames.  A self-driven source (testsrc camera) mixed in would be
        #: fast-forwarded by a burst — fabricating future frames — so the
        #: scheduler must keep such pipelines on the tick cadence.
        self.all_sources_host_driven = bool(self.host_sources) and all(
            getattr(op.elem, "is_host_source", False)
            for op in ops if not op.in_slots)
        # -- query-protocol topology flags (see core/batching.py) -------------
        #: serversrc/serversink pairs of a query *server* pipeline
        self.query_sources = [op.elem for op in ops if op.is_query_src]
        self.query_sinks = [op.elem for op in ops if op.is_query_sink]
        #: pipeline contains tensor_query_client elements — the runtime
        #: scheduler can run it deferred (pause at each client, gather the
        #: request into a server-side micro-batch, resume with the answer)
        self.has_query_clients = any(op.is_query_client for op in ops)
        #: server pipeline whose impure elements are exactly one injectable
        #: serversrc plus capturable serversinks: N decoded requests can be
        #: stacked and served in ONE hoisted `step_n` scan dispatch.  Anything
        #: else (extra impure elements, multiple serversrcs, non-serversrc
        #: graph sources) keeps the sequential one-request-at-a-time path.
        self.query_batchable = (
            len(self.query_sources) == 1 and bool(self.query_sinks)
            and all(getattr(e, "is_query_source", False)
                    or getattr(e, "is_query_sink", False) for e in impure)
            and all(op.is_query_src for op in ops if not op.in_slots))
        #: query-batchable server whose serve element runs a streaming
        #: (autoregressive) workload: decode state is PLAN STATE carried
        #: across ticks, so the dispatch is one stateful `serve_tick` per
        #: runtime tick (continuous batching over state slots) instead of
        #: the stateless stack-scan-split over independent frames
        self.stream_serving = self.query_batchable and any(
            getattr(op.elem, "is_stream_serve", False) for op in ops)
        #: stream-serving pipeline that is ONE STAGE of an among-device
        #: pipeline-parallel chain (DESIGN.md §8): the serve element owns a
        #: contiguous layer slice plus that slice of the slot-stacked
        #: decode cache; (stage, n_stages) is the hop signature — part of
        #: the multi-hop serve fingerprint, so two stages of one chain (or
        #: the same stage of two chains of different depth) never share a
        #: serve_tick executable even when their cache structures agree
        stage_elems = [op.elem for op in ops
                       if getattr(op.elem, "is_stage_serve", False)]
        self.stage_serving = self.stream_serving and bool(stage_elems)
        self.serve_stage = ((stage_elems[0].stage, stage_elems[0].n_stages)
                            if self.stage_serving else None)
        #: op indices of the query clients, in schedule order (the deferred
        #: walk's pause points — static, because topology is static)
        self.client_idxs = tuple(i for i, op in enumerate(ops)
                                 if op.is_query_client)
        #: every impure element is a query client: the segments BETWEEN
        #: pause points are pure and each can run as one jitted dispatch
        #: (run_deferred_compiled) instead of an interpreted walk
        self.deferred_compilable = bool(self.client_idxs) and all(
            getattr(e, "is_query_client", False) for e in impure)
        self.fingerprint = self._fingerprint(order, links)

    @staticmethod
    def _fingerprint(order: List[Element], links) -> Tuple:
        elems = tuple(e.plan_signature() for e in order)
        wiring = tuple((l.src.name, l.src_pad, l.dst.name, l.dst_pad)
                       for l in links)
        return (elems, wiring)

    # -- single-frame execution ------------------------------------------------
    def _exec_ops(self, params: dict, ctx: PipelineContext, vals: List[Any],
                  outputs: Dict[str, StreamBuffer],
                  inputs: Dict[str, StreamBuffer], start: int,
                  hoist_io: bool, hoist_queries: bool, defer_queries: bool
                  ) -> Optional[Tuple[int, StreamBuffer]]:
        """Walk ``ops[start:]`` mutating ``vals``/``outputs``/``ctx``.

        Returns ``None`` when the schedule completes, or ``(op_idx, request)``
        when ``defer_queries=True`` and a query client is reached — the
        caller ships ``request`` to a server batch and later resumes from
        ``op_idx`` with the answer (see :class:`PendingQuery`).
        """
        for idx in range(start, len(self.ops)):
            op = self.ops[idx]
            ins = [vals[s] for s in op.in_slots]
            injectable = op.injectable or (hoist_queries and op.is_query_src)
            if injectable and op.name in inputs:
                ins = [inputs[op.name]]
                if getattr(op.elem, "is_host_source", False) or \
                        (hoist_queries and op.is_query_src):
                    # host-driven source (mqttsrc) or hoisted serversrc: its
                    # apply would pull from the channel; the injected,
                    # already-decoded frame IS the pull — emit it directly
                    if op.out_slots and op.out_slots[0] >= 0:
                        vals[op.out_slots[0]] = ins[0]
                    continue
            elif hoist_io and getattr(op.elem, "is_host_source", False):
                raise ValueError(
                    f"{op.name}: hoisted execution requires an injected "
                    f"input frame for every host-driven source")
            elif hoist_queries and op.is_query_src:
                raise ValueError(
                    f"{op.name}: hoisted query serving requires an injected "
                    f"request frame for every serversrc")
            if (hoist_io and op.is_host_sink) or \
                    (hoist_queries and op.is_query_sink):
                # capture instead of the impure push; the caller replays the
                # captured frame through the element's real apply afterwards
                outputs[op.name] = ins[0]
                continue
            if defer_queries and op.is_query_client:
                return idx, ins[0]
            outs = op.elem.apply(params.get(op.name, {}), ins, ctx)
            for i, o in enumerate(outs):
                if i < len(op.out_slots) and op.out_slots[i] >= 0:
                    vals[op.out_slots[i]] = o
            if op.is_sink and outs:
                outputs[op.name] = outs[0]
        return None

    def run(self, params: dict, state: dict,
            inputs: Optional[Dict[str, StreamBuffer]] = None,
            hoist_io: bool = False, hoist_queries: bool = False
            ) -> Tuple[Dict[str, StreamBuffer], dict]:
        """One frame through the static schedule.  Pure (jittable) when the
        pipeline is pure or hoisted (``hoist_io`` with all host sources
        injected; ``hoist_queries`` with the serversrc request injected).
        Semantics match the seed interpreter bitwise."""
        inputs = inputs or {}
        ctx = PipelineContext(state)
        vals: List[Any] = [None] * self.n_slots
        outputs: Dict[str, StreamBuffer] = {}
        self._exec_ops(params, ctx, vals, outputs, inputs, 0,
                       hoist_io, hoist_queries, defer_queries=False)
        return outputs, ctx.next_state

    def run_deferred(self, params: dict, state: dict,
                     inputs: Optional[Dict[str, StreamBuffer]] = None):
        """Start one frame, pausing at the first un-answered query client.

        Returns ``(outputs, next_state)`` when the pipeline has no query
        client on this frame's path, or a :class:`PendingQuery` whose
        ``request`` is the buffer the client was about to send.  The caller
        performs the send/serve/receive at host level (the runtime
        scheduler's queue-gather-flush) and calls ``resume(answer)``.
        Interpreted host-level execution only — never jit this path."""
        inputs = inputs or {}
        ctx = PipelineContext(state)
        vals: List[Any] = [None] * self.n_slots
        outputs: Dict[str, StreamBuffer] = {}
        res = self._exec_ops(params, ctx, vals, outputs, inputs, 0,
                             hoist_io=False, hoist_queries=False,
                             defer_queries=True)
        if res is None:
            return outputs, ctx.next_state
        return PendingQuery(self, params, inputs, ctx, vals, outputs, *res)

    # -- burst execution -------------------------------------------------------
    @staticmethod
    def shardable_batch(n: int, state: dict, mesh) -> bool:
        """True when an ``n``-frame burst can be laid out along ``mesh``'s
        data axes without changing semantics: more than one data-axis device,
        a frame axis that tiles them evenly, and NO cross-frame state (a
        state pytree with leaves must thread through the scan in FIFO order —
        splitting it across devices would change what frame ``i`` sees).
        The decision is trace-static (shapes + pytree structure only), so the
        host-side caller and the jitted executable always agree on it."""
        if mesh is None or n <= 0:
            return False
        if jax.tree_util.tree_leaves(state):
            return False
        from ..launch.mesh import data_axis_size
        dsize = data_axis_size(mesh)
        return dsize > 1 and n % dsize == 0

    def _step_n_sharded(self, params: dict, state: dict, inputs, mesh,
                        hoist_io: bool, hoist_queries: bool
                        ) -> Tuple[Dict[str, StreamBuffer], dict]:
        """Among-device burst: shard the stacked frame axis along the mesh's
        data axes; every device runs the single-device scan program over its
        own contiguous frame slice (params/state replicated), so frame ``i``
        is bitwise what the single-device scan produces.  Only called when
        :meth:`shardable_batch` holds — state has no leaves, hence no carry
        crosses the shard boundary."""
        from jax.sharding import PartitionSpec as P
        from ..jaxcompat import shard_map
        from ..launch.mesh import batch_spec, data_axis_size
        dspec = P(batch_spec(mesh))
        n_local = (jax.tree_util.tree_leaves(inputs)[0].shape[0]
                   // data_axis_size(mesh))

        def local_scan(p, s, local):
            if n_local == 1:
                # one frame per device: run the DAG directly — a length-1
                # lax.scan drags while-loop/dynamic-slice machinery into
                # every partition for nothing (measured ~2x the dispatch)
                frame = jax.tree_util.tree_map(lambda l: l[0], local)
                outs, _ = self.run(p, s, frame, hoist_io=hoist_io,
                                   hoist_queries=hoist_queries)
                return jax.tree_util.tree_map(lambda l: l[None], outs)

            def body(carry, x):
                outs, nxt = self.run(p, carry, x, hoist_io=hoist_io,
                                     hoist_queries=hoist_queries)
                return nxt, outs
            _, outs = lax.scan(body, s, local)
            return outs

        outs = shard_map(local_scan, mesh=mesh,
                         in_specs=(P(), P(), dspec),
                         out_specs=dspec)(params, state, inputs)
        # no state leaves: the scan carry is pure structure, returned as-is
        return outs, dict(state)

    def step_n(self, params: dict, state: dict,
               inputs: Optional[Dict[str, StreamBuffer]] = None,
               n: Optional[int] = None, hoist_io: bool = False,
               hoist_queries: bool = False, mesh=None
               ) -> Tuple[Dict[str, StreamBuffer], dict]:
        """Run an N-frame burst with a single ``lax.scan`` dispatch.

        ``inputs`` maps source names to *stacked* StreamBuffers (leading axis
        N, see :func:`repro.core.buffers.stack_buffers`); self-driven
        pipelines pass ``n`` instead.  Returns (stacked outputs, final
        state) — frame ``i`` of the outputs equals what ``run`` would have
        produced on the ``i``-th sequential call.

        With ``mesh``, hoisted bursts whose frame axis tiles the mesh's data
        axes and whose state pytree is leafless run sharded
        (:meth:`_step_n_sharded`); anything else falls back to the
        single-device scan unchanged.
        """
        if inputs is None and n is None:
            raise ValueError("step_n needs stacked `inputs` or a length `n`")
        if mesh is not None and inputs is not None:
            leaves = jax.tree_util.tree_leaves(inputs)
            nn = int(leaves[0].shape[0]) if leaves else 0
            if self.shardable_batch(nn, state, mesh):
                return self._step_n_sharded(params, state, inputs, mesh,
                                            hoist_io, hoist_queries)

        def body(carry, x):
            outs, nxt = self.run(params, carry, x, hoist_io=hoist_io,
                                 hoist_queries=hoist_queries)
            return nxt, outs

        final_state, outs = lax.scan(body, state, inputs, length=n)
        return outs, final_state

    def serve_batch(self, params: dict, state: dict, frames: Tuple,
                    mesh=None) -> Tuple[Tuple, dict]:
        """Serve N query requests as one traced unit: stack the per-frame
        input dicts, scan the hoisted DAG, and split the outputs back into
        per-frame pytrees — all INSIDE the trace, so a compiled batch costs
        one host dispatch total (eager stack/unstack would pay one dispatch
        per leaf per frame, which is the overhead batching exists to kill).

        ``frames`` is a tuple of ``{source_name: StreamBuffer}`` dicts with
        identical pytree structure.  Returns (tuple of per-frame outputs,
        final state); frame ``i`` equals the ``i``-th sequential hoisted
        ``run``.

        With ``mesh``, batches satisfying :meth:`shardable_batch` serve
        sharded along the mesh's data axes (one frame slice per device);
        everything else — including every stateful plan — keeps the
        single-device scan, so batch composition and placement never change
        any client's numerics."""
        n = len(frames)
        if n == 1:  # never shardable: 1 frame cannot tile >1 devices
            outs, final = self.run(params, state, frames[0],
                                   hoist_io=True, hoist_queries=True)
            return (outs,), final
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *frames)
        outs, final = self.step_n(params, state, stacked,
                                  hoist_io=True, hoist_queries=True,
                                  mesh=mesh)
        per = tuple(jax.tree_util.tree_map(lambda l, _i=i: l[_i], outs)
                    for i in range(n))
        return per, final

    def serve_batch_wire(self, params: dict, state: dict, wire_frames: Tuple,
                         codec: str) -> Tuple[Tuple, dict]:
        """Codec-fused :meth:`serve_batch`: the whole wire path of a batch —
        per-request decode, stacked scan, per-frame re-encode of the query
        answers — in one traced unit (DESIGN.md §5).

        ``wire_frames`` is a tuple of ``{serversrc_name: wire StreamBuffer}``
        dicts with identical pytree structure and one shared static
        ``codec`` (the batcher groups by codec exactly like it groups by
        structure).  Returns ``((stacked_wire_answers, stacked_app_outs,
        dropped), final_state)``:

        * ``stacked_wire_answers`` — ``{sink_name: wire StreamBuffer}`` with
          a leading frame axis; frame ``i`` of every payload is bitwise
          what the eager path (decode → serve → ``encode``) produces;
        * ``stacked_app_outs`` — non-query-sink outputs, stacked;
        * ``dropped`` — ``{sink_name: int32 [tensors, frames]}`` deferred
          sparse truncation counts, PER SINK (empty unless the codec is
          sparse): the caller syncs ONCE per flush and stamps each sink's
          own ``meta["sparse_dropped"]`` / codec stats host-side — the
          per-buffer loss signal the eager serversink encode produces,
          without its one sync per tensor.

        Answers stay stacked at the jit boundary (the PR-4 lesson: per-frame
        outputs cost a dispatch per leaf per frame; the host fetches the
        stack once and splits as numpy)."""
        from . import compression as comp
        n = len(wire_frames)
        src = self.query_sources[0].name
        stacked_wire = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[f[src] for f in wire_frames])
        dense = comp.decode_stacked(stacked_wire, codec)
        if n == 1:
            # one frame: run the DAG directly (a length-1 scan drags
            # while-loop machinery into the trace for nothing — the same
            # choice serve_batch makes)
            frame = jax.tree_util.tree_map(lambda l: l[0], dense)
            outs, final = self.run(params, state, {src: frame},
                                   hoist_io=True, hoist_queries=True)
            outs = jax.tree_util.tree_map(lambda l: l[None], outs)
        else:
            outs, final = self.step_n(params, state, {src: dense},
                                      hoist_io=True, hoist_queries=True)
        sink_names = {e.name for e in self.query_sinks}
        wire_outs: Dict[str, StreamBuffer] = {}
        app_outs: Dict[str, StreamBuffer] = {}
        dropped: Dict[str, Any] = {}
        for name, buf in outs.items():
            if name in sink_names:
                w, drp = comp.encode_stacked(buf, codec)
                wire_outs[name] = w
                if drp is not None:
                    dropped[name] = drp
            else:
                app_outs[name] = buf
        return (wire_outs, app_outs, dropped), final

    # -- compiled executables --------------------------------------------------
    def _cache(self) -> Dict[str, Any]:
        ent = _EXEC_CACHE.get(self.fingerprint)
        if ent is None:
            ent = {"fns": {}}
            _EXEC_CACHE[self.fingerprint] = ent
            while len(_EXEC_CACHE) > _EXEC_CACHE_MAX:
                _EXEC_CACHE.popitem(last=False)
        else:
            _EXEC_CACHE.move_to_end(self.fingerprint)
        return ent

    @staticmethod
    def _resolve_donate(donate: Optional[bool]) -> bool:
        if donate is None:
            return jax.default_backend() in ("gpu", "tpu")
        return bool(donate)

    def compiled_step(self, donate: Optional[bool] = None) -> Callable:
        """Jitted single-frame step ``(params, state, inputs=None) ->
        (outputs, next_state)``, shared across all plans with this
        fingerprint."""
        donate = self._resolve_donate(donate)
        fns = self._cache()["fns"]
        key = ("step", donate)
        if key not in fns:
            fns[key] = jax.jit(self.run,
                               donate_argnums=(1,) if donate else ())
        return fns[key]

    @staticmethod
    def _mesh_key(mesh):
        from ..launch.mesh import mesh_fingerprint
        return mesh_fingerprint(mesh)

    def compiled_step_n(self, hoist_io: bool = False,
                        hoist_queries: bool = False,
                        donate: Optional[bool] = None, mesh=None) -> Callable:
        """Jitted burst step ``(params, state, inputs=None, n=None) ->
        (stacked outputs, final state)``.  ``n``, ``hoist_io``,
        ``hoist_queries`` and ``mesh`` are static; each distinct burst
        length (= query batch size in hoisted-query serving) traces once and
        is cached thereafter in the fingerprint-keyed registry.  The cache
        key carries the mesh identity (axes, shape, device assignment), so a
        mesh-sharded executable is never confused with the single-device one
        and reconnecting with the same mesh never retraces."""
        donate = self._resolve_donate(donate)
        fns = self._cache()["fns"]
        key = ("step_n", hoist_io, hoist_queries, donate, self._mesh_key(mesh))
        if key not in fns:
            def step_n(params, state, inputs=None, n=None,
                       _self=self, _hoist=hoist_io, _hoistq=hoist_queries,
                       _mesh=mesh):
                return _self.step_n(params, state, inputs, n=n,
                                    hoist_io=_hoist, hoist_queries=_hoistq,
                                    mesh=_mesh)
            fns[key] = jax.jit(step_n, static_argnames=("n",),
                               donate_argnums=(1,) if donate else ())
        return fns[key]

    def compiled_serve_batch(self, donate: Optional[bool] = None,
                             mesh=None, codec: Optional[str] = None
                             ) -> Callable:
        """Jitted :meth:`serve_batch` ``(params, state, frames_tuple) ->
        (per-frame outputs tuple, final state)``.  The batch size lives in
        the input pytree structure, so each distinct size traces once per
        fingerprint and is cached thereafter (the QueryBatcher caps sizes
        at ``max_batch``, keeping the trace set tiny).  ``mesh`` extends the
        cache key exactly like :meth:`compiled_step_n`.

        ``codec`` (static) selects the codec-FUSED executable instead: a
        jitted :meth:`serve_batch_wire` ``(params, state, wire_frames) ->
        ((stacked wire answers, stacked app outs, dropped), final)``.  The
        cache key carries the codec fingerprint, so codec-fused and plain
        executables never collide — and neither do two codecs (quant8 and
        sparse trace different wire pytrees).  Codec fusion composes with
        single-device serving only; mesh placement keeps the PR-4 eager
        wire path (the batcher decides per group).

        The mesh executable moves the stack/split to the HOST (numpy, zero
        XLA dispatches) and keeps the jit boundary stacked-and-sharded:
        per-frame outputs at an SPMD boundary would each pay a cross-device
        gather (measured ~10x the whole serve), whereas one sharded stacked
        output costs a single device_get.  Host-split answers are therefore
        numpy — bitwise the same frames.  Groups the mesh cannot take
        (:meth:`shardable_batch` fails) fall through to the single-device
        executable inside the same callable."""
        donate = self._resolve_donate(donate)
        fns = self._cache()["fns"]
        key = ("serve_batch", donate, self._mesh_key(mesh), codec)
        if key in fns:
            return fns[key]
        if codec is not None:
            if mesh is not None:
                raise ValueError("codec-fused serving is single-device; "
                                 "mesh groups keep the eager wire path")
            def serve_wire(params, state, frames, _self=self, _codec=codec):
                return _self.serve_batch_wire(params, state, frames, _codec)
            fns[key] = jax.jit(serve_wire,
                               donate_argnums=(1,) if donate else ())
            return fns[key]
        if mesh is None:
            def serve_batch(params, state, frames, _self=self):
                return _self.serve_batch(params, state, frames)
            fns[key] = jax.jit(serve_batch,
                               donate_argnums=(1,) if donate else ())
            return fns[key]

        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..launch.mesh import batch_spec
        single = self.compiled_serve_batch(donate=donate, mesh=None)
        step = self.compiled_step_n(hoist_io=True, hoist_queries=True,
                                    donate=donate, mesh=mesh)
        frame_sharding = NamedSharding(mesh, P(batch_spec(mesh)))

        def serve_sharded(params, state, frames, _self=self):
            n = len(frames)
            if not _self.shardable_batch(n, state, mesh):
                return single(params, state, frames)
            import numpy as np
            host = jax.device_get(frames)
            stacked = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *host)
            stacked = jax.device_put(
                stacked, jax.tree_util.tree_map(lambda _: frame_sharding,
                                                stacked))
            outs, final = step(params, state, stacked)
            outs = jax.device_get(outs)
            per = tuple(jax.tree_util.tree_map(lambda l, _i=i: l[_i], outs)
                        for i in range(n))
            return per, final

        fns[key] = serve_sharded
        return fns[key]

    # -- stateful streaming serve ----------------------------------------------
    def _serve_tick_fn(self, donate: bool, state_key) -> Callable:
        """Executable behind :meth:`compiled_serve_tick`, addressable by its
        full cache key so reconfigure warming can replicate it (see
        ``reconfig._warm``)."""
        fns = self._cache()["fns"]
        key = ("serve_tick", donate, self.serve_stage, state_key)
        if key not in fns:
            def serve_tick(params, state, inputs, _self=self):
                return _self.run(params, state, inputs,
                                 hoist_io=True, hoist_queries=True)
            fns[key] = jax.jit(serve_tick,
                               donate_argnums=(1,) if donate else ())
        return fns[key]

    def compiled_serve_tick(self, state: dict,
                            donate: Optional[bool] = None) -> Callable:
        """Jitted stateful decode tick ``(params, state, inputs) ->
        (outputs, next_state)`` for a ``stream_serving`` plan.

        Unlike :meth:`compiled_serve_batch` — which stacks N independent
        stateless frames — the batch here lives INSIDE the plan state (slot
        axis of the KV/SSM cache plus an active-slot mask), so requests join
        and leave mid-generation without changing the traced program.  The
        cache key therefore carries a distinct fingerprint axis: the state
        pytree's :func:`structure_key` (treedef + leaf shapes/dtypes, which
        covers both the cache layout and the active-slot mask).  Two serve
        configurations with different slot counts or cache structures never
        collide; re-dispatching the same structure never retraces."""
        from .buffers import structure_key
        return self._serve_tick_fn(self._resolve_donate(donate),
                                   structure_key(state))

    # -- compiled deferred segments --------------------------------------------
    def _next_client(self, after: int) -> Optional[int]:
        for i in self.client_idxs:
            if i > after:
                return i
        return None

    def _live_slots(self, pause_idx: int) -> Tuple[int, ...]:
        """Value slots that must survive a pause at ``pause_idx``: written
        by an op before the pause AND read by an op after it.  Static —
        the schedule is topology-fixed — so segment jits carry exactly the
        live values and nothing else."""
        written = {s for op in self.ops[:pause_idx]
                   for s in op.out_slots if s >= 0}
        read = {s for op in self.ops[pause_idx + 1:] for s in op.in_slots}
        return tuple(sorted(written & read))

    def _deferred_segment(self, start: Optional[int]) -> Callable:
        """Pure segment of the deferred walk as one traceable function:
        ``start=None`` runs op 0 → the first query client; ``start=j``
        injects the answer for the client at op ``j`` and runs to the next
        client or the end.  Where the segment stops is static (topology),
        so the caller knows the return shape without looking:

        * pauses again → ``(request, live_vals, outputs, next_state)``
        * completes    → ``(outputs, next_state)``
        """
        def seg(params, state, next_state, live_vals, answer, inputs):
            ctx = PipelineContext(state)
            ctx.next_state = dict(next_state)
            vals: List[Any] = [None] * self.n_slots
            outputs: Dict[str, StreamBuffer] = {}
            if start is None:
                begin = 0
            else:
                for s, v in zip(self._live_slots(start), live_vals):
                    vals[s] = v
                op = self.ops[start]
                if op.out_slots and op.out_slots[0] >= 0:
                    vals[op.out_slots[0]] = answer
                if op.is_sink:
                    outputs[op.name] = answer
                begin = start + 1
            res = self._exec_ops(params, ctx, vals, outputs, inputs, begin,
                                 hoist_io=False, hoist_queries=False,
                                 defer_queries=True)
            if res is None:
                return outputs, ctx.next_state
            idx, request = res
            live = tuple(vals[s] for s in self._live_slots(idx))
            return request, live, outputs, ctx.next_state
        return seg

    def compiled_deferred_segment(self, start: Optional[int]) -> Callable:
        """Jitted :meth:`_deferred_segment`, cached in the fingerprint-keyed
        registry (failover reconnects of a structurally identical client
        pipeline never retrace its segments)."""
        fns = self._cache()["fns"]
        key = ("defer_seg", -1 if start is None else start)
        if key not in fns:
            fns[key] = jax.jit(self._deferred_segment(start))
        return fns[key]

    def run_deferred_compiled(self, params: dict, state: dict,
                              inputs: Optional[Dict[str, StreamBuffer]] = None):
        """Compiled counterpart of :meth:`run_deferred` for plans whose only
        impure elements are query clients (:attr:`deferred_compilable`):
        the walk to the first client is ONE jitted dispatch instead of an
        interpreted per-element walk — bitwise the same frame, minus the
        eager dispatch overhead per element.  Returns a compiled-mode
        :class:`PendingQuery` (its ``resume`` runs jitted segments too)."""
        inputs = inputs or {}
        fn = self.compiled_deferred_segment(None)
        request, live, outputs, next_state = fn(params, state, state,
                                                (), None, inputs)
        return PendingQuery.compiled(self, params, inputs, state, next_state,
                                     live, outputs, self.client_idxs[0],
                                     request)


class PendingQuery:
    """A frame paused mid-schedule at a query client, awaiting its answer.

    Produced by :meth:`ExecutionPlan.run_deferred`; ``request`` is the
    StreamBuffer the client was about to ship.  After the host sends the
    request and the (batched) server answer arrives, ``resume(answer)``
    continues the walk — returning ``(outputs, next_state)`` on completion
    or ``self`` again if a later query client pauses the frame once more.

    The request buffer is retained until the answer is in hand, which is
    what makes serving **fault-tolerant**: ``endpoint`` records where the
    scheduler actually shipped the request, and if that server dies before
    answering, the scheduler re-dispatches the very same ``request`` to the
    next-ranked survivor (``redispatches`` counts the hops) or parks the
    frame until one registers — see DESIGN.md §3.

    Two execution modes, bitwise-identical: the interpreted mode carries the
    live walk (``ctx``/``vals``) and resumes element by element; the
    COMPILED mode (``run_deferred_compiled``) carries only the live slot
    values plus the state pytrees, and ``resume`` runs the next pure
    segment as one jitted dispatch.
    """

    __slots__ = ("plan", "params", "inputs", "ctx", "vals", "outputs",
                 "op_idx", "request", "endpoint", "redispatches",
                 "state", "next_state", "live", "is_compiled",
                 "dseq", "retries", "next_retry")

    def __init__(self, plan: ExecutionPlan, params: dict, inputs: dict,
                 ctx: PipelineContext, vals: List[Any],
                 outputs: Dict[str, StreamBuffer], op_idx: int,
                 request: StreamBuffer):
        self.plan = plan
        self.params = params
        self.inputs = inputs
        self.ctx = ctx
        self.vals = vals
        self.outputs = outputs
        self.op_idx = op_idx
        self.request = request
        #: endpoint the in-flight request was dispatched to (scheduler-owned)
        self.endpoint = None
        #: failover hops this frame survived (scheduler-owned)
        self.redispatches = 0
        #: delivery id + retransmit clock (scheduler-owned, DESIGN.md §10).
        #: ``dseq`` is minted ONCE per logical request and reused verbatim
        #: by every retransmit and failover re-dispatch — idempotence by
        #: dedup rests on the id surviving the frame's whole lifetime.
        self.dseq = None
        self.retries = 0
        self.next_retry = 0
        # compiled-mode fields (PendingQuery.compiled)
        self.state = None
        self.next_state = None
        self.live = ()
        self.is_compiled = False

    @classmethod
    def compiled(cls, plan: ExecutionPlan, params: dict, inputs: dict,
                 state: dict, next_state: dict, live: Tuple,
                 outputs: Dict[str, StreamBuffer], op_idx: int,
                 request: StreamBuffer) -> "PendingQuery":
        pq = cls(plan, params, inputs, None, [], outputs, op_idx, request)
        pq.state = state
        pq.next_state = next_state
        pq.live = live
        pq.is_compiled = True
        return pq

    @property
    def client(self):
        """The tensor_query_client element this frame is paused at."""
        return self.plan.ops[self.op_idx].elem

    def resume(self, answer: StreamBuffer):
        """Inject the server's answer as the paused client's output and run
        the rest of the schedule."""
        if self.is_compiled:
            return self._resume_compiled(answer)
        op = self.plan.ops[self.op_idx]
        if op.out_slots and op.out_slots[0] >= 0:
            self.vals[op.out_slots[0]] = answer
        if op.is_sink:
            self.outputs[op.name] = answer
        res = self.plan._exec_ops(self.params, self.ctx, self.vals,
                                  self.outputs, self.inputs,
                                  self.op_idx + 1, hoist_io=False,
                                  hoist_queries=False, defer_queries=True)
        if res is None:
            return self.outputs, self.ctx.next_state
        self.op_idx, self.request = res
        self.endpoint = None  # the next client's request is not yet in flight
        return self

    def _resume_compiled(self, answer: StreamBuffer):
        """One jitted dispatch for the segment after the paused client."""
        plan = self.plan
        fn = plan.compiled_deferred_segment(self.op_idx)
        nxt = plan._next_client(self.op_idx)
        res = fn(self.params, self.state, self.next_state, self.live,
                 answer, self.inputs)
        if nxt is None:
            outputs, final = res
            return {**self.outputs, **outputs}, final
        request, live, outputs, next_state = res
        self.op_idx = nxt
        self.request = request
        self.live = live
        self.outputs = {**self.outputs, **outputs}
        self.next_state = next_state
        self.endpoint = None
        return self
