"""Compiled pipeline execution engine.

``ExecutionPlan`` turns a realized :class:`~repro.core.pipeline.Pipeline`
into a *plan*: the topo order and link wiring are flattened once, at
``realize()`` time, into a static slot-indexed schedule, so stepping a frame
no longer re-sorts links or rebuilds dicts per step (the host-side dispatch
cost NNStreamer avoids by compiling the graph once — arXiv 2101.06371).

Three execution tiers, all bitwise-identical to the seed interpreter:

* ``plan.run(params, state, inputs)`` — one frame through the static
  schedule; pure and jittable.
* ``plan.compiled_step()`` — a jitted executable, cached in a process-wide
  registry keyed by the plan's **topology fingerprint** (element configs +
  link wiring + negotiated caps).  Reconnecting a structurally identical
  pipeline after failover reuses the executable and never retraces; per
  fingerprint, XLA's own jit cache covers the (input shapes/dtypes) axis.
* ``plan.step_n(params, state, inputs, n)`` — an N-frame **burst**: one
  ``lax.scan`` dispatch runs the whole DAG N times over stacked
  :class:`StreamBuffer` frames, amortizing Python/jit dispatch to ~1/N per
  frame.  The runtime scheduler uses this to drain queued Channel frames.

Host-impure elements (mqtt sources/sinks) cannot be traced; ``hoist_io=True``
runs the plan in *hoisted* mode: host-driven sources must be injected through
``inputs`` (the scheduler pulls & decodes at host level) and host sinks
capture their input frame into the outputs dict instead of pushing, so the
scheduler can replay the captured frames through the real (impure)
``apply`` after the burst returns.

Donation: compiled executables donate the ``state`` argument when requested
(``donate=True``) or automatically on gpu/tpu backends (``donate=None``) —
state buffers are overwritten in place across frames.  Donation stays off on
CPU where XLA does not implement it (it would only emit warnings).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
from jax import lax

from .buffers import StreamBuffer
from .element import Element, PipelineContext

__all__ = ["ExecutionPlan", "PlanOp", "clear_executable_cache",
           "executable_cache_info"]


class PlanOp:
    """One scheduled element: static wiring resolved to value slots."""

    __slots__ = ("elem", "name", "in_slots", "out_slots", "injectable",
                 "is_sink", "is_host_sink")

    def __init__(self, elem: Element, in_slots: Tuple[int, ...],
                 out_slots: Tuple[int, ...], injectable: bool,
                 is_sink: bool, is_host_sink: bool):
        self.elem = elem
        self.name = elem.name
        self.in_slots = in_slots
        self.out_slots = out_slots
        self.injectable = injectable
        self.is_sink = is_sink
        self.is_host_sink = is_host_sink


# Process-wide executable registry: fingerprint -> (owning plan, jitted fns).
# Two plans with equal fingerprints are behaviorally identical (the
# fingerprint covers element class, static config, wiring and negotiated
# caps), so the first plan's jitted functions serve all of them.
#
# The jitted fns close over the owning plan's element graph, pinning it
# alive; to keep a long-running process that churns through many distinct
# topologies bounded, the registry is LRU-capped — evicting a fingerprint
# only costs a retrace if that topology ever comes back.
_EXEC_CACHE: "OrderedDict[Any, Dict[str, Any]]" = OrderedDict()
_EXEC_CACHE_MAX = 128


def clear_executable_cache():
    _EXEC_CACHE.clear()


def executable_cache_info() -> Dict[str, int]:
    return {"fingerprints": len(_EXEC_CACHE),
            "executables": sum(len(e["fns"]) for e in _EXEC_CACHE.values())}


class ExecutionPlan:
    """Static schedule + executable cache for one realized pipeline."""

    def __init__(self, pipeline):
        from .elements import AppSink, AppSrc  # cycle-free: elements<-element

        order: List[Element] = pipeline._order
        links = pipeline.links
        # slot assignment: every (producer, src_pad) that any link consumes
        slot_of: Dict[Tuple[str, int], int] = {}
        for l in links:
            key = (l.src.name, l.src_pad)
            if key not in slot_of:
                slot_of[key] = len(slot_of)
        self.n_slots = len(slot_of)

        in_links: Dict[str, list] = {e.name: [] for e in order}
        for l in links:
            in_links[l.dst.name].append(l)

        ops: List[PlanOp] = []
        for elem in order:
            lk = sorted(in_links[elem.name], key=lambda l: l.dst_pad)
            in_slots = tuple(slot_of[(l.src.name, l.src_pad)] for l in lk)
            max_pad = max((l.src_pad for l in links if l.src is elem),
                          default=-1)
            out_slots = tuple(slot_of.get((elem.name, p), -1)
                              for p in range(max_pad + 1))
            injectable = isinstance(elem, AppSrc) or \
                getattr(elem, "is_host_source", False)
            ops.append(PlanOp(elem, in_slots, out_slots,
                              injectable=injectable,
                              is_sink=isinstance(elem, AppSink),
                              is_host_sink=getattr(elem, "is_host_sink",
                                                   False)))
        self.ops = ops
        self.host_sources = [op.elem for op in ops
                             if getattr(op.elem, "is_host_source", False)]
        self.host_sinks = [op.elem for op in ops if op.is_host_sink]
        impure = [op.elem for op in ops
                  if getattr(op.elem, "host_impure", False)]
        #: no host-impure elements at all — safe to jit as-is
        self.pure = not impure
        #: every impure element is a hoistable source or terminal sink, so
        #: the DAG between them is traceable and scan-batched bursts apply
        self.burstable = all(
            getattr(e, "is_host_source", False) or
            getattr(e, "is_host_sink", False) for e in impure)
        #: every graph source is host-driven: a burst replays only queued
        #: frames.  A self-driven source (testsrc camera) mixed in would be
        #: fast-forwarded by a burst — fabricating future frames — so the
        #: scheduler must keep such pipelines on the tick cadence.
        self.all_sources_host_driven = bool(self.host_sources) and all(
            getattr(op.elem, "is_host_source", False)
            for op in ops if not op.in_slots)
        self.fingerprint = self._fingerprint(order, links)

    @staticmethod
    def _fingerprint(order: List[Element], links) -> Tuple:
        elems = tuple(e.plan_signature() for e in order)
        wiring = tuple((l.src.name, l.src_pad, l.dst.name, l.dst_pad)
                       for l in links)
        return (elems, wiring)

    # -- single-frame execution ------------------------------------------------
    def run(self, params: dict, state: dict,
            inputs: Optional[Dict[str, StreamBuffer]] = None,
            hoist_io: bool = False
            ) -> Tuple[Dict[str, StreamBuffer], dict]:
        """One frame through the static schedule.  Pure (jittable) when the
        pipeline is pure or ``hoist_io=True`` with all host sources injected.
        Semantics match the seed interpreter bitwise."""
        inputs = inputs or {}
        ctx = PipelineContext(state)
        vals: List[Any] = [None] * self.n_slots
        outputs: Dict[str, StreamBuffer] = {}
        for op in self.ops:
            ins = [vals[s] for s in op.in_slots]
            if op.injectable and op.name in inputs:
                ins = [inputs[op.name]]
                if getattr(op.elem, "is_host_source", False):
                    # host-driven source (mqttsrc): its apply would pull from
                    # the channel; the injected, already-decoded frame IS the
                    # pull — emit it directly
                    if op.out_slots and op.out_slots[0] >= 0:
                        vals[op.out_slots[0]] = ins[0]
                    continue
            elif hoist_io and getattr(op.elem, "is_host_source", False):
                raise ValueError(
                    f"{op.name}: hoisted execution requires an injected "
                    f"input frame for every host-driven source")
            if hoist_io and op.is_host_sink:
                # capture instead of the impure push; the caller replays the
                # captured frame through the element's real apply afterwards
                outputs[op.name] = ins[0]
                continue
            outs = op.elem.apply(params.get(op.name, {}), ins, ctx)
            for i, o in enumerate(outs):
                if i < len(op.out_slots) and op.out_slots[i] >= 0:
                    vals[op.out_slots[i]] = o
            if op.is_sink and outs:
                outputs[op.name] = outs[0]
        return outputs, ctx.next_state

    # -- burst execution -------------------------------------------------------
    def step_n(self, params: dict, state: dict,
               inputs: Optional[Dict[str, StreamBuffer]] = None,
               n: Optional[int] = None, hoist_io: bool = False
               ) -> Tuple[Dict[str, StreamBuffer], dict]:
        """Run an N-frame burst with a single ``lax.scan`` dispatch.

        ``inputs`` maps source names to *stacked* StreamBuffers (leading axis
        N, see :func:`repro.core.buffers.stack_buffers`); self-driven
        pipelines pass ``n`` instead.  Returns (stacked outputs, final
        state) — frame ``i`` of the outputs equals what ``run`` would have
        produced on the ``i``-th sequential call.
        """
        if inputs is None and n is None:
            raise ValueError("step_n needs stacked `inputs` or a length `n`")

        def body(carry, x):
            outs, nxt = self.run(params, carry, x, hoist_io=hoist_io)
            return nxt, outs

        final_state, outs = lax.scan(body, state, inputs, length=n)
        return outs, final_state

    # -- compiled executables --------------------------------------------------
    def _cache(self) -> Dict[str, Any]:
        ent = _EXEC_CACHE.get(self.fingerprint)
        if ent is None:
            ent = {"fns": {}}
            _EXEC_CACHE[self.fingerprint] = ent
            while len(_EXEC_CACHE) > _EXEC_CACHE_MAX:
                _EXEC_CACHE.popitem(last=False)
        else:
            _EXEC_CACHE.move_to_end(self.fingerprint)
        return ent

    @staticmethod
    def _resolve_donate(donate: Optional[bool]) -> bool:
        if donate is None:
            return jax.default_backend() in ("gpu", "tpu")
        return bool(donate)

    def compiled_step(self, donate: Optional[bool] = None) -> Callable:
        """Jitted single-frame step ``(params, state, inputs=None) ->
        (outputs, next_state)``, shared across all plans with this
        fingerprint."""
        donate = self._resolve_donate(donate)
        fns = self._cache()["fns"]
        key = ("step", donate)
        if key not in fns:
            fns[key] = jax.jit(self.run,
                               donate_argnums=(1,) if donate else ())
        return fns[key]

    def compiled_step_n(self, hoist_io: bool = False,
                        donate: Optional[bool] = None) -> Callable:
        """Jitted burst step ``(params, state, inputs=None, n=None) ->
        (stacked outputs, final state)``.  ``n`` and ``hoist_io`` are static;
        each distinct burst length traces once and is cached thereafter."""
        donate = self._resolve_donate(donate)
        fns = self._cache()["fns"]
        key = ("step_n", hoist_io, donate)
        if key not in fns:
            def step_n(params, state, inputs=None, n=None,
                       _self=self, _hoist=hoist_io):
                return _self.step_n(params, state, inputs, n=n,
                                    hoist_io=_hoist)
            fns[key] = jax.jit(step_n, static_argnames=("n",),
                               donate_argnums=(1,) if donate else ())
        return fns[key]
