"""Query protocol — inference workload offloading (paper §4.2.2, Fig. 2).

``tensor_query_client`` drops into a pipeline wherever a ``tensor_filter``
would go; the inference itself runs in a *server* pipeline
(``tensor_query_serversrc ! tensor_filter ! tensor_query_serversink``) on
another device.  The client is transparent: swap it with a local
tensor_filter and the rest of the pipeline is untouched (R1).

Transports:
* ``TCP_RAW``     — direct connection to a fixed endpoint; fast, but no
                    discovery/failover (fails R3/R4 — kept as the paper's
                    baseline).
* ``MQTT_HYBRID`` — connection & control via broker topics (operation name =
                    topic; wildcards pick among servers), bulk tensors direct.

Multi-client: serversrc tags ``client_id`` into buffer meta; serversink uses
it to route the answer back to the right client connection — exactly the
paper's mechanism.
"""
from __future__ import annotations

import enum
import itertools
from collections import OrderedDict
from typing import Dict, Optional

from .broker import Broker, BrokerError
from .buffers import StreamBuffer
from .element import Element, register_element
from .formats import Caps
from .pubsub import Channel
from . import compression as comp
from . import netfault

__all__ = ["QueryTransport", "QueryServerEndpoint", "TensorQueryClient",
           "TensorQueryServerSrc", "TensorQueryServerSink"]


class QueryTransport(enum.Enum):
    TCP_RAW = "tcp"
    MQTT_HYBRID = "hybrid"


class QueryServerEndpoint:
    """Server side connection state shared by serversrc/serversink pairs.

    Holds one request channel and per-client response channels."""

    _ids = itertools.count(1)

    def __init__(self, operation: str, spec: Optional[Dict] = None):
        self.operation = operation
        self.spec = spec or {}
        self.requests = Channel(capacity=64)
        self.responses: Dict[int, Channel] = {}
        self.endpoint_id = next(self._ids)
        self.alive = True

    def client_channel(self, client_id: int) -> Channel:
        if client_id not in self.responses:
            self.responses[client_id] = Channel(capacity=64)
        return self.responses[client_id]


@register_element("tensor_query_client")
class TensorQueryClient(Element):
    """Behaves exactly like tensor_filter, but remote.

    Properties: operation (service name = topic), transport, codec (payload
    compression — beyond-paper extension: the paper compresses pub/sub
    streams, we extend it to the query path), require-* spec filters ("server
    workload status", "model and version" in the paper).
    """

    host_impure = True
    #: the runtime scheduler may pause a frame here (plan.run_deferred),
    #: gather the request into a server-side micro-batch, and resume with
    #: the answer — see core/batching.py
    is_query_client = True

    _ids = itertools.count(1)

    def __init__(self, name=None, operation="", transport="hybrid",
                 codec="none", broker: Optional[Broker] = None,
                 tenant=None, **props):
        super().__init__(name=name, **props)
        self.operation = props.get("operation", operation)
        self.transport = (QueryTransport.MQTT_HYBRID if transport in ("hybrid", "mqtt")
                          else QueryTransport.TCP_RAW)
        self.codec = codec
        self.broker = broker
        self.client_id = next(self._ids)
        #: tenant this client's requests bill against (DESIGN.md §9).  None
        #: (the default) tags NOTHING — untagged requests book under the
        #: admission layer's default tenant, so single-tenant deployments
        #: and every pre-QoS pipeline string are untouched on the wire.
        self.tenant = props.get("tenant", tenant)
        self.binding = None
        self._direct: Optional[QueryServerEndpoint] = None
        self.require = {k[8:]: v for k, v in props.items() if k.startswith("require_")}
        #: delivery layer (DESIGN.md §10).  None — the default, and every
        #: pre-delivery pipeline — stamps nothing and checks nothing: the
        #: wire is bitwise the old wire.  A DeliveryPolicy turns on
        #: (sender_id, seq) delivery ids + CRC32 checksums on requests and
        #: dedup/corruption guarding on received answers.
        self.delivery: Optional[netfault.DeliveryPolicy] = None
        self._dseq = 0
        self._ans_seen = OrderedDict()  # bounded LRU of consumed answer ids
        self._ans_stash: Dict = {}      # early answers for other in-flight ids
        self.answer_dups = 0
        self.answer_corrupt = 0
        self.push_drops = 0

    def next_dseq(self):
        """Mint the delivery id for ONE logical request.  Retransmits must
        reuse the id — that is what makes them idempotent downstream."""
        self._dseq += 1
        return (self.client_id, self._dseq)

    def _routing_meta(self) -> Dict:
        meta = {"client_id": self.client_id, "codec": self.codec}
        if self.tenant is not None:
            meta["tenant_id"] = self.tenant
        return meta

    def connect(self, broker: Broker):
        self.broker = broker
        return self

    def connect_direct(self, endpoint: QueryServerEndpoint):
        """TCP-raw: explicit server endpoint (the IP:port config R3 removes)."""
        self._direct = endpoint
        return self

    def _endpoint(self) -> QueryServerEndpoint:
        if self.transport == QueryTransport.TCP_RAW:
            if self._direct is None or not self._direct.alive:
                raise BrokerError(f"{self.name}: TCP-raw endpoint gone; no failover "
                                  f"in raw transport (R4 unmet by design)")
            return self._direct
        if self.binding is None:
            if self.broker is None:
                raise BrokerError(f"{self.name}: MQTT-hybrid requires a broker")
            # capability-aware selection: rank servers by codec support /
            # throughput / load (DESIGN.md §3) on top of the hard require-*
            # spec filters; a tenant-tagged client also prefers replicas
            # that declare affinity for its tenant (soft, like codec)
            prefer = {"codec": self.codec}
            if self.tenant is not None:
                prefer["tenant"] = self.tenant
            self.binding = self.broker.subscribe(
                f"query/{self.operation}", prefer=prefer,
                **self.require)
        ep = self.binding.endpoint
        if not ep.alive:
            # liveness re-check on use: _rebind filters by endpoint.alive,
            # so this either lands on a live server or raises above
            self.binding._rebind()
            ep = self.binding.endpoint
        return ep

    # -- host-level request/answer (runtime scheduler & tests) ------------------
    def send_query(self, buf: StreamBuffer,
                   ep: Optional[QueryServerEndpoint] = None,
                   dseq=None) -> QueryServerEndpoint:
        """Encode + tag + push one request.  ``ep`` pins the destination (the
        scheduler resolves once and records where the request actually went,
        so in-flight failover re-dispatches exactly the orphaned buffers);
        by default the best-ranked live endpoint is resolved here.  With
        delivery on, ``dseq`` pins the delivery id — a retransmit passes the
        original id so the server's dedup window recognizes it."""
        if ep is None:
            ep = self._endpoint()
        payload, nbytes = comp.encode(buf, self.codec)
        meta = {**payload.meta, **self._routing_meta()}
        crc = None
        if self.delivery is not None:
            meta["dseq"] = dseq if dseq is not None else self.next_dseq()
            meta["crc"] = crc = netfault.checksum(payload)
        payload = payload.with_(meta=meta)
        if crc is not None:
            netfault.memoize_crc(payload, crc)
        if self.transport == QueryTransport.MQTT_HYBRID and self.broker is not None:
            # control message (topic resolution ping) — tiny, broker-borne
            self.broker.relay_msgs += 0  # control msgs are not data-relayed
        if not ep.requests.push(payload, nbytes):
            self.push_drops += 1
        return ep

    def send_query_wire(self, payload: StreamBuffer, nbytes: int,
                        ep: QueryServerEndpoint,
                        dseq=None) -> QueryServerEndpoint:
        """Push an ALREADY-ENCODED request (fused wire path: the scheduler
        encodes a whole dispatch round in one batched codec call, then
        pushes per client).  Tags routing meta exactly like
        :meth:`send_query`; the payload/nbytes must be what ``encode``
        would have produced — bitwise, pinned by the codec batch tests."""
        meta = {**payload.meta, **self._routing_meta()}
        crc = None
        if self.delivery is not None:
            meta["dseq"] = dseq if dseq is not None else self.next_dseq()
            meta["crc"] = crc = netfault.checksum(payload)
        payload = payload.with_(meta=meta)
        if crc is not None:
            netfault.memoize_crc(payload, crc)
        if not ep.requests.push(payload, nbytes):
            self.push_drops += 1
        return ep

    def _guard_answer(self, raw: StreamBuffer, channel,
                      want) -> Optional[StreamBuffer]:
        """Delivery-side answer triage: reject corrupt (counted), dedup by
        id (counted), stash early answers for OTHER in-flight requests of
        this client, and strip the delivery meta off an accepted answer so
        everything downstream sees exactly the pre-delivery buffer."""
        meta = raw.meta or {}
        crc = meta.get("crc")
        if crc is not None and netfault.checksum(raw) != int(crc):
            self.answer_corrupt += 1
            netfault.note(channel, "rejected_corrupt")
            return None
        dseq = meta.get("dseq")
        if dseq is None:
            netfault.note(channel, "accepted")
            return raw
        if dseq in self._ans_seen:
            self._ans_seen.move_to_end(dseq)
            self.answer_dups += 1
            netfault.note(channel, "deduped")
            return None
        if want is not None and dseq != want:
            # a different request's answer arrived first (reordering): hold
            # it for that request's own recv instead of consuming it here
            self._ans_stash[dseq] = raw
            netfault.note(channel, "accepted")
            return None
        self._ans_seen[dseq] = True
        while len(self._ans_seen) > self.delivery.window:
            self._ans_seen.popitem(last=False)
        netfault.note(channel, "accepted")
        stripped = dict(meta)
        stripped.pop("dseq", None)
        stripped.pop("crc", None)
        return raw.with_(meta=stripped)

    def recv_answer_raw(self, ep: QueryServerEndpoint, want=None
                        ) -> Optional[StreamBuffer]:
        """Pop this client's WIRE-form answer without decoding (the
        scheduler's drain batch-decodes a whole round in one dispatch).
        With delivery on, ``want`` names the expected delivery id: corrupt
        and duplicate answers are discarded (counted, never consumed as
        data), answers for other in-flight ids are stashed for their own
        recv, and the accepted answer comes back stripped of delivery
        meta — bitwise what a delivery-off server would have answered."""
        ch = ep.client_channel(self.client_id)
        if self.delivery is None:
            return ch.pop()
        if want is not None and want in self._ans_stash:
            return self._accept_stashed(self._ans_stash.pop(want), want)
        while True:
            raw = ch.pop()
            if raw is None:
                return None
            out = self._guard_answer(raw, ch, want)
            if out is not None:
                return out

    def _accept_stashed(self, raw: StreamBuffer, dseq) -> StreamBuffer:
        self._ans_seen[dseq] = True
        while len(self._ans_seen) > self.delivery.window:
            self._ans_seen.popitem(last=False)
        stripped = dict(raw.meta or {})
        stripped.pop("dseq", None)
        stripped.pop("crc", None)
        return raw.with_(meta=stripped)

    def recv_answer_from(self, ep: QueryServerEndpoint, want=None
                         ) -> Optional[StreamBuffer]:
        """Pop this client's answer from a specific endpoint — the scheduler
        reads from the endpoint it dispatched to, never a rebound one."""
        raw = self.recv_answer_raw(ep, want=want)
        if raw is None:
            return None
        return comp.decode(raw, self.codec)

    def recv_answer(self) -> Optional[StreamBuffer]:
        return self.recv_answer_from(self._endpoint())

    def apply(self, params, inputs, ctx=None):
        """Synchronous round-trip (compiled-pipeline semantics): the runtime
        scheduler interleaves server pipelines between send/recv; in a single
        process we call the server's pending step inline.  With delivery on
        the round-trip retransmits (same delivery id — idempotent by the
        server's dedup window) up to ``hop_retries`` times before giving
        up, so a lossy link can't starve the inline path."""
        if self.delivery is None:
            self.send_query(inputs[0])
            srv = self._endpoint()
            runner = srv.spec.get("inline_runner")
            if runner is not None:
                runner()
            out = self.recv_answer()
            if out is None:
                raise BrokerError(f"{self.name}: no answer from {self.operation!r}")
            return [out]
        dseq = self.next_dseq()
        for _ in range(max(1, self.delivery.hop_retries)):
            srv = self.send_query(inputs[0], dseq=dseq)
            runner = srv.spec.get("inline_runner")
            if runner is not None:
                runner()
            out = self.recv_answer_from(srv, want=dseq)
            if out is not None:
                return [out]
        raise BrokerError(f"{self.name}: no answer from {self.operation!r} "
                          f"after {self.delivery.hop_retries} retransmits")


@register_element("tensor_query_serversrc")
class TensorQueryServerSrc(Element):
    """Receives queries; tags client_id into meta for the paired serversink."""

    n_sink_pads = 0
    host_impure = True
    #: hoistable out of a batched serving dispatch: the QueryBatcher pulls &
    #: decodes queued requests at host level and injects them stacked
    is_query_source = True

    def __init__(self, name=None, operation="", broker: Optional[Broker] = None,
                 **props):
        super().__init__(name=name, **props)
        self.operation = props.get("operation", operation)
        self.endpoint = QueryServerEndpoint(self.operation)
        self.broker = broker
        self.registration = None
        self.specs = {k: v for k, v in props.items() if not k.startswith("_")}

    def connect(self, broker: Broker, **extra_specs):
        self.broker = broker
        self.endpoint.spec.update(extra_specs)
        self.registration = broker.register(
            f"query/{self.operation}", Caps.ANY, self.endpoint,
            **{**self.specs, **extra_specs})
        return self

    def pull(self) -> Optional[StreamBuffer]:
        return self.endpoint.requests.pop()

    def apply(self, params, inputs, ctx=None):
        buf = self.pull()
        if buf is None:
            raise BrokerError(f"{self.name}: no pending query")
        codec = buf.meta.get("codec", "none")
        decoded = comp.decode(buf, codec)
        # decode strips the wire-form codec claim; the client's codec
        # survives as ROUTING meta so the paired serversink knows how to
        # encode the answer back (mirrors the batcher's routing hoist).
        # The request's wire checksum does NOT survive: it authenticated
        # the inbound frame only — were it to ride the pipeline into the
        # answer meta, the client would verify the answer against the
        # REQUEST's crc and reject it (the sink stamps answers afresh)
        meta = {**decoded.meta, "codec": codec}
        meta.pop("crc", None)
        return [decoded.with_(meta=meta)]


@register_element("tensor_query_serversink")
class TensorQueryServerSink(Element):
    """Routes the inference answer back to the tagged client connection."""

    n_src_pads = 0
    host_impure = True
    #: capturable by a batched serving dispatch: the QueryBatcher replays the
    #: captured answers through the real apply (encode + client_id routing)
    is_query_sink = True

    def __init__(self, name=None, serversrc: Optional[TensorQueryServerSrc] = None,
                 **props):
        super().__init__(name=name, **props)
        self.serversrc = serversrc
        #: delivery guard shared with the owning batcher (DESIGN.md §10):
        #: when set, outgoing answers get a fresh CRC over their encoded
        #: form and are recorded in the replay cache, so a retransmitted
        #: request whose original answer was lost is answered BITWISE again
        #: without re-serving.  None = pre-delivery wire, untouched.
        self.guard = None
        #: answers displaced off a full client channel (satellite of the
        #: PR-3 conservation law: a push rejection is the sink's loss to
        #: book, not a silent vanishing act)
        self.answer_drops = 0

    def pair_with(self, serversrc: TensorQueryServerSrc):
        self.serversrc = serversrc
        return self

    def _ship(self, payload: StreamBuffer, nbytes: int, client_id: int):
        """One answer push: fresh CRC + replay-cache entry when the
        delivery layer is on, overflow folded into the sink ledger."""
        ep = self.serversrc.endpoint
        if self.guard is not None:
            dseq = payload.meta.get("dseq")
            if dseq is not None:
                crc = netfault.checksum(payload)
                payload = payload.with_(
                    meta={**payload.meta, "crc": crc})
                netfault.memoize_crc(payload, crc)
                self.guard.record_answer(
                    dseq, lambda ep=ep, cid=client_id, p=payload, n=nbytes:
                        ep.client_channel(cid).push(p, n))
        if not ep.client_channel(client_id).push(payload, nbytes):
            self.answer_drops += 1

    def apply(self, params, inputs, ctx=None):
        buf = inputs[0]
        client_id = buf.meta.get("client_id")
        if client_id is None:
            raise BrokerError(f"{self.name}: answer buffer lost its client_id tag")
        codec = buf.meta.get("codec", "none")
        payload, nbytes = comp.encode(buf, codec)
        self._ship(payload, nbytes, client_id)
        return []

    def push_wire(self, payload: StreamBuffer, nbytes: int, client_id: int):
        """Route an ALREADY-ENCODED answer (fused wire path: the batch was
        re-encoded inside the serving jit; the batcher routes the wire
        frames with meta restored host-side).  Same channel push and byte
        accounting as :meth:`apply`."""
        self._ship(payload, nbytes, client_id)
