"""Zero-loss live reconfiguration — prepare/commit hot swap (DESIGN.md §6).

The paper's among-device vision needs pipelines that survive devices
joining, leaving and changing roles at runtime; NNStreamer exposes this as
dynamic pipeline control (element swap without teardown — arXiv
2101.06371's ``Processing.setModules`` applies new modules "on the next
run").  Here a topology edit becomes a first-class runtime operation with
prepare → warm → commit → drain semantics:

* **prepare** — the edit script (:class:`ReconfigPlan`) is applied to a
  *shadow* copy of the live topology: unchanged elements are SHARED by
  object identity (their channels, bindings and queued frames carry
  intrinsically), new elements are fresh.  The shadow realizes off the
  serving path; a caps/trace error here rolls back before anything
  observable changed.
* **warm** — the shadow plan's executables are created in the
  fingerprint-keyed registry (core/plan.py): an unchanged fingerprint is a
  cache HIT (zero retrace — the exec-cache makes re-realization free), a
  new fingerprint pre-creates the same executable set the live plan uses,
  and pure plans are lowered/compiled ahead of the cutover so the first
  post-commit tick pays no trace.
* **commit** — at a tick boundary: the run's pipe/params/state swap to the
  shadow (kept elements keep their live state entries, new elements get
  fresh ones), removed elements retire (registrations unregister → clients
  re-bind via the exactly-once win-back; bindings close; batchers drop),
  and new broker-facing elements wire in.  Queued channel/pubsub frames and
  in-flight :class:`~repro.core.plan.PendingQuery` s are carried across the
  swap by the PR-3 rebind machinery — shared elements keep their queues,
  paused frames complete on the epoch they started in — so zero frames are
  lost and post-commit answers are bitwise what a freshly-built pipeline
  produces.
* **drain** — a run with frames still paused at a query client does not cut
  over mid-frame: the commit defers (status ``draining``) until its parked
  frames resolve, expire, or the target dies (rollback).

Failover is the UNPLANNED half of the same machinery: a server death or
revival is a topology edit nobody prepared, so the broker watch events that
PR-3 special-cased inside the scheduler now route through
:meth:`ReconfigManager.on_broker_event` — one copy of the endpoint
lifecycle (:func:`teardown_endpoint` / :func:`activate_endpoint`) shared by
planned removals, planned additions, crashes and revivals alike.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax

from . import netfault
from .pipeline import Link, Pipeline
from .pubsub import MqttSink, MqttSrc
from .query import (QueryServerEndpoint, TensorQueryClient,
                    TensorQueryServerSrc)

__all__ = ["ReconfigError", "ReconfigPlan", "Reconfiguration",
           "ReconfigManager", "teardown_endpoint", "activate_endpoint"]


class ReconfigError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# Endpoint lifecycle — ONE copy, shared by planned and unplanned edits
# ---------------------------------------------------------------------------

def teardown_endpoint(ep: QueryServerEndpoint) -> int:
    """Take a query-server endpoint out of service: stop serving NOW and
    purge its channels.  Queued requests are orphans the scheduler
    re-dispatches from its own PendingQuery records (the count is returned
    for the orphan ledger); the per-client response channels are released
    outright — clients re-bind away, and stale pre-death answers must never
    satisfy a post-revival frame (a purge that only cleared queues would
    also leak one orphaned Channel per client per epoch, forever)."""
    ep.alive = False
    orphans = len(ep.requests)
    _book_purges(ep)
    ep.requests.q.clear()
    ep.responses.clear()
    return orphans


def activate_endpoint(ep: QueryServerEndpoint):
    """Bring a query-server endpoint (back) into service as a FRESH epoch:
    whatever a previous life left queued is invalid — returning clients get
    new response channels on their first routed answer."""
    ep.alive = True
    _book_purges(ep)
    ep.requests.q.clear()
    ep.responses.clear()


def _book_purges(ep: QueryServerEndpoint):
    """Book frames a teardown/activation is about to clear on their fault
    links (no-op outside chaos runs): a purged frame left the network
    accounted — the §10 per-link conservation law must see it as ``purged``,
    not linger forever as ``in_flight``."""
    netfault.note_purged(ep.requests, len(ep.requests.q))
    for ch in ep.responses.values():
        netfault.note_purged(ch, len(ch.q))


# ---------------------------------------------------------------------------
# Edit script
# ---------------------------------------------------------------------------

class ReconfigPlan:
    """A topology edit script against a live pipeline.

    Edits are recorded, not applied; :meth:`apply_to` materializes them on a
    shadow copy whose unchanged elements are the LIVE objects (shared by
    identity — that sharing is what carries queued frames, bindings and
    registrations across the swap for free).  Vocabulary:

    * ``swap(name, new_elem)`` — replace the element while keeping its name
      and wiring (the NNStreamer "swap a filter without teardown" case; the
      new element adopts ``name`` so param/state keys stay aligned);
    * ``relink(src, dst, ...)`` — re-route a link: the edge into
      ``(dst, dst_pad)`` now comes from ``(src, src_pad)``;
    * ``add(elem)`` / ``link(src, dst, ...)`` — grow the graph (a new
      query-server endpoint, a new pubsub binding);
    * ``remove(name)`` — drop an element and every link touching it
      (removing ALL elements decommissions the run — the scheduler retires
      it at commit).
    """

    def __init__(self, pipe: Pipeline):
        self.pipe = pipe
        self._edits: List[Tuple] = []

    # -- vocabulary -----------------------------------------------------------
    def swap(self, name: str, new_elem) -> "ReconfigPlan":
        self._edits.append(("swap", name, new_elem))
        return self

    def relink(self, src: str, dst: str, src_pad: int = 0,
               dst_pad: int = 0) -> "ReconfigPlan":
        self._edits.append(("relink", src, dst, src_pad, dst_pad))
        return self

    def add(self, elem) -> "ReconfigPlan":
        self._edits.append(("add", elem))
        return self

    def link(self, src: str, dst: str, src_pad: int = 0,
             dst_pad: int = 0) -> "ReconfigPlan":
        self._edits.append(("link", src, dst, src_pad, dst_pad))
        return self

    def remove(self, name: str) -> "ReconfigPlan":
        self._edits.append(("remove", name))
        return self

    # -- materialization ------------------------------------------------------
    def apply_to(self, live: Pipeline) -> Pipeline:
        """Build the shadow: same element objects where unchanged, fresh
        ``Link`` records throughout (links are mutated by swaps; the live
        pipeline's wiring must stay intact for rollback)."""
        shadow = Pipeline(name=live.name)
        shadow.elements = dict(live.elements)
        shadow.links = [Link(l.src, l.src_pad, l.dst, l.dst_pad)
                        for l in live.links]
        for edit in self._edits:
            kind = edit[0]
            if kind == "swap":
                _, name, new_elem = edit
                old = shadow.elements.get(name)
                if old is None:
                    raise ReconfigError(f"swap: no element {name!r}")
                new_elem.name = name
                shadow.elements[name] = new_elem
                for l in shadow.links:
                    if l.src is old:
                        l.src = new_elem
                    if l.dst is old:
                        l.dst = new_elem
            elif kind == "relink":
                _, src, dst, src_pad, dst_pad = edit
                s, d = self._lookup(shadow, src), self._lookup(shadow, dst)
                shadow.links = [l for l in shadow.links
                                if not (l.dst is d and l.dst_pad == dst_pad)]
                shadow.links.append(Link(s, src_pad, d, dst_pad))
            elif kind == "add":
                _, elem = edit
                if elem.name in shadow.elements:
                    raise ReconfigError(f"add: duplicate name {elem.name!r}")
                shadow.elements[elem.name] = elem
            elif kind == "link":
                _, src, dst, src_pad, dst_pad = edit
                s, d = self._lookup(shadow, src), self._lookup(shadow, dst)
                shadow.links.append(Link(s, src_pad, d, dst_pad))
            elif kind == "remove":
                _, name = edit
                gone = shadow.elements.pop(name, None)
                if gone is None:
                    raise ReconfigError(f"remove: no element {name!r}")
                shadow.links = [l for l in shadow.links
                                if l.src is not gone and l.dst is not gone]
        return shadow

    @staticmethod
    def _lookup(shadow: Pipeline, name: str):
        elem = shadow.elements.get(name)
        if elem is None:
            raise ReconfigError(f"no element {name!r} in topology")
        return elem


# ---------------------------------------------------------------------------
# One reconfiguration: the prepare/warm/commit/drain/rollback state machine
# ---------------------------------------------------------------------------

class Reconfiguration:
    """State machine for one topology edit on one live pipeline run.

    ``pending → prepared → warming → [draining →] committed`` on success;
    any failure (shadow realize error, target device death mid-warm)
    lands in ``rolled_back`` with ``error``/``reason`` recorded — never
    limbo.  The manager drives :meth:`commit` at tick boundaries only."""

    def __init__(self, runtime, run, plan: ReconfigPlan,
                 warm_ticks: int = 1, rng=None, kind: str = "planned"):
        self.runtime = runtime
        self.run = run
        self.plan = plan
        self.warm_ticks = max(0, int(warm_ticks))
        self.rng = rng
        self.kind = kind
        self.requested_tick = runtime.ticks
        self.status = "pending"
        self.reason: Optional[str] = None
        self.error: Optional[Exception] = None
        self.shadow: Optional[Pipeline] = None
        self.new_params: Optional[dict] = None
        self.frames_carried = 0
        self.committed_tick: Optional[int] = None

    # -- prepare ---------------------------------------------------------------
    def prepare(self) -> "Reconfiguration":
        """Build and realize the shadow topology off the serving path.
        Consumer-side NEW elements (mqttsrc, query clients) connect to the
        broker here so caps discovery sees the real publishers; publisher
        registration (mqttsink, serversrc) waits for commit — a prepared
        server must never win client bindings before it serves."""
        try:
            shadow = self.plan.apply_to(self.run.pipe)
            live = self.run.pipe.elements
            for e in shadow.elements.values():
                if live.get(e.name) is e:
                    continue
                if isinstance(e, (MqttSrc, TensorQueryClient)) \
                        and e.broker is None:
                    e.connect(self.runtime.broker)
            shadow.realize()
            self.new_params = self._carry_params(shadow)
            # the shadow realize re-negotiated the SHARED elements' caps;
            # restore the live topology's negotiation so the stream keeps
            # serving the committed config through the warm window (both
            # fingerprints are cached — neither realize retraces anything)
            self.run.pipe._realized = False
            self.run.pipe.realize()
            self.shadow = shadow
            self.status = "prepared"
        except Exception as exc:  # caps error, trace error, bad edit
            self.error = exc
            self.rollback("prepare-failed")
        return self

    def _carry_params(self, shadow: Pipeline) -> dict:
        """Kept elements keep their live param entries; new elements init
        fresh (params are static across ticks, so prepare-time is safe —
        STATE is snapshotted at commit, it evolves every tick)."""
        live = self.run.pipe.elements
        params: dict = {}
        rng = self.rng if self.rng is not None else jax.random.PRNGKey(0)
        for elem in shadow._order:
            if live.get(elem.name) is elem:
                if elem.name in self.run.params:
                    params[elem.name] = self.run.params[elem.name]
            else:
                rng, sub = jax.random.split(rng)
                p = elem.init_params(sub)
                if p:
                    params[elem.name] = p
        return params

    def _carry_state(self) -> dict:
        state: dict = {}
        live = self.run.pipe.elements
        for elem in self.shadow._order:
            if live.get(elem.name) is elem:
                if elem.name in self.run.state:
                    state[elem.name] = self.run.state[elem.name]
            else:
                s = elem.init_state()
                if s:
                    state[elem.name] = s
        return state

    # -- warm ------------------------------------------------------------------
    def warm(self) -> "Reconfiguration":
        """Create the shadow plan's registry entry and pre-create the same
        executable set the live plan carries.  Unchanged fingerprints hit
        the LRU cache (no retrace — the churn contract test_exec_cache
        pins); genuinely new topologies pay their trace HERE, off the
        serving path, and pure plans are lowered/compiled so the cutover
        tick dispatches a ready executable."""
        if self.status != "prepared":
            return self
        plan = self.shadow.plan
        plan._cache()
        old_plan = self.run.pipe.plan
        mesh = self.runtime.mesh
        mesh_fp = plan._mesh_key(mesh)
        for key in list(old_plan._cache()["fns"]):
            try:
                if key[0] == "step":
                    plan.compiled_step(donate=key[1])
                elif key[0] == "step_n":
                    plan.compiled_step_n(
                        hoist_io=key[1], hoist_queries=key[2], donate=key[3],
                        mesh=mesh if key[4] == mesh_fp else None)
                elif key[0] == "serve_batch":
                    plan.compiled_serve_batch(
                        donate=key[1], mesh=mesh if key[2] == mesh_fp
                        else None, codec=key[3])
                elif key[0] == "serve_tick":
                    # stateful streaming executable: key[-1] is the state
                    # structure axis (key[2] is the multi-hop stage
                    # signature, re-derived from the shadow plan itself) —
                    # identical serve topology re-keys to the same entry,
                    # so a mid-decode hot swap dispatches warm on its
                    # first post-commit tick
                    plan._serve_tick_fn(key[1], key[-1])
            except Exception:
                pass  # warm is best-effort; commit never depends on it
        if plan.deferred_compilable:
            plan.compiled_deferred_segment(None)
            for idx in plan.client_idxs:
                plan.compiled_deferred_segment(idx)
        if plan.pure and plan.ops:
            try:
                fn = plan.compiled_step()
                fn.lower(self.new_params, self._carry_state()).compile()
            except Exception:
                pass  # ahead-of-time compile is an optimization only
        self.status = "warming"
        return self

    # -- commit ----------------------------------------------------------------
    def commit(self) -> "Reconfiguration":
        """Cut over at a tick boundary.  The manager guarantees the run has
        no frame paused mid-schedule (drain) and the target device is alive;
        here the swap itself is a handful of pointer moves — the pause the
        stream sees is bounded by plan-cache lookups, not traces."""
        if self.status not in ("prepared", "warming", "draining"):
            return self
        rt, run = self.runtime, self.run
        old_pipe = run.pipe
        shadow = self.shadow
        self.frames_carried += self._count_carried(old_pipe, shadow)
        run.pipe = shadow
        run.params = self.new_params
        run.state = self._carry_state_from(old_pipe)
        run.mesh_params = None
        # retire what left the topology (fires unregister events — clients
        # re-bind through the exactly-once win-back, orphans are accounted
        # by the same teardown the unplanned path uses)
        for name, e in old_pipe.elements.items():
            if shadow.elements.get(name) is not e:
                rt._retire_element(e)
        if not shadow.elements:
            run.retired = True
            run.step_fn = None
            self.status = "committed"
            self.committed_tick = rt.ticks
            return self
        # wire what joined (publisher registration happens HERE — prepared
        # servers become discoverable only once they actually serve) and
        # re-realize with the broker in place; the fingerprint matches the
        # warmed shadow, so this is a cache hit, not a retrace
        dev = rt._device_of(run)
        for e in shadow.elements.values():
            if isinstance(e, (MqttSink, MqttSrc)) and e.sync_clock is None \
                    and dev is not None:
                e.sync_clock = dev.pipeline_clock
        rt._wire(dev, run)
        run.step_fn = run.pipe.compiled_step() \
            if (run.jit and run.pipe.plan.pure) else run.pipe.step
        # grow-from-empty (elastic scale-up, DESIGN.md §9): a placeholder
        # run starts retired (nothing to serve pre-commit) and goes live
        # here, in the same commit that registers its endpoints — the
        # replica is discoverable and runnable atomically
        run.retired = False
        for b in rt._batchers.values():
            if b.run is run:
                b.on_reconfig()
        self.status = "committed"
        self.committed_tick = rt.ticks
        return self

    def _carry_state_from(self, old_pipe: Pipeline) -> dict:
        state: dict = {}
        for elem in self.shadow._order:
            if old_pipe.elements.get(elem.name) is elem:
                if elem.name in self.run.state:
                    state[elem.name] = self.run.state[elem.name]
            else:
                s = elem.init_state()
                if s:
                    state[elem.name] = s
        return state

    def _count_carried(self, old_pipe: Pipeline, shadow: Pipeline) -> int:
        """Frames that cross the swap: queued pubsub frames on kept host
        sources (their channels are shared by identity) and queued requests
        on kept query-server endpoints.  Dropped backlogs of REMOVED
        subscribers are folded into the run's drop accounting instead — a
        replaced binding abandons its history, it does not lose frames
        silently."""
        carried = 0
        for name, e in shadow.elements.items():
            if old_pipe.elements.get(name) is not e:
                continue
            if isinstance(e, MqttSrc):
                try:
                    carried += e.queued()
                except Exception:
                    carried += len(e._pushback)
            elif isinstance(e, TensorQueryServerSrc):
                carried += len(e.endpoint.requests)
        for name, e in old_pipe.elements.items():
            if shadow.elements.get(name) is e:
                continue
            if isinstance(e, MqttSrc):
                self.run.carried_drops += e.drops + len(e._pushback)
                for _, rx in e._rx_hist.values():
                    self.run.carried_drops += len(rx)
            elif isinstance(e, MqttSink):
                self.run.carried_drops += e.channel.drops
        return carried

    # -- rollback --------------------------------------------------------------
    def rollback(self, reason: str) -> "Reconfiguration":
        """Return cleanly to the old plan.  The shadow realize mutated the
        SHARED elements' negotiated caps, so the live pipeline re-realizes —
        its fingerprint is unchanged, making that a cache hit, not a
        retrace; bindings opened for never-committed elements close."""
        if self.status in ("committed", "rolled_back"):
            return self
        self.reason = reason
        if self.shadow is not None:
            live = self.run.pipe.elements
            for e in self.shadow.elements.values():
                if live.get(e.name) is e:
                    continue
                binding = getattr(e, "binding", None)
                if binding is not None:
                    binding.close()
                    e.binding = None
        try:
            self.run.pipe._realized = False
            self.run.pipe.realize()
        except Exception:
            pass  # the live topology realized before; caps restore is best-effort
        self.status = "rolled_back"
        return self


# ---------------------------------------------------------------------------
# Manager: owns planned requests, tick stepping, and the unplanned path
# ---------------------------------------------------------------------------

class ReconfigManager:
    """Runtime-owned coordinator for every topology change, planned or not.

    Planned: :meth:`request` prepares + warms immediately, then
    :meth:`step` (top of every tick — the tick boundary) commits once the
    warm window elapsed and the run has drained its paused frames, or rolls
    back if the target died mid-warm.  Unplanned: broker liveness events
    route through :meth:`on_broker_event` — server death/revival is a
    topology edit nobody prepared, handled by the same endpoint lifecycle
    helpers planned removals use (the PR-3 scheduler special case, deleted).
    """

    def __init__(self, runtime):
        self.rt = runtime
        self.pending: List[Reconfiguration] = []
        self.planned = 0
        self.unplanned = 0
        self.rollbacks = 0
        self.frames_carried = 0
        #: (tick, kind, status, reason) — one row per terminal transition
        self.log: List[Tuple[int, str, str, Optional[str]]] = []
        self._in_planned_commit = False

    # -- planned ---------------------------------------------------------------
    def request(self, run, plan: ReconfigPlan, warm_ticks: int = 1,
                rng=None) -> Reconfiguration:
        rc = Reconfiguration(self.rt, run, plan, warm_ticks=warm_ticks,
                             rng=rng)
        rc.prepare()
        if rc.status == "prepared":
            rc.warm()
            self.pending.append(rc)
        else:
            self._note_terminal(rc)
        return rc

    def step(self):
        """Advance every pending reconfiguration at the tick boundary."""
        if not self.pending:
            return
        still: List[Reconfiguration] = []
        for rc in self.pending:
            dev = self.rt._device_of(rc.run)
            if dev is None or not dev.alive:
                rc.rollback("target-dead")
            elif self.rt.ticks - rc.requested_tick > rc.warm_ticks:
                if self.rt._run_in_flight(rc.run):
                    # drain: never cut over mid-frame — paused PendingQuerys
                    # complete on the epoch they started in first
                    rc.status = "draining"
                else:
                    self._in_planned_commit = True
                    try:
                        rc.commit()
                    finally:
                        self._in_planned_commit = False
            if rc.status in ("committed", "rolled_back"):
                self._note_terminal(rc)
            else:
                still.append(rc)
        self.pending = still

    def _note_terminal(self, rc: Reconfiguration):
        if rc.status == "committed":
            self.planned += 1
            self.frames_carried += rc.frames_carried
        else:
            self.rollbacks += 1
        self.log.append((self.rt.ticks, rc.kind, rc.status, rc.reason))

    # -- unplanned (failover = a reconfiguration nobody prepared) --------------
    def on_broker_event(self, event: str, reg):
        """Broker liveness transition on a query-server endpoint: apply it
        as an immediate unplanned reconfiguration — teardown on death
        (orphans re-dispatch from PendingQuery records), fresh-epoch
        activation on registration/revival.  Events fired BY a planned
        commit (its retires/registers) are that commit's bookkeeping, not a
        second reconfiguration."""
        ep = reg.endpoint
        if not isinstance(ep, QueryServerEndpoint):
            return
        # initial wiring (tick 0) is topology CONSTRUCTION, not a change;
        # events fired by a planned commit's retire/register are that
        # commit's bookkeeping, not a second reconfiguration — either way
        # the endpoint lifecycle itself always runs
        counts = self.rt.ticks > 0 and not self._in_planned_commit
        if event in ("down", "unregister"):
            orphans = teardown_endpoint(ep)
            if orphans:
                self.rt.orphaned_requests += orphans
            if counts:
                self.unplanned += 1
                self.log.append((self.rt.ticks, "unplanned", event,
                                 reg.down_reason))
        elif event == "register":
            activate_endpoint(ep)
            if counts:
                self.unplanned += 1
                self.log.append((self.rt.ticks, "unplanned", event, None))

    # -- stats -----------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return {"reconfigs": self.planned + self.unplanned,
                "planned": self.planned,
                "unplanned": self.unplanned,
                "rollbacks": self.rollbacks,
                "frames_carried": self.frames_carried,
                "pending": len(self.pending)}
