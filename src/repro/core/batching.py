"""Server-side micro-batching for the query protocol (paper §4.2.2, Fig. 2).

The paper's offloading protocol answers one round-trip per frame.  On edge
silicon the per-dispatch host cost dominates long before the model does
(arXiv 2210.10514) — the same amortize-the-dispatch argument behind the
PR-1 burst engine.  This module batches *across clients*: concurrent
``tensor_query_client`` requests that land on one ``QueryServerEndpoint``
within a scheduler tick are gathered, decoded, stacked along a leading
frame axis, and served by ONE hoisted ``step_n`` scan dispatch through the
server pipeline's compiled plan; the stacked answers are unstacked and
routed back per ``client_id`` through the real serversink ``apply``.

Semantics are preserved relative to sequential serving:

* requests are served in channel FIFO order (= arrival order), and the
  server state threads through the scan in that order — frame ``i`` of a
  batch is exactly the ``i``-th sequential serve;
* per-request codecs survive: decode happens at gather time, encode at
  routing time, both through the unchanged ``compression`` code paths;
* routing meta (``client_id``, ``codec``) is hoisted out of the buffers
  before stacking (meta is static pytree aux — differing client ids would
  otherwise make frames structurally unstackable) and re-attached to each
  answer before the serversink replay.

Fallback rules (automatic, per flush):

* server plans that are not :attr:`ExecutionPlan.query_batchable` (extra
  impure elements, multiple serversrcs) serve sequentially through the
  runtime's interpreted per-request step — the pre-batching behavior;
* requests whose decoded frames differ in pytree structure or tensor
  shapes/dtypes (mixed caps across clients) are split into consecutive
  same-structure groups; a group of one is still served through the
  compiled hoisted path, so every answer leaves through the same execution
  mode and batch composition never changes numerics.

Mesh sharding (DESIGN.md §4): when the runtime is built over a jax mesh
(``Runtime(mesh=...)``), the batcher also holds the mesh-sharded executable
— groups whose frame count tiles the mesh's data axes can serve with one
frame slice per device (``ExecutionPlan.shardable_batch``); every other
group keeps the single-device scan, so the answers are bitwise identical
either way.

Placement is a COST decision, not a faith decision: the dispatch-vs-silicon
gap (arXiv 2210.10514) cuts both ways — on real multi-chip meshes sharding
multiplies serving throughput, but on a host-forged mesh (8 "devices" on 2
cores) the SPMD dispatch overhead exceeds the whole single-device serve.
``shard_mode="auto"`` (default) therefore probes both executables once per
batch size at first use — a handful of extra dispatches, both bitwise
correct — and picks the faster for that size thereafter; ``"always"`` /
``"never"`` force the choice (tests force ``"always"`` to pin the sharded
path's semantics regardless of host speed).  Placement stays transparent to
elements and clients, NNStreamer-style: only latency changes.

Fused wire path (DESIGN.md §5, default on): the batcher does NOT decode
requests at gather time.  Wire-form requests group by **(codec, wire
structure)** — consecutive, same as mixed-structure grouping, and since a
codec determines its wire pytree this subsumes the old mixed-codec
stacking — and each group serves through the codec-fused executable
(``plan.compiled_serve_batch(codec=...)``): per-request decode, stacked
scan, and per-frame answer re-encode all inside ONE jit.  Routing meta is
hoisted on the host exactly as before; the stacked wire answers are fetched
with ONE device_get, split as numpy, and pushed through the serversink's
wire-level route (``push_wire`` — byte accounting from static shapes, no
sync); deferred sparse-truncation counts ride out of the jit as one array
and sync once per flush.  Groups a mesh may take keep the PR-4 eager wire
path (host decode → placement probe → sharded serve → host encode), so the
sharding guarantees are untouched; ``fused=False`` restores the eager path
everywhere (the benchmark baseline).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from .buffers import StreamBuffer, structure_key, unstack_buffers
from .query import QueryServerEndpoint
from . import compression as comp

__all__ = ["BatchingPolicy", "QueryBatcher", "StreamingQueryBatcher",
           "DEFAULT_QUERY_BATCH"]

DEFAULT_QUERY_BATCH = 8

#: buffer meta keys that carry per-request routing, not payload semantics —
#: hoisted out before stacking and re-attached to the routed answer
_ROUTING_KEYS = ("client_id", "codec")


@dataclass(frozen=True)
class BatchingPolicy:
    """How a runtime gathers and flushes query requests.

    * ``max_batch <= 0`` disables batching entirely: clients keep the
      legacy synchronous round-trip inside ``tensor_query_client.apply``
      (one interpreted server step per request).
    * ``max_batch >= 1`` turns on queue-gather-flush: the scheduler defers
      query clients, gathers their requests, and flushes every endpoint at
      the tick deadline — or as soon as ``flush_on_full`` sees ``max_batch``
      requests pending.  Each flush serves in chunks of ``max_batch``
      through the compiled hoisted plan.
    """

    max_batch: int = DEFAULT_QUERY_BATCH
    flush_on_full: bool = True

    @classmethod
    def of(cls, value) -> "BatchingPolicy":
        if isinstance(value, cls):
            return value
        return cls(max_batch=int(value))

    @property
    def enabled(self) -> bool:
        return self.max_batch >= 1


class QueryBatcher:
    """Gather-decode-stack-dispatch-route loop for one server endpoint.

    ``run`` is the scheduler's pipeline-run record for the server pipeline
    (duck-typed: ``pipe``, ``params``, ``state``, ``frames``, ``bursts``,
    ``burst_frames``, ``last_outputs``, ``sink_log``); ``inline_step`` is a
    zero-arg callable performing one legacy interpreted server step
    (serversrc pull → … → serversink route) — the sequential fallback.
    """

    def __init__(self, endpoint: QueryServerEndpoint, run: Any,
                 policy: BatchingPolicy,
                 inline_step: Optional[Callable[[], Any]] = None,
                 mesh=None, shard_mode: str = "auto", fused: bool = True,
                 on_orphans: Optional[Callable[[int], None]] = None):
        if shard_mode not in ("auto", "always", "never"):
            raise ValueError(f"shard_mode {shard_mode!r} not in "
                             f"('auto', 'always', 'never')")
        self.endpoint = endpoint
        self.run = run
        self.policy = policy
        self.inline_step = inline_step
        #: called with the number of popped-but-unserved requests a flush
        #: abandons when its endpoint dies mid-flush (the runtime adds them
        #: to its orphan ledger; the paused frames re-dispatch from their
        #: PendingQuery records exactly like channel-purged orphans)
        self.on_orphans = on_orphans
        #: codec-fused serving (module docstring); False = PR-4 eager codec
        self.fused = fused
        #: jax Mesh to lay batches out on (None = single-device serving)
        self.mesh = mesh
        #: sharded-executable placement policy (module docstring)
        self.shard_mode = shard_mode
        #: batch size -> "sharded" | "single", decided by probe in auto mode
        self.placements: Dict[int, str] = {}
        #: mesh-placed (replicated) copy of the server params, built lazily
        #: at first sharded use: re-broadcasting params at every flush costs
        #: more than the serve itself, while handing mesh-replicated arrays
        #: to the single-device executable costs a gather per call — so each
        #: executable gets params in ITS OWN layout
        self._mesh_params = None
        # stats for Runtime.stats() / the batching benchmark
        self.flushes = 0
        self.batches = 0
        self.batched_frames = 0
        self.sequential_frames = 0
        self.sharded_batches = 0
        self.sharded_frames = 0
        self.fused_batches = 0
        self.fused_frames = 0
        self.orphaned = 0

    # -- public API ------------------------------------------------------------
    def in_flight(self, client_id: int) -> bool:
        """Whether ``client_id`` has a stream mid-generation on this server.
        Stateless batching answers every request within its flush, so the
        base batcher is never in flight; the streaming subclass overrides."""
        return False

    def pending(self) -> int:
        return len(self.endpoint.requests)

    def full(self) -> bool:
        pending = self.pending()
        # backpressure floor, independent of policy: the request Channel is
        # bounded (leaky-drop), so once the gather reaches its capacity we
        # MUST serve — one more send would silently drop a client's request
        # and its frame would then die with 'no answer' at the deadline
        if pending >= self.endpoint.requests.capacity:
            return True
        return self.policy.flush_on_full and \
            pending >= max(1, self.policy.max_batch)

    def flush(self) -> int:
        """Serve every pending request; returns the number served.

        Also wired as the endpoint's ``inline_runner`` so edge clients
        (``EdgeQueryClient.infer``) and direct ``pipe.step`` round-trips
        keep their serve-before-return contract unchanged.
        """
        if not self.endpoint.alive:
            # dead server: never serve — requests still on the endpoint are
            # orphans the scheduler re-dispatches from its own PendingQuery
            # records (the runtime purges the channel on the down event)
            return 0
        served = 0
        plan = self.run.pipe.plan
        # max_batch == 1 is still batching-enabled: a group of one serves
        # through the compiled hoisted path (the module contract above), so
        # turning the batch size down never silently changes execution mode
        batchable = self.policy.enabled and plan.query_batchable
        # liveness is re-checked before EVERY group, not only at entry: a
        # mark_down can land mid-flush (the serving chain itself announces
        # a death), and frames this flush already popped off the request
        # channel are invisible to the down event's purge — a corpse must
        # not keep serving them, so the remainder goes to the orphan ledger
        # and re-dispatches like any channel-purged orphan
        while self.pending() and self.endpoint.alive:
            if not batchable:
                while self.pending():
                    if not self.endpoint.alive:
                        break
                    self._serve_sequential()
                    served += 1
                continue
            raws = self.endpoint.requests.pop_n(self.policy.max_batch)
            if self.fused:
                groups = list(self._group_wire(raws))
                for gi, (pairs, codec) in enumerate(groups):
                    if not self.endpoint.alive:
                        self._orphan(sum(len(p) for p, _ in groups[gi:]))
                        break
                    if codec.partition(":")[0] == "none" or \
                            self._mesh_may_take(len(pairs)):
                        # nothing to fuse for "none" (decode/encode are
                        # identity — the fused executable would only add a
                        # per-flush answer fetch), and mesh placement needs
                        # dense frames (probe + sharded executable): both
                        # keep the eager wire path per PR-4, lazy answers —
                        # but the request decode still batches into one
                        # stacked dispatch
                        decoded = comp.decode_batch(
                            [clean for clean, _ in pairs], codec)
                        self._serve_batched(
                            [(dec, routing) for dec, (_, routing)
                             in zip(decoded, pairs)])
                    else:
                        self._serve_batched_wire(pairs, codec)
                    served += len(pairs)
            else:
                groups = list(self._group(raws))
                for gi, group in enumerate(groups):
                    if not self.endpoint.alive:
                        self._orphan(sum(len(g) for g in groups[gi:]))
                        break
                    self._serve_batched(group)
                    served += len(group)
        if served:
            self.flushes += 1
        return served

    def _orphan(self, n: int):
        """Account requests a dying flush popped but never served."""
        if n <= 0:
            return
        self.orphaned += n
        if self.on_orphans is not None:
            self.on_orphans(n)

    def on_reconfig(self):
        """The served pipeline was hot-swapped under this batcher: calibrated
        placements and mesh-placed params belong to the OLD plan/params —
        drop them so the next flush re-probes and re-places against the new
        epoch (the plan itself is always read through ``run.pipe``)."""
        self.placements.clear()
        self._mesh_params = None

    # -- gather & grouping -----------------------------------------------------
    def _decode(self, raw: StreamBuffer) -> Tuple[StreamBuffer, Dict]:
        """Host-level decode + routing-meta hoist: returns the clean frame
        (payload meta only) and the routing dict to re-attach on the answer.
        Routing is read off the WIRE buffer — decode strips the wire-form
        ``codec`` claim from the decoded frame, but the client's codec
        preference must still route its answer's re-encode."""
        codec = raw.meta.get("codec", "none")
        buf = comp.decode(raw, codec)
        routing = {k: raw.meta[k] for k in _ROUTING_KEYS if k in raw.meta}
        clean = buf.with_(meta={k: v for k, v in buf.meta.items()
                                if k not in _ROUTING_KEYS})
        return clean, routing

    @staticmethod
    def _structure(buf: StreamBuffer) -> Tuple:
        return structure_key(buf)

    def _group(self, raws: List[StreamBuffer]):
        """Split decoded requests into consecutive same-structure groups,
        preserving arrival order (so server state still threads through in
        FIFO order even when client caps are mixed)."""
        groups: List[List[Tuple[StreamBuffer, Dict]]] = []
        last_key = None
        for raw in raws:
            clean, routing = self._decode(raw)
            key = self._structure(clean)
            if groups and key == last_key:
                groups[-1].append((clean, routing))
            else:
                groups.append([(clean, routing)])
                last_key = key
        return groups

    def _group_wire(self, raws: List[StreamBuffer]):
        """Fused-path grouping: consecutive same-(codec, WIRE structure)
        runs of raw requests, arrival order preserved — no host decode.
        The codec is part of the key because it is the fused executable's
        static trace parameter (and two codecs' wire pytrees differ
        anyway), so mixed-codec batches split exactly like mixed-structure
        batches always have.  Yields ``([(clean_wire, routing), ...],
        codec)`` — the hoisted pairs the key was built from, so serving
        never re-hoists."""
        groups: List[Tuple[List[Tuple[StreamBuffer, Dict]], str]] = []
        last_key = None
        for raw in raws:
            codec = raw.meta.get("codec", "none")
            pair = self._hoist_wire(raw)
            key = (codec, self._structure(pair[0]))
            if groups and key == last_key:
                groups[-1][0].append(pair)
            else:
                groups.append(([pair], codec))
                last_key = key
        return groups

    def _hoist_wire(self, raw: StreamBuffer) -> Tuple[StreamBuffer, Dict]:
        """Routing hoist for a WIRE request: strip routing meta (as always)
        plus the wire-form meta — ``codec`` becomes the group's static
        trace parameter and ``sparse_dropped`` differs per frame, either
        would make same-shaped requests structurally unstackable."""
        routing = {k: raw.meta[k] for k in _ROUTING_KEYS if k in raw.meta}
        keep = {k: v for k, v in raw.meta.items()
                if k not in _ROUTING_KEYS and k not in comp._WIRE_META}
        return raw.with_(meta=keep), routing

    def _mesh_may_take(self, n: int) -> bool:
        """Whether mesh placement might claim this group — those groups
        need host-decoded dense frames (calibration probe + sharded
        executable input), so they keep the eager wire path.  A batch size
        whose calibrated placement already said "single" is NOT claimed:
        forfeiting codec fusion there would re-pay the eager per-frame
        codec cost for nothing (only the first, probe-carrying flush of a
        size goes eager in auto mode)."""
        if self.mesh is None or self.shard_mode == "never":
            return False
        if not self.run.pipe.plan.shardable_batch(n, self.run.state,
                                                  self.mesh):
            return False
        return self.shard_mode == "always" or \
            self.placements.get(n) != "single"

    # -- serving ---------------------------------------------------------------
    def _serve_sequential(self):
        """Legacy one-request interpreted step (also the fallback for server
        plans the hoisted scan cannot express)."""
        if self.inline_step is None:
            raise RuntimeError("sequential fallback needs an inline_step")
        self.inline_step()
        self.sequential_frames += 1

    def _pick_placement(self, n: int, frames_in: Tuple) -> bool:
        """Whether THIS group serves through the mesh-sharded executable.
        Groups the mesh cannot take (non-tiling size, stateful plan) always
        serve single-device; shardable groups follow ``shard_mode`` —
        forced, or probed once per batch size in auto mode."""
        plan = self.run.pipe.plan
        if self.mesh is None or \
                not plan.shardable_batch(n, self.run.state, self.mesh):
            return False
        if self.shard_mode != "auto":
            return self.shard_mode == "always"
        dec = self.placements.get(n)
        if dec is None:
            dec = self._calibrate(n, frames_in)
        return dec == "sharded"

    def _mesh_placed_params(self):
        """Replicated-on-the-mesh params (the launch/shardings.py spec for
        serving params), placed once and reused by every sharded serve."""
        if self._mesh_params is None:
            from ..launch.shardings import replicated
            self._mesh_params = jax.device_put(
                self.run.params, replicated(self.mesh, self.run.params))
        return self._mesh_params

    def _calibrate(self, n: int, frames_in: Tuple) -> str:
        """Probe both executables on this very batch and keep the faster
        for this size.  Both are bitwise-correct and the plan is stateless
        (shardable), so the probe serves are just discarded warm-ups —
        placement costs a handful of dispatches, once."""
        import time as _time
        run = self.run
        best = {}
        for label, mesh, params in (
                ("sharded", self.mesh, self._mesh_placed_params()),
                ("single", None, run.params)):
            fn = run.pipe.plan.compiled_serve_batch(mesh=mesh)
            fn(params, run.state, frames_in)       # compile + warm, untimed
            ts = []
            for _ in range(3):
                t0 = _time.perf_counter()
                # block: the single-device jit returns lazy arrays while the
                # sharded wrapper device_gets internally — timing dispatch
                # only would structurally bias the probe toward "single"
                jax.block_until_ready(fn(params, run.state, frames_in))
                ts.append(_time.perf_counter() - t0)
            best[label] = min(ts)
        dec = "sharded" if best["sharded"] <= best["single"] else "single"
        self.placements[n] = dec
        return dec

    def _serve_batched(self, group: List[Tuple[StreamBuffer, Dict]]):
        """One compiled dispatch over the whole group: stack, hoisted scan
        (serversrc frames injected, serversink answers captured), and
        per-frame split all happen INSIDE the jitted serve_batch, so the
        host pays a single dispatch per batch; the captured answers then
        replay through the real serversink apply with routing restored.
        Placement (mesh-sharded vs single-device executable) is decided by
        :meth:`_pick_placement`."""
        run = self.run
        plan = run.pipe.plan
        n = len(group)
        src = plan.query_sources[0].name
        frames_in = tuple({src: clean} for clean, _ in group)
        use_mesh = self._pick_placement(n, frames_in)
        serve = plan.compiled_serve_batch(mesh=self.mesh if use_mesh
                                          else None)
        params = self._mesh_placed_params() if use_mesh else run.params
        frames_out, run.state = serve(params, run.state, frames_in)
        for (_, routing), frame in zip(group, frames_out):
            self._route(frame, routing)
            run.frames += 1
        self.batched_frames += n
        if use_mesh:
            self.sharded_batches += 1
            self.sharded_frames += n
        if n > 1:
            self.batches += 1
            run.bursts += 1
            run.burst_frames += n

    def _serve_batched_wire(self, pairs: List[Tuple[StreamBuffer, Dict]],
                            codec: str):
        """One codec-fused dispatch over a same-(codec, structure) group of
        hoisted ``(clean_wire, routing)`` pairs: the requests go into the
        jit in WIRE form; decode, stacked scan and answer re-encode all
        happen inside ``serve_batch_wire``; the stacked wire answers come
        back in ONE device fetch (plus the deferred sparse-truncation
        counts — one sync per flush, not per tensor) and are routed as
        numpy frames through the serversink's wire-level push, with routing
        meta and the loss signal restored host-side.  Byte accounting is
        computed from static payload shapes."""
        run = self.run
        plan = run.pipe.plan
        n = len(pairs)
        src = plan.query_sources[0].name
        frames_in = tuple({src: clean} for clean, _ in pairs)
        serve = plan.compiled_serve_batch(codec=codec)
        (wire_outs, app_outs, dropped), run.state = serve(
            run.params, run.state, frames_in)
        wire_outs, app_outs, dropped = jax.device_get(
            (wire_outs, app_outs, dropped))
        base_codec = codec.partition(":")[0]
        wire_frames = {name: unstack_buffers(b, n)
                       for name, b in wire_outs.items()}
        app_frames = {name: unstack_buffers(b, n)
                      for name, b in app_outs.items()}
        for i, (_, routing) in enumerate(pairs):
            for name, frames in wire_frames.items():
                wb = frames[i]
                # per-sink deferred loss accounting: each sink's answer
                # carries ITS OWN truncation count, as the eager per-buffer
                # encode would stamp it
                frame_dropped = (comp.account_sparse_dropped(
                    dropped[name][:, i]) if name in dropped else 0)
                # meta layering matches the eager path: scan answer meta,
                # then routing, then the wire-form claims encode would stamp
                meta = {**wb.meta, **routing, "codec": base_codec}
                if frame_dropped:
                    meta["sparse_dropped"] = frame_dropped
                wb = wb.with_(meta=meta)
                run.pipe.elements[name].push_wire(
                    wb, comp.wire_nbytes(wb), routing["client_id"])
            outs_i = {name: frames[i] for name, frames in app_frames.items()}
            for name, buf in outs_i.items():
                run.sink_log.setdefault(name, []).append(buf)
            run.last_outputs = outs_i
            run.frames += 1
        self.batched_frames += n
        self.fused_batches += 1
        self.fused_frames += n
        if n > 1:
            self.batches += 1
            run.bursts += 1
            run.burst_frames += n

    def _route(self, frame_outs: Dict[str, StreamBuffer], routing: Dict):
        """Deliver one frame's captured outputs: serversink answers replay
        through the element's real apply (encode + client-channel push) with
        the hoisted routing meta restored; any app sinks land in the server
        run's sink log, matching the sequential bookkeeping."""
        run = self.run
        app_outs = {}
        for name, buf in frame_outs.items():
            elem = run.pipe.elements[name]
            if getattr(elem, "is_query_sink", False):
                answer = buf.with_(meta={**buf.meta, **routing})
                elem.apply(run.params.get(name, {}), [answer])
            else:
                app_outs[name] = buf
                run.sink_log.setdefault(name, []).append(buf)
        run.last_outputs = app_outs

    def stats(self) -> Dict[str, int]:
        return {"flushes": self.flushes, "batches": self.batches,
                "batched_frames": self.batched_frames,
                "sequential_frames": self.sequential_frames,
                "sharded_batches": self.sharded_batches,
                "sharded_frames": self.sharded_frames,
                "fused_batches": self.fused_batches,
                "fused_frames": self.fused_frames,
                "flush_orphans": self.orphaned}


class StreamingQueryBatcher(QueryBatcher):
    """Continuous-batching request lifecycle for a ``stream_serving`` server
    (DESIGN.md §7): prefill on arrival → N decode ticks in a slot of the
    plan-state decode batch → one answer when the budget is spent.

    Per flush (called every scheduler drain round):

    1. **admit** — pop every pending wire request, decode it (per-request
       codec, routing hoisted exactly like the stateless path), run the
       serve element's host prefill (first token + b=1 cache), and queue
       the stream for a slot.  ``gen <= 1`` answers immediately.
    2. **decode tick** — at most ONCE per scheduler tick (``tick_source``
       guard; the drain loop flushes many times per tick): assign free
       slots to waiting streams lowest-slot-first, assemble the admit
       bundle, and run ONE ``compiled_serve_tick`` dispatch over the whole
       slot table.  Joins and leaves are data (admit mask / finished lane),
       never a retrace.
    3. **finish** — slots whose ``finished`` lane fired deliver their
       accumulated tokens as one answer through the real serversink apply
       (per-client codec encode + channel route), and the slot frees.

    Conservation (pinned by the soak): ``tokens_generated ==
    tokens_delivered + tokens_dropped + inflight_tokens()`` — a dead
    endpoint aborts every live stream into ``tokens_dropped`` (their
    PendingQuery records re-dispatch with PREFILL REPLAY on a survivor,
    regenerating from scratch — greedy decode makes the re-generation
    bitwise, pinned by the chaos test)."""

    def __init__(self, *args, tick_source: Optional[Callable[[], int]] = None,
                 **kwargs):
        super().__init__(*args, **kwargs)
        self.tick_source = tick_source or (lambda: -1)
        self._slots: Dict[int, Dict] = {}       # slot -> stream record
        self._waiting: List[Dict] = []          # FIFO, no free slot yet
        self._replay: List[Dict] = []           # re-prefill on the next admit
        self._by_client: Dict[int, Dict] = {}
        self._last_decode_tick: Optional[int] = None
        self.prefills = 0
        self.replays = 0
        self.decode_ticks = 0
        self.tokens_generated = 0
        self.tokens_delivered = 0
        self.tokens_dropped = 0
        self.streams_started = 0
        self.streams_finished = 0

    # -- introspection ---------------------------------------------------------
    def in_flight(self, client_id: int) -> bool:
        return client_id in self._by_client

    def inflight_tokens(self) -> int:
        return sum(len(rec["tokens"]) for rec in self._by_client.values())

    def active_streams(self) -> int:
        return len(self._by_client)

    def _serve_elem(self):
        plan = self.run.pipe.plan
        for op in plan.ops:
            if getattr(op.elem, "is_stream_serve", False):
                return op.elem
        raise RuntimeError("StreamingQueryBatcher on a non-streaming plan")

    # -- lifecycle -------------------------------------------------------------
    def flush(self) -> int:
        if not self.endpoint.alive:
            self._abort_streams()
            return 0
        served = self._admit()
        tick = self.tick_source()
        if tick != self._last_decode_tick and (self._slots or self._waiting):
            self._last_decode_tick = tick
            served += self._decode_tick()
        if served:
            self.flushes += 1
        return served

    def _admit(self) -> int:
        """Pop + prefill every pending request; short generations answer
        here, the rest join the waiting FIFO (slot assignment happens at
        the next decode tick, so admission order is arrival order)."""
        finished = 0
        elem = self._serve_elem()
        params = self.run.params.get(elem.name, {})
        if self._replay:
            # hot-swap replay: streams orphaned by a committed reconfig
            # re-prefill on the NEW epoch's params (greedy decode — the
            # regeneration is bitwise what a fresh build answers)
            replays, self._replay = self._replay, []
            for rec in replays:
                tok, cache = elem.host_prefill(params, rec["prompt"])
                self.prefills += 1
                self.tokens_generated += 1
                rec["tokens"] = [tok]
                rec["remaining"] = max(0, rec["gen"] - 1)
                rec["cache"] = cache
                if rec["remaining"] <= 0:
                    self._finish(rec)
                    finished += 1
                else:
                    self._waiting.append(rec)
        while self.pending() and self.endpoint.alive:
            raw = self.endpoint.requests.pop()
            clean, routing = self._decode(raw)
            gen = int(clean.meta.get("gen", 1))
            tok, cache = elem.host_prefill(params, clean.tensors[0])
            self.prefills += 1
            self.streams_started += 1
            self.tokens_generated += 1
            rec = {"routing": routing, "tokens": [tok], "prompt":
                   clean.tensors[0], "gen": gen,
                   "remaining": max(0, gen - 1), "cache": cache}
            if rec["remaining"] <= 0:
                self._finish(rec)
                finished += 1
            else:
                self._waiting.append(rec)
                self._by_client[routing["client_id"]] = rec
        return finished

    def _decode_tick(self) -> int:
        """ONE stateful dispatch over the whole slot table: waiting streams
        join under the admit mask, every active slot emits a token, spent
        slots leave — all inside the same jitted program."""
        run = self.run
        plan = run.pipe.plan
        elem = self._serve_elem()
        free = sorted(s for s in range(elem.slots) if s not in self._slots)
        admits = []
        while free and self._waiting:
            rec = self._waiting.pop(0)
            slot = free.pop(0)
            admits.append((slot, rec["tokens"][-1], rec["remaining"],
                           rec["cache"]))
            rec["cache"] = None     # lives in plan state from here on
            self._slots[slot] = rec
        if not self._slots:
            return 0
        src = plan.query_sources[0].name
        sink = plan.query_sinks[0].name
        serve = plan.compiled_serve_tick(run.state)
        outputs, run.state = serve(run.params, run.state,
                                   {src: elem.build_admit(admits)})
        toks, emitted, finished = jax.device_get(outputs[sink].tensors)
        self.decode_ticks += 1
        run.frames += 1
        n_active = int(emitted.sum())
        self.batched_frames += n_active
        if n_active > 1:
            self.batches += 1
        done = 0
        for slot in sorted(self._slots):
            rec = self._slots[slot]
            if emitted[slot]:
                rec["tokens"].append(int(toks[slot]))
                self.tokens_generated += 1
            if finished[slot]:
                self._finish(rec)
                del self._slots[slot]
                done += 1
        return done

    def _finish(self, rec: Dict):
        """Deliver one completed stream: all its tokens as ONE answer
        through the real serversink apply (per-client codec encode +
        client-channel route — identical to the stateless routing path)."""
        import numpy as np
        routing = rec["routing"]
        sink = self.run.pipe.plan.query_sinks[0]
        answer = StreamBuffer(
            tensors=(np.asarray(rec["tokens"], np.int32),), meta=routing)
        sink.apply(self.run.params.get(sink.name, {}), [answer])
        self.tokens_delivered += len(rec["tokens"])
        self.streams_finished += 1
        self._by_client.pop(routing["client_id"], None)

    def on_reconfig(self):
        """The serve topology was hot-swapped under live streams: a swapped
        serve element's plan state re-initialized at commit (kept elements
        carry theirs, but the batcher cannot tell which epoch a slot's
        cache belongs to), so every in-flight stream REPLAYS — its partial
        tokens become declared drops and the stream re-prefills on the new
        epoch at the next flush.  Greedy decode makes the replay bitwise a
        fresh build's answer (pinned in tests/test_model_serving.py);
        stale still-active slots in carried plan state self-clear (their
        ``remaining`` lane drains to zero with no record listening)."""
        super().on_reconfig()
        recs = [self._slots[s] for s in sorted(self._slots)] + self._waiting
        self._slots.clear()
        self._waiting = []
        for rec in recs:
            self.tokens_dropped += len(rec["tokens"])
            self.replays += 1
            rec["tokens"] = []
            rec["cache"] = None
        self._replay.extend(recs)

    def _abort_streams(self):
        """Endpoint died: every live stream's partial tokens are DECLARED
        drops (conservation law) — the orphaned PendingQuery records
        re-dispatch with prefill replay on a survivor, so the client still
        loses zero tokens end-to-end."""
        if not self._by_client:
            return
        for rec in self._by_client.values():
            self.tokens_dropped += len(rec["tokens"])
        self._orphan(len(self._by_client))
        self._slots.clear()
        self._waiting.clear()
        self._replay.clear()
        self._by_client.clear()

    def stats(self) -> Dict[str, int]:
        base = super().stats()
        base.update({
            "prefills": self.prefills,
            "decode_ticks": self.decode_ticks,
            "tokens_generated": self.tokens_generated,
            "tokens_delivered": self.tokens_delivered,
            "tokens_dropped": self.tokens_dropped,
            "tokens_in_flight": self.inflight_tokens(),
            "streams_started": self.streams_started,
            "streams_finished": self.streams_finished,
            "replays": self.replays,
        })
        return base
