"""Server-side micro-batching for the query protocol (paper §4.2.2, Fig. 2).

The paper's offloading protocol answers one round-trip per frame.  On edge
silicon the per-dispatch host cost dominates long before the model does
(arXiv 2210.10514) — the same amortize-the-dispatch argument behind the
PR-1 burst engine.  This module batches *across clients*: concurrent
``tensor_query_client`` requests that land on one ``QueryServerEndpoint``
within a scheduler tick are gathered, decoded, stacked along a leading
frame axis, and served by ONE hoisted ``step_n`` scan dispatch through the
server pipeline's compiled plan; the stacked answers are unstacked and
routed back per ``client_id`` through the real serversink ``apply``.

Semantics are preserved relative to sequential serving:

* requests are served in channel FIFO order (= arrival order), and the
  server state threads through the scan in that order — frame ``i`` of a
  batch is exactly the ``i``-th sequential serve;
* per-request codecs survive: decode happens at gather time, encode at
  routing time, both through the unchanged ``compression`` code paths;
* routing meta (``client_id``, ``codec``) is hoisted out of the buffers
  before stacking (meta is static pytree aux — differing client ids would
  otherwise make frames structurally unstackable) and re-attached to each
  answer before the serversink replay.

Fallback rules (automatic, per flush):

* server plans that are not :attr:`ExecutionPlan.query_batchable` (extra
  impure elements, multiple serversrcs) serve sequentially through the
  runtime's interpreted per-request step — the pre-batching behavior;
* requests whose decoded frames differ in pytree structure or tensor
  shapes/dtypes (mixed caps across clients) are split into consecutive
  same-structure groups; a group of one is still served through the
  compiled hoisted path, so every answer leaves through the same execution
  mode and batch composition never changes numerics.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from .buffers import StreamBuffer
from .query import QueryServerEndpoint
from . import compression as comp

__all__ = ["BatchingPolicy", "QueryBatcher", "DEFAULT_QUERY_BATCH"]

DEFAULT_QUERY_BATCH = 8

#: buffer meta keys that carry per-request routing, not payload semantics —
#: hoisted out before stacking and re-attached to the routed answer
_ROUTING_KEYS = ("client_id", "codec")


@dataclass(frozen=True)
class BatchingPolicy:
    """How a runtime gathers and flushes query requests.

    * ``max_batch <= 0`` disables batching entirely: clients keep the
      legacy synchronous round-trip inside ``tensor_query_client.apply``
      (one interpreted server step per request).
    * ``max_batch >= 1`` turns on queue-gather-flush: the scheduler defers
      query clients, gathers their requests, and flushes every endpoint at
      the tick deadline — or as soon as ``flush_on_full`` sees ``max_batch``
      requests pending.  Each flush serves in chunks of ``max_batch``
      through the compiled hoisted plan.
    """

    max_batch: int = DEFAULT_QUERY_BATCH
    flush_on_full: bool = True

    @classmethod
    def of(cls, value) -> "BatchingPolicy":
        if isinstance(value, cls):
            return value
        return cls(max_batch=int(value))

    @property
    def enabled(self) -> bool:
        return self.max_batch >= 1


class QueryBatcher:
    """Gather-decode-stack-dispatch-route loop for one server endpoint.

    ``run`` is the scheduler's pipeline-run record for the server pipeline
    (duck-typed: ``pipe``, ``params``, ``state``, ``frames``, ``bursts``,
    ``burst_frames``, ``last_outputs``, ``sink_log``); ``inline_step`` is a
    zero-arg callable performing one legacy interpreted server step
    (serversrc pull → … → serversink route) — the sequential fallback.
    """

    def __init__(self, endpoint: QueryServerEndpoint, run: Any,
                 policy: BatchingPolicy,
                 inline_step: Optional[Callable[[], Any]] = None):
        self.endpoint = endpoint
        self.run = run
        self.policy = policy
        self.inline_step = inline_step
        # stats for Runtime.stats() / the batching benchmark
        self.flushes = 0
        self.batches = 0
        self.batched_frames = 0
        self.sequential_frames = 0

    # -- public API ------------------------------------------------------------
    def pending(self) -> int:
        return len(self.endpoint.requests)

    def full(self) -> bool:
        pending = self.pending()
        # backpressure floor, independent of policy: the request Channel is
        # bounded (leaky-drop), so once the gather reaches its capacity we
        # MUST serve — one more send would silently drop a client's request
        # and its frame would then die with 'no answer' at the deadline
        if pending >= self.endpoint.requests.capacity:
            return True
        return self.policy.flush_on_full and \
            pending >= max(1, self.policy.max_batch)

    def flush(self) -> int:
        """Serve every pending request; returns the number served.

        Also wired as the endpoint's ``inline_runner`` so edge clients
        (``EdgeQueryClient.infer``) and direct ``pipe.step`` round-trips
        keep their serve-before-return contract unchanged.
        """
        if not self.endpoint.alive:
            # dead server: never serve — requests still on the endpoint are
            # orphans the scheduler re-dispatches from its own PendingQuery
            # records (the runtime purges the channel on the down event)
            return 0
        served = 0
        plan = self.run.pipe.plan
        batchable = self.policy.max_batch > 1 and plan.query_batchable
        while self.pending():
            if not batchable:
                n = self.pending()
                for _ in range(n):
                    self._serve_sequential()
                served += n
                continue
            raws = self.endpoint.requests.pop_n(self.policy.max_batch)
            for group in self._group(raws):
                self._serve_batched(group)
                served += len(group)
        if served:
            self.flushes += 1
        return served

    # -- gather & grouping -----------------------------------------------------
    def _decode(self, raw: StreamBuffer) -> Tuple[StreamBuffer, Dict]:
        """Host-level decode + routing-meta hoist: returns the clean frame
        (payload meta only) and the routing dict to re-attach on the answer."""
        codec = raw.meta.get("codec", "none")
        buf = comp.decode(raw, codec)
        routing = {k: buf.meta[k] for k in _ROUTING_KEYS if k in buf.meta}
        clean = buf.with_(meta={k: v for k, v in buf.meta.items()
                                if k not in _ROUTING_KEYS})
        return clean, routing

    @staticmethod
    def _structure(buf: StreamBuffer) -> Tuple:
        leaves, treedef = jax.tree_util.tree_flatten(buf)
        return (treedef, tuple((getattr(l, "shape", ()),
                                str(getattr(l, "dtype", type(l))))
                               for l in leaves))

    def _group(self, raws: List[StreamBuffer]):
        """Split decoded requests into consecutive same-structure groups,
        preserving arrival order (so server state still threads through in
        FIFO order even when client caps are mixed)."""
        groups: List[List[Tuple[StreamBuffer, Dict]]] = []
        last_key = None
        for raw in raws:
            clean, routing = self._decode(raw)
            key = self._structure(clean)
            if groups and key == last_key:
                groups[-1].append((clean, routing))
            else:
                groups.append([(clean, routing)])
                last_key = key
        return groups

    # -- serving ---------------------------------------------------------------
    def _serve_sequential(self):
        """Legacy one-request interpreted step (also the fallback for server
        plans the hoisted scan cannot express)."""
        if self.inline_step is None:
            raise RuntimeError("sequential fallback needs an inline_step")
        self.inline_step()
        self.sequential_frames += 1

    def _serve_batched(self, group: List[Tuple[StreamBuffer, Dict]]):
        """One compiled dispatch over the whole group: stack, hoisted scan
        (serversrc frames injected, serversink answers captured), and
        per-frame split all happen INSIDE the jitted serve_batch, so the
        host pays a single dispatch per batch; the captured answers then
        replay through the real serversink apply with routing restored."""
        run = self.run
        plan = run.pipe.plan
        n = len(group)
        src = plan.query_sources[0].name
        serve = plan.compiled_serve_batch()
        frames_in = tuple({src: clean} for clean, _ in group)
        frames_out, run.state = serve(run.params, run.state, frames_in)
        for (_, routing), frame in zip(group, frames_out):
            self._route(frame, routing)
            run.frames += 1
        self.batched_frames += n
        if n > 1:
            self.batches += 1
            run.bursts += 1
            run.burst_frames += n

    def _route(self, frame_outs: Dict[str, StreamBuffer], routing: Dict):
        """Deliver one frame's captured outputs: serversink answers replay
        through the element's real apply (encode + client-channel push) with
        the hoisted routing meta restored; any app sinks land in the server
        run's sink log, matching the sequential bookkeeping."""
        run = self.run
        app_outs = {}
        for name, buf in frame_outs.items():
            elem = run.pipe.elements[name]
            if getattr(elem, "is_query_sink", False):
                answer = buf.with_(meta={**buf.meta, **routing})
                elem.apply(run.params.get(name, {}), [answer])
            else:
                app_outs[name] = buf
                run.sink_log.setdefault(name, []).append(buf)
        run.last_outputs = app_outs

    def stats(self) -> Dict[str, int]:
        return {"flushes": self.flushes, "batches": self.batches,
                "batched_frames": self.batched_frames,
                "sequential_frames": self.sequential_frames}
