"""Server-side micro-batching for the query protocol (paper §4.2.2, Fig. 2).

The paper's offloading protocol answers one round-trip per frame.  On edge
silicon the per-dispatch host cost dominates long before the model does
(arXiv 2210.10514) — the same amortize-the-dispatch argument behind the
PR-1 burst engine.  This module batches *across clients*: concurrent
``tensor_query_client`` requests that land on one ``QueryServerEndpoint``
within a scheduler tick are gathered, decoded, stacked along a leading
frame axis, and served by ONE hoisted ``step_n`` scan dispatch through the
server pipeline's compiled plan; the stacked answers are unstacked and
routed back per ``client_id`` through the real serversink ``apply``.

Semantics are preserved relative to sequential serving:

* requests are served in channel FIFO order (= arrival order), and the
  server state threads through the scan in that order — frame ``i`` of a
  batch is exactly the ``i``-th sequential serve;
* per-request codecs survive: decode happens at gather time, encode at
  routing time, both through the unchanged ``compression`` code paths;
* routing meta (``client_id``, ``codec``) is hoisted out of the buffers
  before stacking (meta is static pytree aux — differing client ids would
  otherwise make frames structurally unstackable) and re-attached to each
  answer before the serversink replay.

Fallback rules (automatic, per flush):

* server plans that are not :attr:`ExecutionPlan.query_batchable` (extra
  impure elements, multiple serversrcs) serve sequentially through the
  runtime's interpreted per-request step — the pre-batching behavior;
* requests whose decoded frames differ in pytree structure or tensor
  shapes/dtypes (mixed caps across clients) are split into consecutive
  same-structure groups; a group of one is still served through the
  compiled hoisted path, so every answer leaves through the same execution
  mode and batch composition never changes numerics.

Mesh sharding (DESIGN.md §4): when the runtime is built over a jax mesh
(``Runtime(mesh=...)``), the batcher also holds the mesh-sharded executable
— groups whose frame count tiles the mesh's data axes can serve with one
frame slice per device (``ExecutionPlan.shardable_batch``); every other
group keeps the single-device scan, so the answers are bitwise identical
either way.

Placement is a COST decision, not a faith decision: the dispatch-vs-silicon
gap (arXiv 2210.10514) cuts both ways — on real multi-chip meshes sharding
multiplies serving throughput, but on a host-forged mesh (8 "devices" on 2
cores) the SPMD dispatch overhead exceeds the whole single-device serve.
``shard_mode="auto"`` (default) therefore probes both executables once per
batch size at first use — a handful of extra dispatches, both bitwise
correct — and picks the faster for that size thereafter; ``"always"`` /
``"never"`` force the choice (tests force ``"always"`` to pin the sharded
path's semantics regardless of host speed).  Placement stays transparent to
elements and clients, NNStreamer-style: only latency changes.

Fused wire path (DESIGN.md §5, default on): the batcher does NOT decode
requests at gather time.  Wire-form requests group by **(codec, wire
structure)** — consecutive, same as mixed-structure grouping, and since a
codec determines its wire pytree this subsumes the old mixed-codec
stacking — and each group serves through the codec-fused executable
(``plan.compiled_serve_batch(codec=...)``): per-request decode, stacked
scan, and per-frame answer re-encode all inside ONE jit.  Routing meta is
hoisted on the host exactly as before; the stacked wire answers are fetched
with ONE device_get, split as numpy, and pushed through the serversink's
wire-level route (``push_wire`` — byte accounting from static shapes, no
sync); deferred sparse-truncation counts ride out of the jit as one array
and sync once per flush.  Groups a mesh may take keep the PR-4 eager wire
path (host decode → placement probe → sharded serve → host encode), so the
sharding guarantees are untouched; ``fused=False`` restores the eager path
everywhere (the benchmark baseline).

Admission layer (DESIGN.md §9): every batcher's queueing now runs through
one shared :class:`~repro.core.admission.AdmissionQueue` — requests pop
off the endpoint Channel into per-tenant session queues at flush time, and
the dequeue is the scheduling function (pure global FIFO when QoS is off —
bitwise the old channel ``pop_n`` — weighted-fair across priority classes
with EDF within a class when a :class:`~repro.core.admission.QoSConfig` is
installed).  Scheduling changes ordering and admission, never answers:
whatever ``take`` returns flows through the exact serve paths documented
above, so the parity pins are out of scope by construction.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from .admission import AdmissionQueue, QoSConfig
from .buffers import StreamBuffer, structure_key, unstack_buffers
from .query import QueryServerEndpoint
from . import compression as comp
from . import netfault

__all__ = ["BatchingPolicy", "QueryBatcher", "StreamingQueryBatcher",
           "StagedStreamingBatcher", "StageQueryBatcher",
           "DEFAULT_QUERY_BATCH"]

DEFAULT_QUERY_BATCH = 8

#: buffer meta keys that carry per-request routing, not payload semantics —
#: hoisted out before stacking and re-attached to the routed answer
#: (``tenant_id`` rides along so admission can book the request before the
#: hoist and the answer still names its tenant; ``dseq`` — the §10 delivery
#: id — varies per frame, so leaving it in meta would split every stacking
#: group down to singletons)
_ROUTING_KEYS = ("client_id", "codec", "tenant_id", "dseq")


@dataclass(frozen=True)
class BatchingPolicy:
    """How a runtime gathers and flushes query requests.

    * ``max_batch <= 0`` disables batching entirely: clients keep the
      legacy synchronous round-trip inside ``tensor_query_client.apply``
      (one interpreted server step per request).
    * ``max_batch >= 1`` turns on queue-gather-flush: the scheduler defers
      query clients, gathers their requests, and flushes every endpoint at
      the tick deadline — or as soon as ``flush_on_full`` sees ``max_batch``
      requests pending.  Each flush serves in chunks of ``max_batch``
      through the compiled hoisted plan.
    """

    max_batch: int = DEFAULT_QUERY_BATCH
    flush_on_full: bool = True

    @classmethod
    def of(cls, value) -> "BatchingPolicy":
        if isinstance(value, cls):
            return value
        return cls(max_batch=int(value))

    @property
    def enabled(self) -> bool:
        return self.max_batch >= 1


class QueryBatcher:
    """Gather-decode-stack-dispatch-route loop for one server endpoint.

    ``run`` is the scheduler's pipeline-run record for the server pipeline
    (duck-typed: ``pipe``, ``params``, ``state``, ``frames``, ``bursts``,
    ``burst_frames``, ``last_outputs``, ``sink_log``); ``inline_step`` is a
    zero-arg callable performing one legacy interpreted server step
    (serversrc pull → … → serversink route) — the sequential fallback.
    """

    def __init__(self, endpoint: QueryServerEndpoint, run: Any,
                 policy: BatchingPolicy,
                 inline_step: Optional[Callable[[], Any]] = None,
                 mesh=None, shard_mode: str = "auto", fused: bool = True,
                 on_orphans: Optional[Callable[[int], None]] = None,
                 *, qos: Optional[QoSConfig] = None,
                 clock: Optional[Callable[[], int]] = None):
        if shard_mode not in ("auto", "always", "never"):
            raise ValueError(f"shard_mode {shard_mode!r} not in "
                             f"('auto', 'always', 'never')")
        self.endpoint = endpoint
        self.run = run
        self.policy = policy
        self.inline_step = inline_step
        #: THE queueing/shedding/accounting core (module docstring): with
        #: qos=None this is an exact global-FIFO pass-through and the only
        #: change vs popping the channel directly is the per-tenant ledger
        self.admission = AdmissionQueue(qos=qos, clock=clock)
        #: called with the number of popped-but-unserved requests a flush
        #: abandons when its endpoint dies mid-flush (the runtime adds them
        #: to its orphan ledger; the paused frames re-dispatch from their
        #: PendingQuery records exactly like channel-purged orphans)
        self.on_orphans = on_orphans
        #: delivery guard (DESIGN.md §10), installed by the runtime when a
        #: DeliveryPolicy is on: every request this batcher ingests passes
        #: CRC + dedup triage first.  None (the default) is the pre-§10
        #: wire, bit for bit.
        self.guard = None
        #: codec-fused serving (module docstring); False = PR-4 eager codec
        self.fused = fused
        #: jax Mesh to lay batches out on (None = single-device serving)
        self.mesh = mesh
        #: sharded-executable placement policy (module docstring)
        self.shard_mode = shard_mode
        #: batch size -> "sharded" | "single", decided by probe in auto mode
        self.placements: Dict[int, str] = {}
        #: mesh-placed (replicated) copy of the server params, built lazily
        #: at first sharded use: re-broadcasting params at every flush costs
        #: more than the serve itself, while handing mesh-replicated arrays
        #: to the single-device executable costs a gather per call — so each
        #: executable gets params in ITS OWN layout
        self._mesh_params = None
        # stats for Runtime.stats() / the batching benchmark
        self.flushes = 0
        self.batches = 0
        self.batched_frames = 0
        self.sequential_frames = 0
        self.sharded_batches = 0
        self.sharded_frames = 0
        self.fused_batches = 0
        self.fused_frames = 0
        self.orphaned = 0

    # -- public API ------------------------------------------------------------
    def in_flight(self, client_id: int) -> bool:
        """Whether ``client_id`` has work the scheduler must keep waiting
        on at this server.  Stateless batching answers every DEQUEUED
        request within its flush, but a QoS serve budget may hold the
        request queued across ticks — still in flight, not lost; the
        streaming subclass additionally tracks mid-generation streams."""
        return self.admission.queued_for(client_id) > 0

    def pending(self) -> int:
        return len(self.endpoint.requests) + len(self.admission)

    def full(self) -> bool:
        # backpressure floor, independent of policy: the request Channel is
        # bounded (leaky-drop), so once the gather reaches its capacity we
        # MUST serve — one more send would silently drop a client's request
        # and its frame would then die with 'no answer' at the deadline
        if len(self.endpoint.requests) >= self.endpoint.requests.capacity:
            return True
        return self.policy.flush_on_full and \
            self.pending() >= max(1, self.policy.max_batch)

    def flush(self) -> int:
        """Serve every pending request; returns the number served.

        Also wired as the endpoint's ``inline_runner`` so edge clients
        (``EdgeQueryClient.infer``) and direct ``pipe.step`` round-trips
        keep their serve-before-return contract unchanged.
        """
        if not self.endpoint.alive:
            # dead server: never serve — requests still on the endpoint
            # channel are orphans the scheduler re-dispatches from its own
            # PendingQuery records (the runtime purges the channel on the
            # down event); requests already ADMITTED here close on this
            # queue's ledger as server-died sheds (their re-dispatch is a
            # fresh admission at the survivor, so conservation holds both
            # per queue and summed)
            self._shed_dead()
            return 0
        adm = self.admission
        served = 0
        plan = self.run.pipe.plan
        # max_batch == 1 is still batching-enabled: a group of one serves
        # through the compiled hoisted path (the module contract above), so
        # turning the batch size down never silently changes execution mode
        batchable = self.policy.enabled and plan.query_batchable
        # liveness is re-checked before EVERY group, not only at entry: a
        # mark_down can land mid-flush (the serving chain itself announces
        # a death), and frames this flush already dequeued are invisible to
        # the down event's purge — a corpse must not keep serving them, so
        # the remainder goes to the orphan ledger and re-dispatches like
        # any channel-purged orphan
        while self.endpoint.alive:
            # re-ingest every round: serving can land new requests on the
            # channel (inline chains), exactly as the old per-iteration
            # channel check saw them
            self._ingest()
            adm.expire()
            if not len(adm):
                break
            if not batchable:
                recs = adm.take(1)
                if not recs:
                    break               # serve budget spent this tick
                self._serve_sequential(recs[0])
                served += 1
                continue
            recs = adm.take(self.policy.max_batch)
            if not recs:
                break                   # serve budget spent this tick
            raws = [r.raw for r in recs]
            idx = 0
            if self.fused:
                for pairs, codec in self._group_wire(raws):
                    if not self.endpoint.alive:
                        self._shed_flush_remainder(recs[idx:])
                        break
                    if codec.partition(":")[0] == "none" or \
                            self._mesh_may_take(len(pairs)):
                        # nothing to fuse for "none" (decode/encode are
                        # identity — the fused executable would only add a
                        # per-flush answer fetch), and mesh placement needs
                        # dense frames (probe + sharded executable): both
                        # keep the eager wire path per PR-4, lazy answers —
                        # but the request decode still batches into one
                        # stacked dispatch
                        decoded = comp.decode_batch(
                            [clean for clean, _ in pairs], codec)
                        self._serve_batched(
                            [(dec, routing) for dec, (_, routing)
                             in zip(decoded, pairs)])
                    else:
                        self._serve_batched_wire(pairs, codec)
                    for rec in recs[idx:idx + len(pairs)]:
                        adm.mark_served(rec)
                    idx += len(pairs)
                    served += len(pairs)
            else:
                for group in self._group(raws):
                    if not self.endpoint.alive:
                        self._shed_flush_remainder(recs[idx:])
                        break
                    self._serve_batched(group)
                    for rec in recs[idx:idx + len(group)]:
                        adm.mark_served(rec)
                    idx += len(group)
                    served += len(group)
        if served:
            self.flushes += 1
        return served

    def _ingest(self):
        """Drain the endpoint channel into admission — through the delivery
        guard when the runtime installed one (DESIGN.md §10).  Guard triage:
        corrupt frames are rejected and counted (never silently consumed),
        duplicates dedup against the LRU window and re-fire the committed
        answer's bitwise replay (a retransmit means the client never saw
        it), and accepted frames shed their wire checksum — it
        authenticated THIS hop; the answer gets its own — before admitting
        exactly as the guard-less path would."""
        ch = self.endpoint.requests
        if self.guard is None:
            self.admission.ingest_channel(ch)
            return
        while True:
            raw = ch.pop()
            if raw is None:
                return
            verdict = self.guard.check(raw, ch)
            if verdict == "ok":
                meta = raw.meta or {}
                if "crc" in meta:
                    # the wire frame owns its meta dict (every send path
                    # builds it fresh), so shed the checksum in place —
                    # a with_ copy per accepted request is pure overhead
                    del meta["crc"]
                self.admission.ingest(raw)
            elif verdict == "dup":
                self.guard.replay_answer((raw.meta or {}).get("dseq"))
            # "corrupt": counted by the guard; the frame dies here

    def _forget_delivery(self, rec):
        """Evict a shed-unserved request's delivery id from the dedup
        window: its failover re-dispatch reuses the id (idempotence key),
        and a window that still remembers it would dedup the retry into a
        void — a silent loss the §10 conservation law forbids."""
        if self.guard is None or rec is None:
            return
        raw = getattr(rec, "raw", None)
        if raw is not None:
            self.guard.forget((raw.meta or {}).get("dseq"))

    def _orphan(self, n: int):
        """Account requests a dying flush popped but never served."""
        if n <= 0:
            return
        self.orphaned += n
        if self.on_orphans is not None:
            self.on_orphans(n)

    def _shed_flush_remainder(self, recs):
        """Close the dequeued-but-unserved tail of a dying flush: shed on
        the tenant ledger (reason ``server-died``, no client notice — the
        scheduler re-dispatches these from their PendingQuery records and
        the client gets a real answer elsewhere) + the orphan ledger."""
        for rec in recs:
            self.admission.mark_shed(rec, "server-died", notify=False)
            self._forget_delivery(rec)
        self._orphan(len(recs))

    def _shed_dead(self) -> int:
        """Endpoint is dead: everything still queued in admission sheds
        (``server-died``) and joins the orphan ledger for re-dispatch."""
        n = self.admission.shed_queued("server-died", on_shed=self._forget_delivery)
        self._orphan(n)
        return n

    def on_reconfig(self):
        """The served pipeline was hot-swapped under this batcher: calibrated
        placements and mesh-placed params belong to the OLD plan/params —
        drop them so the next flush re-probes and re-places against the new
        epoch (the plan itself is always read through ``run.pipe``)."""
        self.placements.clear()
        self._mesh_params = None

    # -- gather & grouping -----------------------------------------------------
    def _decode(self, raw: StreamBuffer) -> Tuple[StreamBuffer, Dict]:
        """Host-level decode + routing-meta hoist: returns the clean frame
        (payload meta only) and the routing dict to re-attach on the answer.
        Routing is read off the WIRE buffer — decode strips the wire-form
        ``codec`` claim from the decoded frame, but the client's codec
        preference must still route its answer's re-encode."""
        codec = raw.meta.get("codec", "none")
        buf = comp.decode(raw, codec)
        routing = {k: raw.meta[k] for k in _ROUTING_KEYS if k in raw.meta}
        clean = buf.with_(meta={k: v for k, v in buf.meta.items()
                                if k not in _ROUTING_KEYS})
        return clean, routing

    @staticmethod
    def _structure(buf: StreamBuffer) -> Tuple:
        return structure_key(buf)

    def _group(self, raws: List[StreamBuffer]):
        """Split decoded requests into consecutive same-structure groups,
        preserving arrival order (so server state still threads through in
        FIFO order even when client caps are mixed)."""
        groups: List[List[Tuple[StreamBuffer, Dict]]] = []
        last_key = None
        for raw in raws:
            clean, routing = self._decode(raw)
            key = self._structure(clean)
            if groups and key == last_key:
                groups[-1].append((clean, routing))
            else:
                groups.append([(clean, routing)])
                last_key = key
        return groups

    def _group_wire(self, raws: List[StreamBuffer]):
        """Fused-path grouping: consecutive same-(codec, WIRE structure)
        runs of raw requests, arrival order preserved — no host decode.
        The codec is part of the key because it is the fused executable's
        static trace parameter (and two codecs' wire pytrees differ
        anyway), so mixed-codec batches split exactly like mixed-structure
        batches always have.  Yields ``([(clean_wire, routing), ...],
        codec)`` — the hoisted pairs the key was built from, so serving
        never re-hoists."""
        groups: List[Tuple[List[Tuple[StreamBuffer, Dict]], str]] = []
        last_key = None
        for raw in raws:
            codec = raw.meta.get("codec", "none")
            pair = self._hoist_wire(raw)
            key = (codec, self._structure(pair[0]))
            if groups and key == last_key:
                groups[-1][0].append(pair)
            else:
                groups.append(([pair], codec))
                last_key = key
        return groups

    def _hoist_wire(self, raw: StreamBuffer) -> Tuple[StreamBuffer, Dict]:
        """Routing hoist for a WIRE request: strip routing meta (as always)
        plus the wire-form meta — ``codec`` becomes the group's static
        trace parameter and ``sparse_dropped`` differs per frame, either
        would make same-shaped requests structurally unstackable."""
        routing = {k: raw.meta[k] for k in _ROUTING_KEYS if k in raw.meta}
        keep = {k: v for k, v in raw.meta.items()
                if k not in _ROUTING_KEYS and k not in comp._WIRE_META}
        return raw.with_(meta=keep), routing

    def _mesh_may_take(self, n: int) -> bool:
        """Whether mesh placement might claim this group — those groups
        need host-decoded dense frames (calibration probe + sharded
        executable input), so they keep the eager wire path.  A batch size
        whose calibrated placement already said "single" is NOT claimed:
        forfeiting codec fusion there would re-pay the eager per-frame
        codec cost for nothing (only the first, probe-carrying flush of a
        size goes eager in auto mode)."""
        if self.mesh is None or self.shard_mode == "never":
            return False
        if not self.run.pipe.plan.shardable_batch(n, self.run.state,
                                                  self.mesh):
            return False
        return self.shard_mode == "always" or \
            self.placements.get(n) != "single"

    # -- serving ---------------------------------------------------------------
    def _serve_sequential(self, rec=None):
        """Legacy one-request interpreted step (also the fallback for server
        plans the hoisted scan cannot express).  ``rec`` is the admission
        record whose raw request this step serves: it re-enters the HEAD of
        the request channel (``appendleft`` — no double byte/msg
        accounting) so the interpreted serversrc pull sees exactly the
        pre-admission world, then closes served on the ledger."""
        if self.inline_step is None:
            raise RuntimeError("sequential fallback needs an inline_step")
        if rec is not None:
            self.endpoint.requests.q.appendleft(rec.raw)
        self.inline_step()
        self.sequential_frames += 1
        if rec is not None:
            self.admission.mark_served(rec)

    def _pick_placement(self, n: int, frames_in: Tuple) -> bool:
        """Whether THIS group serves through the mesh-sharded executable.
        Groups the mesh cannot take (non-tiling size, stateful plan) always
        serve single-device; shardable groups follow ``shard_mode`` —
        forced, or probed once per batch size in auto mode."""
        plan = self.run.pipe.plan
        if self.mesh is None or \
                not plan.shardable_batch(n, self.run.state, self.mesh):
            return False
        if self.shard_mode != "auto":
            return self.shard_mode == "always"
        dec = self.placements.get(n)
        if dec is None:
            dec = self._calibrate(n, frames_in)
        return dec == "sharded"

    def _mesh_placed_params(self):
        """Replicated-on-the-mesh params (the launch/shardings.py spec for
        serving params), placed once and reused by every sharded serve."""
        if self._mesh_params is None:
            from ..launch.shardings import replicated
            self._mesh_params = jax.device_put(
                self.run.params, replicated(self.mesh, self.run.params))
        return self._mesh_params

    def _calibrate(self, n: int, frames_in: Tuple) -> str:
        """Probe both executables on this very batch and keep the faster
        for this size.  Both are bitwise-correct and the plan is stateless
        (shardable), so the probe serves are just discarded warm-ups —
        placement costs a handful of dispatches, once."""
        import time as _time
        run = self.run
        best = {}
        for label, mesh, params in (
                ("sharded", self.mesh, self._mesh_placed_params()),
                ("single", None, run.params)):
            fn = run.pipe.plan.compiled_serve_batch(mesh=mesh)
            fn(params, run.state, frames_in)       # compile + warm, untimed
            ts = []
            for _ in range(3):
                t0 = _time.perf_counter()
                # block: the single-device jit returns lazy arrays while the
                # sharded wrapper device_gets internally — timing dispatch
                # only would structurally bias the probe toward "single"
                jax.block_until_ready(fn(params, run.state, frames_in))
                ts.append(_time.perf_counter() - t0)
            best[label] = min(ts)
        dec = "sharded" if best["sharded"] <= best["single"] else "single"
        self.placements[n] = dec
        return dec

    def _serve_batched(self, group: List[Tuple[StreamBuffer, Dict]]):
        """One compiled dispatch over the whole group: stack, hoisted scan
        (serversrc frames injected, serversink answers captured), and
        per-frame split all happen INSIDE the jitted serve_batch, so the
        host pays a single dispatch per batch; the captured answers then
        replay through the real serversink apply with routing restored.
        Placement (mesh-sharded vs single-device executable) is decided by
        :meth:`_pick_placement`."""
        run = self.run
        plan = run.pipe.plan
        n = len(group)
        src = plan.query_sources[0].name
        frames_in = tuple({src: clean} for clean, _ in group)
        use_mesh = self._pick_placement(n, frames_in)
        serve = plan.compiled_serve_batch(mesh=self.mesh if use_mesh
                                          else None)
        params = self._mesh_placed_params() if use_mesh else run.params
        frames_out, run.state = serve(params, run.state, frames_in)
        for (_, routing), frame in zip(group, frames_out):
            self._route(frame, routing)
            run.frames += 1
        self.batched_frames += n
        if use_mesh:
            self.sharded_batches += 1
            self.sharded_frames += n
        if n > 1:
            self.batches += 1
            run.bursts += 1
            run.burst_frames += n

    def _serve_batched_wire(self, pairs: List[Tuple[StreamBuffer, Dict]],
                            codec: str):
        """One codec-fused dispatch over a same-(codec, structure) group of
        hoisted ``(clean_wire, routing)`` pairs: the requests go into the
        jit in WIRE form; decode, stacked scan and answer re-encode all
        happen inside ``serve_batch_wire``; the stacked wire answers come
        back in ONE device fetch (plus the deferred sparse-truncation
        counts — one sync per flush, not per tensor) and are routed as
        numpy frames through the serversink's wire-level push, with routing
        meta and the loss signal restored host-side.  Byte accounting is
        computed from static payload shapes."""
        run = self.run
        plan = run.pipe.plan
        n = len(pairs)
        src = plan.query_sources[0].name
        frames_in = tuple({src: clean} for clean, _ in pairs)
        serve = plan.compiled_serve_batch(codec=codec)
        (wire_outs, app_outs, dropped), run.state = serve(
            run.params, run.state, frames_in)
        wire_outs, app_outs, dropped = jax.device_get(
            (wire_outs, app_outs, dropped))
        base_codec = codec.partition(":")[0]
        wire_frames = {name: unstack_buffers(b, n)
                       for name, b in wire_outs.items()}
        app_frames = {name: unstack_buffers(b, n)
                      for name, b in app_outs.items()}
        for i, (_, routing) in enumerate(pairs):
            for name, frames in wire_frames.items():
                wb = frames[i]
                # per-sink deferred loss accounting: each sink's answer
                # carries ITS OWN truncation count, as the eager per-buffer
                # encode would stamp it
                frame_dropped = (comp.account_sparse_dropped(
                    dropped[name][:, i]) if name in dropped else 0)
                # meta layering matches the eager path: scan answer meta,
                # then routing, then the wire-form claims encode would stamp
                meta = {**wb.meta, **routing, "codec": base_codec}
                if frame_dropped:
                    meta["sparse_dropped"] = frame_dropped
                wb = wb.with_(meta=meta)
                run.pipe.elements[name].push_wire(
                    wb, comp.wire_nbytes(wb), routing["client_id"])
            outs_i = {name: frames[i] for name, frames in app_frames.items()}
            for name, buf in outs_i.items():
                run.sink_log.setdefault(name, []).append(buf)
            run.last_outputs = outs_i
            run.frames += 1
        self.batched_frames += n
        self.fused_batches += 1
        self.fused_frames += n
        if n > 1:
            self.batches += 1
            run.bursts += 1
            run.burst_frames += n

    def _route(self, frame_outs: Dict[str, StreamBuffer], routing: Dict):
        """Deliver one frame's captured outputs: serversink answers replay
        through the element's real apply (encode + client-channel push) with
        the hoisted routing meta restored; any app sinks land in the server
        run's sink log, matching the sequential bookkeeping."""
        run = self.run
        app_outs = {}
        for name, buf in frame_outs.items():
            elem = run.pipe.elements[name]
            if getattr(elem, "is_query_sink", False):
                answer = buf.with_(meta={**buf.meta, **routing})
                elem.apply(run.params.get(name, {}), [answer])
            else:
                app_outs[name] = buf
                run.sink_log.setdefault(name, []).append(buf)
        run.last_outputs = app_outs

    def stats(self) -> Dict[str, int]:
        """Unified base schema every batcher shares (subclasses EXTEND this
        dict, never replace keys): flush/dispatch counters plus the
        admission totals whose conservation law ``admitted == served +
        shed + queued + in_flight`` Runtime.stats() asserts."""
        adm = self.admission.stats()
        return {"flushes": self.flushes, "batches": self.batches,
                "batched_frames": self.batched_frames,
                "sequential_frames": self.sequential_frames,
                "sharded_batches": self.sharded_batches,
                "sharded_frames": self.sharded_frames,
                "fused_batches": self.fused_batches,
                "fused_frames": self.fused_frames,
                "flush_orphans": self.orphaned,
                "admitted_requests": sum(t["admitted"] for t in
                                         adm.values()),
                "served_requests": sum(t["served"] for t in adm.values()),
                "shed_requests": sum(t["shed"] for t in adm.values()),
                "queued_requests": sum(t["queued"] for t in adm.values())}

    def tenant_stats(self) -> Dict[str, Dict]:
        """Per-tenant ledgers for ``Runtime.stats()["tenants"]``."""
        return self.admission.stats()


class StreamingQueryBatcher(QueryBatcher):
    """Continuous-batching request lifecycle for a ``stream_serving`` server
    (DESIGN.md §7): prefill on arrival → N decode ticks in a slot of the
    plan-state decode batch → one answer when the budget is spent.

    Per flush (called every scheduler drain round):

    1. **admit** — pop every pending wire request, decode it (per-request
       codec, routing hoisted exactly like the stateless path), run the
       serve element's host prefill (first token + b=1 cache), and queue
       the stream for a slot.  ``gen <= 1`` answers immediately.
    2. **decode tick** — at most ONCE per scheduler tick (``tick_source``
       guard; the drain loop flushes many times per tick): assign free
       slots to waiting streams lowest-slot-first, assemble the admit
       bundle, and run ONE ``compiled_serve_tick`` dispatch over the whole
       slot table.  Joins and leaves are data (admit mask / finished lane),
       never a retrace.
    3. **finish** — slots whose ``finished`` lane fired deliver their
       accumulated tokens as one answer through the real serversink apply
       (per-client codec encode + channel route), and the slot frees.

    Conservation (pinned by the soak): ``tokens_generated ==
    tokens_delivered + tokens_dropped + inflight_tokens()`` — a dead
    endpoint aborts every live stream into ``tokens_dropped`` (their
    PendingQuery records re-dispatch with PREFILL REPLAY on a survivor,
    regenerating from scratch — greedy decode makes the re-generation
    bitwise, pinned by the chaos test)."""

    def __init__(self, *args, tick_source: Optional[Callable[[], int]] = None,
                 **kwargs):
        super().__init__(*args, **kwargs)
        if tick_source is None:
            # standalone batcher (no scheduler): a monotonic counter, so
            # EVERY flush is its own decode tick.  A constant default would
            # satisfy the once-per-tick guard exactly once ever and then
            # freeze decode forever (regression-pinned).
            counter = itertools.count()
            tick_source = lambda: next(counter)          # noqa: E731
        self.tick_source = tick_source
        self._slots: Dict[int, Dict] = {}       # slot -> stream record
        self._waiting: List[Dict] = []          # FIFO, no free slot yet
        self._replay: List[Dict] = []           # re-prefill on the next admit
        #: client_id -> FIFO of live stream records.  Keyed per REQUEST
        #: (a list per client), not one record per client: a client may
        #: pipeline a second prompt while its first stream is in flight,
        #: and overwriting would orphan the first record — undercounting
        #: inflight_tokens(), silently breaking conservation, and hiding
        #: the orphan from _abort_streams (regression-pinned).
        self._by_client: Dict[int, List[Dict]] = {}
        self._last_decode_tick: Optional[int] = None
        self.prefills = 0
        self.replays = 0
        self.decode_ticks = 0
        self.tokens_generated = 0
        self.tokens_delivered = 0
        self.tokens_dropped = 0
        self.streams_started = 0
        self.streams_finished = 0

    # -- introspection ---------------------------------------------------------
    def in_flight(self, client_id: int) -> bool:
        return bool(self._by_client.get(client_id)) or \
            super().in_flight(client_id)

    def inflight_tokens(self) -> int:
        return sum(len(rec["tokens"]) for recs in self._by_client.values()
                   for rec in recs)

    def active_streams(self) -> int:
        return sum(len(recs) for recs in self._by_client.values())

    def _track(self, rec: Dict):
        self._by_client.setdefault(rec["routing"]["client_id"],
                                   []).append(rec)

    def _untrack(self, rec: Dict):
        """Drop ONE record by identity (two streams of the same prompt from
        one client compare equal — ``list.remove`` would drop the wrong
        one)."""
        cid = rec["routing"]["client_id"]
        recs = self._by_client.get(cid)
        if not recs:
            return
        for i, r in enumerate(recs):
            if r is rec:
                del recs[i]
                break
        if not recs:
            del self._by_client[cid]

    def _serve_elem(self):
        plan = self.run.pipe.plan
        for op in plan.ops:
            if getattr(op.elem, "is_stream_serve", False):
                return op.elem
        raise RuntimeError("StreamingQueryBatcher on a non-streaming plan")

    # -- lifecycle -------------------------------------------------------------
    def flush(self) -> int:
        if not self.endpoint.alive:
            self._abort_streams()
            return 0
        served = self._admit()
        tick = self.tick_source()
        if tick != self._last_decode_tick and self._has_decode_work():
            self._last_decode_tick = tick
            served += self._decode_tick()
        if served:
            self.flushes += 1
        return served

    def _has_decode_work(self) -> bool:
        return bool(self._slots or self._waiting)

    def _admit(self) -> int:
        """Ingest + prefill every admitted request; short generations
        answer here, the rest join the waiting pool (slot assignment
        happens at the next decode tick — arrival order when QoS is off,
        ``(priority, deadline, arrival)`` order when it is on: slot
        admission honors tenant priority, and since a slotted stream is
        never evicted before its ``finished`` lane fires, preemption only
        ever happens at generation boundaries)."""
        finished = 0
        elem = self._serve_elem()
        params = self.run.params.get(elem.name, {})
        if self._replay:
            # hot-swap replay: streams orphaned by a committed reconfig
            # re-prefill on the NEW epoch's params (greedy decode — the
            # regeneration is bitwise what a fresh build answers)
            replays, self._replay = self._replay, []
            for rec in replays:
                tok, cache = elem.host_prefill(params, rec["prompt"])
                self.prefills += 1
                self.tokens_generated += 1
                rec["tokens"] = [tok]
                rec["remaining"] = max(0, rec["gen"] - 1)
                rec["cache"] = cache
                if rec["remaining"] <= 0:
                    self._finish(rec)
                    finished += 1
                else:
                    self._waiting.append(rec)
        adm = self.admission
        while self.endpoint.alive:
            self._ingest()
            adm.expire()
            recs = adm.take(1)
            if not recs:
                break
            arec = recs[0]
            clean, routing = self._decode(arec.raw)
            gen = int(clean.meta.get("gen", 1))
            tok, cache = elem.host_prefill(params, clean.tensors[0])
            self.prefills += 1
            self.streams_started += 1
            self.tokens_generated += 1
            rec = {"routing": routing, "tokens": [tok], "prompt":
                   clean.tensors[0], "gen": gen,
                   "remaining": max(0, gen - 1), "cache": cache,
                   "adm": arec}
            self._track(rec)
            if rec["remaining"] <= 0:
                self._finish(rec)
                finished += 1
            else:
                self._waiting.append(rec)
        return finished

    def _next_waiting(self) -> Dict:
        """The waiting stream the next free slot goes to: plain FIFO when
        QoS is off (the pre-QoS semantics, bit for bit), else the best
        ``(priority, deadline, arrival)`` key — tenant priority decides
        slot admission, never slot eviction."""
        if not self.admission.enabled or len(self._waiting) <= 1:
            return self._waiting.pop(0)
        best = min(range(len(self._waiting)),
                   key=lambda i: self._waiting[i]["adm"].order_key()
                   if "adm" in self._waiting[i] else (-1, 0.0, -1))
        return self._waiting.pop(best)

    def _decode_tick(self) -> int:
        """ONE stateful dispatch over the whole slot table: waiting streams
        join under the admit mask, every active slot emits a token, spent
        slots leave — all inside the same jitted program."""
        run = self.run
        plan = run.pipe.plan
        elem = self._serve_elem()
        free = sorted(s for s in range(elem.slots) if s not in self._slots)
        admits = []
        while free and self._waiting:
            rec = self._next_waiting()
            slot = free.pop(0)
            admits.append((slot, rec["tokens"][-1], rec["remaining"],
                           rec["cache"]))
            rec["cache"] = None     # lives in plan state from here on
            self._slots[slot] = rec
        if not self._slots:
            return 0
        src = plan.query_sources[0].name
        sink = plan.query_sinks[0].name
        serve = plan.compiled_serve_tick(run.state)
        outputs, run.state = serve(run.params, run.state,
                                   {src: elem.build_admit(admits)})
        toks, emitted, finished = jax.device_get(outputs[sink].tensors)
        self.decode_ticks += 1
        run.frames += 1
        n_active = int(emitted.sum())
        self.batched_frames += n_active
        if n_active > 1:
            self.batches += 1
        done = 0
        for slot in sorted(self._slots):
            rec = self._slots[slot]
            if emitted[slot]:
                rec["tokens"].append(int(toks[slot]))
                self.tokens_generated += 1
            if finished[slot]:
                self._finish(rec)
                del self._slots[slot]
                done += 1
        return done

    def _finish(self, rec: Dict):
        """Deliver one completed stream: all its tokens as ONE answer
        through the real serversink apply (per-client codec encode +
        client-channel route — identical to the stateless routing path)."""
        routing = rec["routing"]
        sink = self.run.pipe.plan.query_sinks[0]
        answer = StreamBuffer(
            tensors=(np.asarray(rec["tokens"], np.int32),), meta=routing)
        sink.apply(self.run.params.get(sink.name, {}), [answer])
        self.tokens_delivered += len(rec["tokens"])
        self.streams_finished += 1
        arec = rec.pop("adm", None)
        if arec is not None:
            self.admission.mark_served(arec)
        self._untrack(rec)

    def on_reconfig(self):
        """The serve topology was hot-swapped under live streams: a swapped
        serve element's plan state re-initialized at commit (kept elements
        carry theirs, but the batcher cannot tell which epoch a slot's
        cache belongs to), so every in-flight stream REPLAYS — its partial
        tokens become declared drops and the stream re-prefills on the new
        epoch at the next flush.  Greedy decode makes the replay bitwise a
        fresh build's answer (pinned in tests/test_model_serving.py);
        stale still-active slots in carried plan state self-clear (their
        ``remaining`` lane drains to zero with no record listening)."""
        super().on_reconfig()
        recs = [self._slots[s] for s in sorted(self._slots)] + self._waiting
        self._slots.clear()
        self._waiting = []
        for rec in recs:
            self.tokens_dropped += len(rec["tokens"])
            self.replays += 1
            rec["tokens"] = []
            rec["cache"] = None
        self._replay.extend(recs)

    def _abort_streams(self):
        """Endpoint died: every live stream's partial tokens are DECLARED
        drops (conservation law) — the orphaned PendingQuery records
        re-dispatch with prefill replay on a survivor, so the client still
        loses zero tokens end-to-end."""
        self._shed_dead()
        if not self._by_client:
            return
        total = 0
        for recs in self._by_client.values():
            for rec in recs:
                self.tokens_dropped += len(rec["tokens"])
                arec = rec.pop("adm", None)
                if arec is not None:
                    self.admission.mark_shed(arec, "server-died",
                                             notify=False)
                    self._forget_delivery(arec)
                total += 1
        self._orphan(total)
        self._slots.clear()
        self._waiting.clear()
        self._replay.clear()
        self._by_client.clear()

    def stats(self) -> Dict[str, int]:
        base = super().stats()
        base.update({
            "prefills": self.prefills,
            "decode_ticks": self.decode_ticks,
            "tokens_generated": self.tokens_generated,
            "tokens_delivered": self.tokens_delivered,
            "tokens_dropped": self.tokens_dropped,
            "tokens_in_flight": self.inflight_tokens(),
            "streams_started": self.streams_started,
            "streams_finished": self.streams_finished,
            "replays": self.replays,
        })
        return base


class StageQueryBatcher(QueryBatcher):
    """Hop server for a DOWNSTREAM ``model_serve_stage`` pipeline (stage
    k >= 1 of an among-device chain, DESIGN.md §8).  Its endpoint receives
    hop requests from the chain's StagedStreamingBatcher, never
    client-facing prompts; ``meta["hop"]`` selects the verb:

    * ``"prefill"`` — stage-local prefill of one stream's boundary
      activations; the resulting b=1 cache PARKS here keyed by the
      coordinator's stream id (caches never cross the wire — only
      activations do), and the boundary output answers back.
    * ``"replay"``  — one b=1 decode step folded into a parked cache: the
      stage-local failover primitive (a replacement stage rebuilds exactly
      its own slice of a dead stage's state from the coordinator's
      retained activations).
    * ``"decode"``  — one slot-table hop through ``compiled_serve_tick``:
      ``meta["admit"]`` maps joining slots to parked stream ids (merged
      under the admit mask inside the jit), ``meta["live"]`` prunes parked
      caches of finished streams.

    Epoch fencing: every §6 reconfig of this pipeline bumps
    ``endpoint.spec["serve_epoch"]`` — the coordinator trusts a stage's
    slot caches only while (endpoint identity, epoch) are unchanged, so a
    hot-swapped stage is indistinguishable from a died-and-replaced one
    and both recover through the same stage-local replay rule."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._parked: Dict[int, Any] = {}      # stream id -> b=1 stage cache
        self.epoch = 0
        self.endpoint.spec.setdefault("serve_epoch", 0)
        self.prefills = 0
        self.replay_steps = 0
        self.decode_hops = 0
        self.slot_steps = 0

    def _serve_elem(self):
        plan = self.run.pipe.plan
        for op in plan.ops:
            if getattr(op.elem, "is_stage_serve", False):
                return op.elem
        raise RuntimeError("StageQueryBatcher on a non-stage plan")

    def flush(self) -> int:
        if not self.endpoint.alive:
            self._parked.clear()
            self._shed_dead()
            return 0
        # hop traffic shares the admission core for its ledger, but is
        # ALWAYS pass-through FIFO regardless of runtime QoS: each hop is
        # one step of a stream the coordinator already admitted under its
        # tenant's budget — re-scheduling mid-chain would deadlock the
        # synchronous hop round-trip (the runtime wires stage batchers
        # with qos=None for exactly this reason)
        adm = self.admission
        served = 0
        while self.endpoint.alive:
            self._ingest()
            recs = adm.take(1)
            if not recs:
                break
            self._serve_hop(recs[0].raw)
            adm.mark_served(recs[0])
            served += 1
        if served:
            self.flushes += 1
        return served

    def _serve_hop(self, raw: StreamBuffer):
        clean, routing = self._decode(raw)
        kind = clean.meta.get("hop", "decode")
        elem = self._serve_elem()
        params = self.run.params.get(elem.name, {})
        if kind == "prefill":
            sid = int(clean.meta["sid"])
            out, cache = elem.host_stage_prefill(params, clean.tensors[0])
            self._parked[sid] = cache
            self.prefills += 1
        elif kind == "replay":
            sid = int(clean.meta["sid"])
            # the hop's delivery id (if any) keys the stage element's
            # idempotence memo: even a duplicate that slipped past an
            # evicted dedup window cannot double-advance this cache
            out, cache = elem.host_stage_decode_idempotent(
                params, clean.tensors[0], self._parked[sid],
                hop_id=routing.get("dseq"))
            self._parked[sid] = cache
            self.replay_steps += 1
        else:
            out = self._serve_decode_hop(clean, elem)
        sink = self.run.pipe.plan.query_sinks[0]
        answer = StreamBuffer(tensors=(out,), meta=dict(routing))
        sink.apply(self.run.params.get(sink.name, {}), [answer])

    def _serve_decode_hop(self, clean: StreamBuffer, elem):
        x, active = clean.tensors
        admits = [(int(slot), self._parked.pop(int(sid)))
                  for slot, sid in clean.meta.get("admit", ())]
        live = clean.meta.get("live")
        if live is not None:
            keep = set(int(s) for s in live)
            self._parked = {s: c for s, c in self._parked.items()
                            if s in keep}
        run = self.run
        plan = run.pipe.plan
        src = plan.query_sources[0].name
        sink = plan.query_sinks[0].name
        serve = plan.compiled_serve_tick(run.state)
        outputs, run.state = serve(run.params, run.state,
                                   {src: elem.build_hop(x, active, admits)})
        self.decode_hops += 1
        run.frames += 1
        n_active = int(np.asarray(active).sum())
        self.slot_steps += n_active
        self.batched_frames += n_active
        if n_active > 1:
            self.batches += 1
        return outputs[sink].tensors[0]

    def on_reconfig(self):
        """Stage hot-swapped under the chain: parked caches and slot rows
        belong to the OLD epoch — drop the parked ones and bump the epoch
        fence so the coordinator replays this stage before trusting it."""
        super().on_reconfig()
        self._parked.clear()
        self.epoch += 1
        self.endpoint.spec["serve_epoch"] = self.epoch

    def stats(self) -> Dict[str, int]:
        base = super().stats()
        base.update({
            "stage_prefills": self.prefills,
            "stage_replay_steps": self.replay_steps,
            "decode_hops": self.decode_hops,
            "slot_steps": self.slot_steps,
            "parked_caches": len(self._parked),
        })
        return base


class StagedStreamingBatcher(StreamingQueryBatcher):
    """The §8 chain coordinator: the streaming request lifecycle of
    StreamingQueryBatcher, with the model split across N
    ``model_serve_stage`` pipelines discovered over the broker.

    It is wired on STAGE 0's endpoint (the client-facing ``query/<op>``
    topic) and owns the slot table; stage 0 serves inline through its own
    run's ``compiled_serve_tick``, stages 1..N-1 are reached as
    among-device hops: a request pushed onto ``query/<op>/s<k>``'s
    best-ranked endpoint, served by that stage's StageQueryBatcher, the
    answer popped off the coordinator's response channel — the exact
    mechanism ``tensor_query_client.apply`` uses, so broker ranking,
    leases, win-back, and the §6 reconfig lifecycle all apply per stage.

    Admission runs a PREFILL CHAIN: stage-0 host prefill parks its b=1
    cache coordinator-side, each downstream stage prefills the upstream
    boundary activations and parks its own slice, the last stage answers
    the first token.  Decode runs one hop per stage per tick over the
    whole slot table.  The coordinator RETAINS every stream's per-stage
    boundary-activation history (prefill acts + one step per completed
    hop) — the feedstock for the per-stage replay rule:

    **Cache trust:** stage k's slot caches are trusted only while
    (endpoint identity, serve_epoch) are unchanged since the last
    successful hop.  On mismatch — death, lease expiry, failover to a
    standby, win-back, or a §6 swap — the coordinator rebuilds ONLY stage
    k: per live stream, replay the retained activations through the
    stage's prefill/replay verbs (bitwise by construction: identical
    traced programs on identical inputs), then re-merge parked caches
    into slot rows under the next hop's admit mask.  Other stages are
    untouched; no generation restarts; zero tokens drop.

    A hop that fails MID-TICK stalls the tick: stages < k already
    advanced this step, so the chain must resume FROM k — the pending-hop
    record keeps the in-flight boundary activations and the next flush
    re-dispatches after re-securing the stage.  Conservation holds per
    stage: ``hops_dispatched[k] == hops_completed[k] + hops_failed[k]``
    every flush, and the §7 token law holds at the coordinator."""

    def __init__(self, *args, broker=None, **kwargs):
        super().__init__(*args, **kwargs)
        self.broker = broker
        from .query import TensorQueryClient
        self._hop_cid = next(TensorQueryClient._ids)
        self._hops: Dict[int, Any] = {}         # stage -> Binding
        self._trust: Dict[int, Optional[Tuple]] = {}
        self._readmit: Dict[int, Dict[int, int]] = {}  # stage->{slot: sid}
        self._pending_hop: Optional[Dict] = None
        self._stalled: List[Dict] = []          # admission chains to retry
        self._sids = itertools.count(1)
        self.hops_dispatched: Dict[int, int] = {}
        self.hops_completed: Dict[int, int] = {}
        self.hops_failed: Dict[int, int] = {}
        self.stage_replays: Dict[int, int] = {}
        self.stage_replay_steps: Dict[int, int] = {}
        #: delivery policy for the among-device hops (DESIGN.md §10),
        #: installed by the runtime alongside the batcher guards.  None
        #: keeps the pre-§10 single-shot hop, bit for bit.
        self.delivery: Optional[netfault.DeliveryPolicy] = None
        self._hop_seq = 0
        self.hop_retransmits = 0
        self.hop_dups = 0
        self.hop_corrupt = 0
        self.hop_push_drops = 0

    @property
    def n_stages(self) -> int:
        return self._serve_elem().n_stages

    def _has_decode_work(self) -> bool:
        return bool(self._slots or self._waiting or self._stalled
                    or self._pending_hop)

    # -- stage discovery & trust ----------------------------------------------
    def _stage_binding(self, k: int):
        b = self._hops.get(k)
        if b is None:
            op = self.endpoint.operation
            b = self._hops[k] = self.broker.subscribe(
                f"query/{op}/s{k}",
                prefer={"codec": "none", "stage": k})
        return b

    def _stage_endpoint(self, k: int):
        from .broker import BrokerError
        try:
            binding = self._stage_binding(k)
            ep = binding.endpoint
            if not ep.alive:
                binding._rebind()
                ep = binding.endpoint
        except BrokerError:
            return None
        return ep if ep.alive else None

    def _ensure_stage(self, k: int):
        """Resolve stage k's endpoint and make its caches trustworthy:
        any change of (endpoint identity, serve_epoch) since the last hop
        triggers the stage-local replay before the stage is used again."""
        ep = self._stage_endpoint(k)
        if ep is None:
            return None
        key = (ep.endpoint_id, ep.spec.get("serve_epoch", 0))
        if self._trust.get(k) != key:
            if not self._replay_stage(k, ep):
                return None
            self._trust[k] = key
        return ep

    def _replay_stage(self, k: int, ep) -> bool:
        """Rebuild ONLY stage k's slice of every live stream's state from
        the retained boundary activations (DESIGN.md §8 replay rule)."""
        recs = [self._slots[s] for s in sorted(self._slots)] + \
            [r for r in self._waiting if r.get("sid") is not None]
        self.stage_replays[k] = self.stage_replays.get(k, 0) + 1
        for rec in recs:
            acts = rec["acts"][k]
            if self._raw_hop(ep, (acts[0],),
                             {"hop": "prefill", "sid": rec["sid"]}) is None:
                return False
            for step in acts[1:]:
                if self._raw_hop(ep, (step,),
                                 {"hop": "replay",
                                  "sid": rec["sid"]}) is None:
                    return False
                self.stage_replay_steps[k] = \
                    self.stage_replay_steps.get(k, 0) + 1
        # slotted streams' rows on the new stage are garbage until their
        # freshly parked caches re-merge at the next decode hop
        rd = self._readmit.setdefault(k, {})
        for slot, rec in self._slots.items():
            rd[slot] = rec["sid"]
        return True

    # -- the hop itself --------------------------------------------------------
    def _raw_hop(self, ep, tensors, meta) -> Optional[StreamBuffer]:
        """One request → inline serve → answer round-trip against a
        RESOLVED stage endpoint (the tensor_query_client mechanism, with
        the coordinator as the client).

        With a delivery policy the hop becomes at-least-once: the request
        carries a delivery id + CRC, and up to ``hop_retries`` synchronous
        retransmits reuse the SAME id — the stage guard dedups replays and
        re-fires the committed answer bitwise, so a duplicated or replayed
        hop can never double-advance a slot (§10).  Hops can't wait a
        tick (the chain holds the slot), hence the inline loop rather
        than the scheduler's backoff clock."""
        buf = StreamBuffer(tensors=tuple(tensors), meta=dict(meta))
        payload, nbytes = comp.encode(buf, "none")
        hmeta = {**payload.meta, "client_id": self._hop_cid,
                 "codec": "none"}
        delivery = self.delivery
        dseq = None
        crc = None
        if delivery is not None:
            self._hop_seq += 1
            dseq = (self._hop_cid, self._hop_seq)
            hmeta["dseq"] = dseq
            hmeta["crc"] = crc = netfault.checksum(payload)
        payload = payload.with_(meta=hmeta)
        if crc is not None:
            netfault.memoize_crc(payload, crc)
        attempts = max(1, delivery.hop_retries) if delivery is not None \
            else 1
        for attempt in range(attempts):
            if attempt:
                self.hop_retransmits += 1
            if not ep.requests.push(payload, nbytes):
                self.hop_push_drops += 1
            runner = ep.spec.get("inline_runner")
            if runner is None or not ep.alive:
                return None
            runner()
            ch = ep.client_channel(self._hop_cid)
            while True:
                raw = ch.pop()
                if raw is None:
                    break
                rmeta = raw.meta or {}
                if delivery is not None:
                    crc = rmeta.get("crc")
                    if crc is not None and \
                            netfault.checksum(raw) != int(crc):
                        self.hop_corrupt += 1
                        netfault.note(ch, "rejected_corrupt")
                        continue
                    rds = rmeta.get("dseq")
                    if rds is not None and rds != dseq:
                        # late duplicate of an EARLIER hop's answer —
                        # that hop already consumed one copy; this one
                        # dedups here, never advances anything
                        self.hop_dups += 1
                        netfault.note(ch, "deduped")
                        continue
                    netfault.note(ch, "accepted")
                return comp.decode(raw, "none")
        return None

    def _hop(self, k: int, tensors, meta) -> Optional[StreamBuffer]:
        ep = self._ensure_stage(k)
        self.hops_dispatched[k] = self.hops_dispatched.get(k, 0) + 1
        ans = None if ep is None else self._raw_hop(ep, tensors, meta)
        if ans is None:
            self.hops_failed[k] = self.hops_failed.get(k, 0) + 1
            self._trust[k] = None       # whatever happened, re-secure first
        else:
            self.hops_completed[k] = self.hops_completed.get(k, 0) + 1
        return ans

    # -- admission (prefill chain) ---------------------------------------------
    def _admit(self) -> int:
        finished = 0
        elem = self._serve_elem()
        params = self.run.params.get(elem.name, {})
        if self._replay:
            # stage-0 hot-swap replay (inherited §6 semantics): the whole
            # chain re-prefills these streams on the new epoch
            replays, self._replay = self._replay, []
            for rec in replays:
                for key in ("cache0", "sid", "acts", "chain_next",
                            "chain_x"):
                    rec.pop(key, None)
                finished += self._start_stream(rec, elem, params)
        if self._stalled:
            stalled, self._stalled = self._stalled, []
            for rec in stalled:
                finished += self._resume_chain(rec)
        adm = self.admission
        while self.endpoint.alive:
            self._ingest()
            adm.expire()
            recs = adm.take(1)
            if not recs:
                break
            arec = recs[0]
            clean, routing = self._decode(arec.raw)
            gen = int(clean.meta.get("gen", 1))
            rec = {"routing": routing, "tokens": [],
                   "prompt": clean.tensors[0], "gen": gen, "remaining": 0,
                   "adm": arec}
            self.streams_started += 1
            self._track(rec)
            finished += self._start_stream(rec, elem, params)
        return finished

    def _start_stream(self, rec: Dict, elem, params) -> int:
        """Stage-0 prefill (parked coordinator-side) + downstream prefill
        chain.  The stage-0 boundary activations are retained as acts[1]'s
        seed; stage 0's own replay feedstock is ``rec["prompt"]``."""
        out, cache0 = elem.host_stage_prefill(params, rec["prompt"])
        self.prefills += 1
        rec["tokens"] = []
        rec["cache0"] = cache0
        rec["sid"] = next(self._sids)
        rec["acts"] = {k: [] for k in range(1, self.n_stages)}
        rec["chain_next"] = 1
        rec["chain_x"] = np.asarray(out)
        return self._resume_chain(rec)

    def _resume_chain(self, rec: Dict) -> int:
        k = rec["chain_next"]
        x = rec["chain_x"]
        while k < self.n_stages:
            rec["acts"][k] = [x]    # assign, not append: retries overwrite
            ans = self._hop(k, (x,), {"hop": "prefill", "sid": rec["sid"]})
            if ans is None:
                rec["chain_next"], rec["chain_x"] = k, x
                self._stalled.append(rec)
                return 0
            x = np.asarray(ans.tensors[0])
            k += 1
        del rec["chain_next"], rec["chain_x"]
        rec["tokens"] = [int(np.asarray(x).reshape(()))]
        self.tokens_generated += 1
        rec["remaining"] = max(0, rec["gen"] - 1)
        if rec["remaining"] <= 0:
            self._finish(rec)
            return 1
        self._waiting.append(rec)
        return 0

    # -- the per-tick decode chain ---------------------------------------------
    def _decode_tick(self) -> int:
        if self._pending_hop is not None:
            # a stage died mid-tick: stages < k already advanced this
            # step — resume the SAME step from stage k, never re-run it
            return self._run_chain()
        run = self.run
        elem = self._serve_elem()
        free = sorted(s for s in range(elem.slots) if s not in self._slots)
        admits0 = []
        while free and self._waiting:
            rec = self._waiting.pop(0)
            slot = free.pop(0)
            admits0.append((slot, rec["cache0"]))
            rec["cache0"] = None    # stage 0's slice lives in plan state now
            self._slots[slot] = rec
            for k in range(1, self.n_stages):
                self._readmit.setdefault(k, {})[slot] = rec["sid"]
        if not self._slots:
            return 0
        s = elem.slots
        active = np.zeros((s,), np.bool_)
        tok = np.zeros((s,), np.int32)
        for slot, rec in self._slots.items():
            active[slot] = True
            tok[slot] = rec["tokens"][-1]
        plan = run.pipe.plan
        src = plan.query_sources[0].name
        sink = plan.query_sinks[0].name
        serve = plan.compiled_serve_tick(run.state)
        outputs, run.state = serve(run.params, run.state,
                                   {src: elem.build_hop(tok, active,
                                                        admits0)})
        y = np.asarray(jax.device_get(outputs[sink].tensors[0]))
        self.decode_ticks += 1
        run.frames += 1
        n_active = int(active.sum())
        self.batched_frames += n_active
        if n_active > 1:
            self.batches += 1
        self._pending_hop = {"k": 1, "x": y, "active": active}
        return self._run_chain()

    def _run_chain(self) -> int:
        ph = self._pending_hop
        x, active = ph["x"], ph["active"]
        k = ph["k"]
        live = tuple(sorted(rec["sid"] for rec in self._iter_recs()
                            if rec.get("sid") is not None))
        while k < self.n_stages:
            # secure the stage BEFORE assembling the admit list: a trust
            # break replays into _readmit[k], and those freshly parked
            # caches must merge on THIS hop — assembling first would ship
            # an empty admit and decode the standby's zero rows
            self._ensure_stage(k)
            rd = self._readmit.get(k, {})
            admit = tuple((int(slot), int(sid))
                          for slot, sid in sorted(rd.items())
                          if active[slot])
            ans = self._hop(k, (x, active),
                            {"hop": "decode", "admit": admit, "live": live})
            if ans is None:
                ph["k"], ph["x"] = k, x
                return 0
            # x is now part of stage k's committed history — retain it as
            # replay feedstock (AFTER the hop: an in-flight step must not
            # be replayed into a cache it never reached)
            for slot, rec in self._slots.items():
                rec["acts"][k].append(x[slot:slot + 1])
            self._readmit[k] = {}
            x = np.asarray(ans.tensors[0])
            k += 1
        self._pending_hop = None
        done = 0
        for slot in sorted(self._slots):
            rec = self._slots[slot]
            rec["tokens"].append(int(x[slot]))
            self.tokens_generated += 1
            rec["remaining"] -= 1
            if rec["remaining"] <= 0:
                self._finish(rec)
                del self._slots[slot]
                for rd in self._readmit.values():
                    rd.pop(slot, None)
                done += 1
        return done

    def _iter_recs(self):
        yield from self._slots.values()
        yield from self._waiting
        yield from self._stalled

    # -- lifecycle edges --------------------------------------------------------
    def on_reconfig(self):
        """Stage 0's pipeline was hot-swapped: inherited whole-stream
        replay (stage 0's slice re-initialized at commit) plus chain
        bookkeeping reset — stalled admissions rejoin the replay queue and
        downstream stages simply see fresh stream ids (their stale parked
        caches prune via the next hop's live list)."""
        stalled, self._stalled = self._stalled, []
        super().on_reconfig()
        for rec in stalled:
            self.replays += 1
            rec["tokens"] = []
            self._replay.append(rec)
        self._pending_hop = None
        self._readmit = {}

    def _abort_streams(self):
        super()._abort_streams()
        self._stalled.clear()
        self._pending_hop = None
        self._readmit = {}
        self._trust = {}

    def stats(self) -> Dict[str, int]:
        base = super().stats()
        base.update({
            "hops_dispatched": sum(self.hops_dispatched.values()),
            "hops_completed": sum(self.hops_completed.values()),
            "hops_failed": sum(self.hops_failed.values()),
            "stage_replays": sum(self.stage_replays.values()),
            "stage_replay_steps": sum(self.stage_replay_steps.values()),
            "hop_retransmits": self.hop_retransmits,
            "hop_dups": self.hop_dups,
            "hop_corrupt": self.hop_corrupt,
            "hop_push_drops": self.hop_push_drops,
        })
        return base

    def stage_ledger(self, k: int) -> Dict[str, int]:
        """Per-stage hop conservation record (pinned per stage by the
        staged soak): every dispatched hop is completed or failed."""
        return {"dispatched": self.hops_dispatched.get(k, 0),
                "completed": self.hops_completed.get(k, 0),
                "failed": self.hops_failed.get(k, 0),
                "replays": self.stage_replays.get(k, 0),
                "replay_steps": self.stage_replay_steps.get(k, 0)}
