"""Stream codecs for inter-device transmission (paper: "Sparse tensors and
gst-gz support compressed transmissions"; clients "explicitly requested
sparse tensor streams to compress streams for language and speech models").

Codecs operate on whole StreamBuffers and report *wire bytes*, which the
benchmark harness uses to reproduce the bandwidth analysis.  The compute
hot-spots (quant8, sparse COO) are Pallas TPU kernels in repro.kernels.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .buffers import SparsePayload, StreamBuffer

__all__ = ["encode", "decode", "CODECS"]

CODECS = ("none", "quant8", "sparse")


def _quant8_enc(x: jnp.ndarray):
    from ..kernels import ops as kops
    from ..kernels.ops import _as2d
    q, scale = kops.quantize8(x)
    m, n = _as2d(x).shape
    return {"q": q, "scale": scale, "dtype": str(x.dtype),
            "shape": tuple(x.shape), "view2d": (m, n)}


def _quant8_dec(enc) -> jnp.ndarray:
    from ..kernels import ops as kops
    x = kops.dequantize8(enc["q"], enc["scale"])
    m, n = enc["view2d"]
    return x[:m, :n].astype(jnp.dtype(enc["dtype"])).reshape(enc["shape"])


def _sparse_enc(x: jnp.ndarray, density: float = 0.25) -> SparsePayload:
    from ..kernels import ops as kops
    cap = max(1, int(x.size * density))
    flat = x.reshape(-1)
    values, indices, nnz = kops.sparse_enc(flat, cap, 0.0)
    return SparsePayload(values=values, indices=indices, nnz=nnz,
                         dense_shape=tuple(x.shape))


def _sparse_dec(sp: SparsePayload) -> jnp.ndarray:
    from ..kernels import ops as kops
    n = int(np.prod(sp.dense_shape))
    return kops.sparse_dec(sp.values, sp.indices, sp.nnz, n).reshape(sp.dense_shape)


def encode(buf: StreamBuffer, codec: str) -> Tuple[StreamBuffer, int]:
    """Returns (encoded buffer, wire bytes).  ``codec`` may carry a parameter:
    "sparse:0.15" bounds the COO capacity at 15% density."""
    codec, _, arg = codec.partition(":")
    if codec == "none":
        return buf, buf.nbytes()
    if codec == "quant8":
        enc = tuple(_quant8_enc(t) for t in buf.tensors)
        # wire framing carries the logical elements (1B each) + scales; the
        # padded tile layout is a kernel-side detail, not wire format
        nbytes = sum(int(np.prod(e["shape"])) * 1 + e["scale"].size * 4
                     for e in enc)
        out = buf.with_(tensors=enc, meta={**buf.meta, "codec": "quant8"})
        return out, nbytes
    if codec == "sparse":
        density = float(arg) if arg else 0.25
        enc = tuple(_sparse_enc(t, density) for t in buf.tensors)
        nbytes = sum(e.wire_nbytes for e in enc)
        out = buf.with_(tensors=enc, meta={**buf.meta, "codec": "sparse"})
        return out, nbytes
    raise ValueError(f"unknown codec {codec!r}")


def decode(buf: StreamBuffer, codec: str) -> StreamBuffer:
    codec, _, _ = codec.partition(":")
    if codec == "none":
        return buf
    if codec == "quant8":
        return buf.with_(tensors=tuple(_quant8_dec(e) for e in buf.tensors))
    if codec == "sparse":
        return buf.with_(tensors=tuple(_sparse_dec(e) for e in buf.tensors))
    raise ValueError(f"unknown codec {codec!r}")
