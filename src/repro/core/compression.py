"""Stream codecs for inter-device transmission (paper: "Sparse tensors and
gst-gz support compressed transmissions"; clients "explicitly requested
sparse tensor streams to compress streams for language and speech models").

Codecs operate on whole StreamBuffers and report *wire bytes*, which the
benchmark harness uses to reproduce the bandwidth analysis.  The compute
hot-spots (quant8, sparse COO) are Pallas TPU kernels in repro.kernels.

Meta contract: ``encode`` stamps ``meta["codec"]`` on the *wire* buffer (the
payload really is encoded), and ``decode`` strips it again — a decoded frame
must never claim to be encoded, or a later ``decode(buf,
buf.meta["codec"])`` would corrupt the payload (double-decode) and wire
accounting would count decoded frames as compressed.  Anything that needs
the client's codec *preference* after decode (answer routing) re-attaches it
explicitly as routing meta.

Sparse encoding is capacity-bounded (block-COO): when the true nonzero count
exceeds the requested density the tail is dropped.  That loss is detected
and accounted — ``meta["sparse_dropped"]`` on the wire buffer carries the
dropped-value count and the module-level :func:`codec_stats` aggregate it —
so a lossy encode is never silent.  Truncation accounting is DEFERRED:
``_sparse_enc`` keeps the dropped count as a device scalar (no host sync per
tensor); eager :func:`encode` folds every tensor's scalar into ONE sync per
call, and the batched/fused paths carry the scalars out of the jit and sync
once per flush (see :func:`account_sparse_dropped`).

Three call layers share the same numerics bitwise:

* per-frame :func:`encode`/:func:`decode` — eager, host-level (pub/sub
  publish, legacy query round-trips);
* :func:`encode_stacked`/:func:`decode_stacked` — TRACEABLE, operate on a
  leading frame axis with the stacked kernel entry points; this is what the
  fused serving dispatch calls inside its jit;
* :func:`encode_batch`/:func:`decode_batch` — host-level batch helpers over
  same-structure frames: one stacked dispatch, ONE device fetch, numpy
  per-frame views out (eager per-frame splits would pay a dispatch per leaf
  per frame — the overhead batching exists to kill).

Wire-bytes accounting is computed from static payload shapes everywhere
(``wire_nbytes``) — no sync, valid even on traced payloads.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .buffers import Quant8Payload, SparsePayload, StreamBuffer

__all__ = ["encode", "decode", "encode_stacked", "decode_stacked",
           "encode_batch", "decode_batch", "wire_nbytes", "CODECS",
           "codec_stats", "reset_codec_stats", "account_sparse_dropped"]

CODECS = ("none", "quant8", "sparse")

#: meta keys describing the WIRE form of a buffer — stamped by encode,
#: stripped by decode (a decoded frame carries neither)
_WIRE_META = ("codec", "sparse_dropped")

# process-wide lossy-encode accounting (benchmarks / Runtime.stats surface
# this; tests reset it)
_CODEC_STATS = {"sparse_truncated_tensors": 0, "sparse_dropped_values": 0}


def codec_stats() -> Dict[str, int]:
    return dict(_CODEC_STATS)


def reset_codec_stats():
    for k in _CODEC_STATS:
        _CODEC_STATS[k] = 0


def account_sparse_dropped(per_tensor) -> int:
    """Fold synced per-tensor dropped counts (ints / numpy) into the
    process-wide codec stats; returns the total dropped values.  The single
    host sync point of the deferred truncation accounting — callers fetch
    their device scalars in one batch and hand the host values here."""
    per_tensor = [int(d) for d in per_tensor]
    total = sum(per_tensor)
    if total:
        _CODEC_STATS["sparse_truncated_tensors"] += \
            sum(1 for d in per_tensor if d)
        _CODEC_STATS["sparse_dropped_values"] += total
    return total


# ---------------------------------------------------------------------------
# per-tensor codec primitives (traceable; no host syncs)
# ---------------------------------------------------------------------------

def _quant8_enc(x: jnp.ndarray) -> Quant8Payload:
    from ..kernels import ops as kops
    from ..kernels.ops import _as2d
    q, scale = kops.quantize8(x)
    m, n = _as2d(x).shape
    return Quant8Payload(q=q, scale=scale, dtype=str(x.dtype),
                         shape=tuple(x.shape), view2d=(m, n))


def _quant8_dec(enc: Quant8Payload) -> jnp.ndarray:
    from ..kernels import ops as kops
    x = kops.dequantize8(enc.q, enc.scale)
    m, n = enc.view2d
    return x[:m, :n].astype(jnp.dtype(enc.dtype)).reshape(enc.shape)


def _sparse_cap(size: int, density: float) -> int:
    """Block-COO capacity for ``size`` elements at ``density``.

    ``density >= 1.0`` must be LOSSLESS: the naive ``int(size * density)``
    spread over ceil(size/B) blocks under-allocates per-block slots when
    ``size`` is not a multiple of the block (e.g. 600 elements -> 2 blocks
    of 300 slots, but 512 nonzeros can land in block 0), so full density
    pins every block at full capacity instead."""
    from ..kernels.ref import SPARSE_B
    if density >= 1.0:
        nb = max(1, -(-size // SPARSE_B))
        return nb * SPARSE_B
    return max(1, int(size * density))


def _sparse_enc(x: jnp.ndarray, density: float = 0.25
                ) -> Tuple[SparsePayload, jnp.ndarray]:
    """Returns (payload, dropped): ``dropped`` counts true nonzeros the
    capacity-bounded COO could not carry (0 = lossless encode).  It stays a
    DEVICE scalar — callers batch the sync (module docstring)."""
    from ..kernels import ops as kops
    cap = _sparse_cap(x.size, density)
    flat = x.reshape(-1)
    values, indices, nnz = kops.sparse_enc(flat, cap, 0.0)
    true_nnz = jnp.sum(jnp.abs(flat) > 0.0).astype(jnp.int32)
    dropped = jnp.maximum(0, true_nnz - nnz)
    return SparsePayload(values=values, indices=indices, nnz=nnz,
                         dense_shape=tuple(x.shape)), dropped


def _sparse_dec(sp: SparsePayload) -> jnp.ndarray:
    from ..kernels import ops as kops
    n = int(np.prod(sp.dense_shape))
    return kops.sparse_dec(sp.values, sp.indices, sp.nnz, n).reshape(sp.dense_shape)


# ---------------------------------------------------------------------------
# stacked codec primitives (leading frame axis; traceable)
# ---------------------------------------------------------------------------

def _view2d(shape: Tuple[int, ...]) -> Tuple[int, int]:
    """Logical 2d view of one frame (same rules as kernels/ops._as2d)."""
    if len(shape) == 0:
        return (1, 1)
    if len(shape) == 1:
        return (1, shape[0])
    return (int(np.prod(shape[:-1])), shape[-1])


def _quant8_enc_stacked(x: jnp.ndarray) -> Quant8Payload:
    """[B, *shape] -> stacked payload (q [B,Mp,Np], scale [B,gm,gn]); frame
    i is bitwise ``_quant8_enc(x[i])`` (tile merge, kernels/ops.py)."""
    from ..kernels import ops as kops
    q, scale = kops.quantize8_stacked(x)
    fshape = tuple(x.shape[1:])
    return Quant8Payload(q=q, scale=scale, dtype=str(x.dtype),
                         shape=fshape, view2d=_view2d(fshape))


def _quant8_dec_stacked(enc: Quant8Payload) -> jnp.ndarray:
    from ..kernels import ops as kops
    b = enc.q.shape[0]
    x = kops.dequantize8_stacked(enc.q, enc.scale)
    m, n = enc.view2d
    return x[:, :m, :n].astype(jnp.dtype(enc.dtype)).reshape((b,) + enc.shape)


def _sparse_enc_stacked(x: jnp.ndarray, density: float
                        ) -> Tuple[SparsePayload, jnp.ndarray]:
    """[B, *shape] -> (stacked payload, dropped int32 [B])."""
    from ..kernels import ops as kops
    fshape = tuple(x.shape[1:])
    size = int(np.prod(fshape)) if fshape else 1
    cap = _sparse_cap(size, density)
    flat = x.reshape(x.shape[0], size)
    values, indices, nnz = kops.sparse_enc_stacked(flat, cap, 0.0)
    true_nnz = jnp.sum(jnp.abs(flat) > 0.0, axis=1).astype(jnp.int32)
    dropped = jnp.maximum(0, true_nnz - nnz)
    return SparsePayload(values=values, indices=indices, nnz=nnz,
                         dense_shape=fshape), dropped


def _sparse_dec_stacked(sp: SparsePayload) -> jnp.ndarray:
    from ..kernels import ops as kops
    b = sp.values.shape[0]
    n = int(np.prod(sp.dense_shape))
    dense = kops.sparse_dec_stacked(sp.values, sp.indices, sp.nnz, n)
    return dense.reshape((b,) + sp.dense_shape)


# ---------------------------------------------------------------------------
# wire-bytes accounting (static shapes; no syncs)
# ---------------------------------------------------------------------------

def _payload_nbytes(t) -> int:
    # one source of truth for the wire framing: the payloads' own
    # wire_nbytes properties (buffers.py) / dense element bytes
    if isinstance(t, (Quant8Payload, SparsePayload)):
        return t.wire_nbytes
    return int(np.prod(t.shape)) * t.dtype.itemsize


def wire_nbytes(buf: StreamBuffer) -> int:
    """Wire bytes of an encoded buffer, from static payload shapes only —
    no device sync, valid even on traced payloads."""
    return sum(_payload_nbytes(t) for t in buf.tensors)


def _strip_wire_meta(meta: Dict) -> Dict:
    return {k: v for k, v in meta.items() if k not in _WIRE_META}


# ---------------------------------------------------------------------------
# per-frame eager API
# ---------------------------------------------------------------------------

def encode(buf: StreamBuffer, codec: str) -> Tuple[StreamBuffer, int]:
    """Returns (encoded buffer, wire bytes).  ``codec`` may carry a parameter:
    "sparse:0.15" bounds the COO capacity at 15% density."""
    codec, _, arg = codec.partition(":")
    if codec == "none":
        return buf, buf.nbytes()
    if codec == "quant8":
        enc = tuple(_quant8_enc(t) for t in buf.tensors)
        out = buf.with_(tensors=enc, meta={**buf.meta, "codec": "quant8"})
        return out, wire_nbytes(out)
    if codec == "sparse":
        density = float(arg) if arg else 0.25
        pairs = tuple(_sparse_enc(t, density) for t in buf.tensors)
        enc = tuple(p for p, _ in pairs)
        meta = {**buf.meta, "codec": "sparse"}
        # deferred truncation accounting: ONE host sync for the whole call
        # (the scalars were kept on device per tensor), folded into the
        # process stats and the wire buffer's loss signal together
        dropped = account_sparse_dropped(
            np.asarray(jnp.stack([d for _, d in pairs])))
        if dropped:
            meta["sparse_dropped"] = dropped
        out = buf.with_(tensors=enc, meta=meta)
        return out, wire_nbytes(out)
    raise ValueError(f"unknown codec {codec!r}")


def decode(buf: StreamBuffer, codec: str) -> StreamBuffer:
    codec, _, _ = codec.partition(":")
    if codec == "none":
        return buf
    if codec == "quant8":
        tensors = tuple(_quant8_dec(e) for e in buf.tensors)
    elif codec == "sparse":
        tensors = tuple(_sparse_dec(e) for e in buf.tensors)
    else:
        raise ValueError(f"unknown codec {codec!r}")
    # the payload is dense again: drop the wire-form meta — a stale
    # meta["codec"] on a decoded frame is a double-decode hazard and
    # mis-counts decoded frames as compressed in wire accounting
    return buf.with_(tensors=tensors, meta=_strip_wire_meta(buf.meta))


# ---------------------------------------------------------------------------
# stacked API (traceable — the fused serving dispatch calls these in-jit)
# ---------------------------------------------------------------------------

def encode_stacked(buf: StreamBuffer, codec: str
                   ) -> Tuple[StreamBuffer, Optional[jnp.ndarray]]:
    """Encode a STACKED buffer (leading frame axis) with one kernel
    dispatch per tensor.  Returns (stacked wire buffer, dropped int32
    [tensors, frames] or None) — frame ``i`` of every payload is bitwise
    ``encode(frame_i)``'s.  Traceable: the dropped counts stay on device
    and ``meta["sparse_dropped"]`` is NOT stamped here (the caller syncs
    once per flush and stamps host-side — see account_sparse_dropped)."""
    codec, _, arg = codec.partition(":")
    if codec == "none":
        return buf, None
    if codec == "quant8":
        enc = tuple(_quant8_enc_stacked(t) for t in buf.tensors)
        return buf.with_(tensors=enc,
                         meta={**buf.meta, "codec": "quant8"}), None
    if codec == "sparse":
        density = float(arg) if arg else 0.25
        pairs = tuple(_sparse_enc_stacked(t, density) for t in buf.tensors)
        enc = tuple(p for p, _ in pairs)
        dropped = jnp.stack([d for _, d in pairs])   # [tensors, frames]
        return buf.with_(tensors=enc,
                         meta={**buf.meta, "codec": "sparse"}), dropped
    raise ValueError(f"unknown codec {codec!r}")


def decode_stacked(buf: StreamBuffer, codec: str) -> StreamBuffer:
    """Decode a STACKED wire buffer (leading frame axis) with one kernel
    dispatch per tensor; frame ``i`` is bitwise ``decode(frame_i)``."""
    codec, _, _ = codec.partition(":")
    if codec == "none":
        return buf  # mirror per-frame decode: "none" is a strict no-op
    if codec == "quant8":
        tensors = tuple(_quant8_dec_stacked(e) for e in buf.tensors)
    elif codec == "sparse":
        tensors = tuple(_sparse_dec_stacked(e) for e in buf.tensors)
    else:
        raise ValueError(f"unknown codec {codec!r}")
    return buf.with_(tensors=tensors, meta=_strip_wire_meta(buf.meta))


# ---------------------------------------------------------------------------
# host-level batch helpers (one dispatch + one device fetch per group)
# ---------------------------------------------------------------------------

def _stack_tensors(bufs: Sequence[StreamBuffer]):
    """Stack per-position tensors/payloads across same-structure frames."""
    cols = zip(*[b.tensors for b in bufs])
    return tuple(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *col)
                 for col in cols)


def _host_frames(stacked_tensors, n: int):
    """ONE device fetch of the stacked pytree, then free numpy views per
    frame (an eager slice per leaf per frame would pay ~a dispatch each —
    the cost batching exists to remove).  Numpy leaves are bitwise the same
    frames; downstream jit calls device_put them on entry."""
    host = jax.device_get(stacked_tensors)
    return [jax.tree_util.tree_map(lambda l, _i=i: l[_i], host)
            for i in range(n)]


def encode_batch(bufs: Sequence[StreamBuffer], codec: str
                 ) -> List[Tuple[StreamBuffer, int]]:
    """Batched :func:`encode` over same-structure frames: one stacked
    kernel dispatch per tensor position, one device fetch, one truncation
    sync for the whole batch.  Element ``i`` is bitwise ``encode(bufs[i])``
    (payloads, meta — including ``sparse_dropped`` — and wire bytes)."""
    bufs = list(bufs)
    if not bufs:
        return []
    base, _, _ = codec.partition(":")
    if base == "none":
        return [(b, b.nbytes()) for b in bufs]
    n = len(bufs)
    stacked = StreamBuffer(tensors=_stack_tensors(bufs),
                           pts=jnp.int32(0), meta={})
    wire, dropped = encode_stacked(stacked, codec)
    per_tensor = ([] if dropped is None else
                  np.asarray(dropped))            # [tensors, frames], 1 sync
    frames = _host_frames(wire.tensors, n)
    out = []
    for i, (buf, tensors) in enumerate(zip(bufs, frames)):
        meta = {**buf.meta, "codec": base}
        if len(per_tensor):
            frame_dropped = account_sparse_dropped(per_tensor[:, i])
            if frame_dropped:
                meta["sparse_dropped"] = frame_dropped
        enc = buf.with_(tensors=tensors, meta=meta)
        out.append((enc, wire_nbytes(enc)))
    return out


def decode_batch(bufs: Sequence[StreamBuffer], codec: str
                 ) -> List[StreamBuffer]:
    """Batched :func:`decode` over same-structure wire frames: one stacked
    kernel dispatch per tensor position, one device fetch.  Element ``i``
    is bitwise ``decode(bufs[i])``."""
    bufs = list(bufs)
    if not bufs:
        return []
    base, _, _ = codec.partition(":")
    if base == "none":
        return bufs  # mirror per-frame decode: "none" is a strict no-op
    n = len(bufs)
    stacked = StreamBuffer(tensors=_stack_tensors(bufs),
                           pts=jnp.int32(0), meta={})
    dec = decode_stacked(stacked, codec)
    frames = _host_frames(dec.tensors, n)
    return [b.with_(tensors=t, meta=_strip_wire_meta(b.meta))
            for b, t in zip(bufs, frames)]
