"""Stream codecs for inter-device transmission (paper: "Sparse tensors and
gst-gz support compressed transmissions"; clients "explicitly requested
sparse tensor streams to compress streams for language and speech models").

Codecs operate on whole StreamBuffers and report *wire bytes*, which the
benchmark harness uses to reproduce the bandwidth analysis.  The compute
hot-spots (quant8, sparse COO) are Pallas TPU kernels in repro.kernels.

Meta contract: ``encode`` stamps ``meta["codec"]`` on the *wire* buffer (the
payload really is encoded), and ``decode`` strips it again — a decoded frame
must never claim to be encoded, or a later ``decode(buf,
buf.meta["codec"])`` would corrupt the payload (double-decode) and wire
accounting would count decoded frames as compressed.  Anything that needs
the client's codec *preference* after decode (answer routing) re-attaches it
explicitly as routing meta.

Sparse encoding is capacity-bounded (block-COO): when the true nonzero count
exceeds the requested density the tail is dropped.  That loss is detected
and accounted — ``meta["sparse_dropped"]`` on the wire buffer carries the
dropped-value count and the module-level :func:`codec_stats` aggregate it —
so a lossy encode is never silent.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from .buffers import SparsePayload, StreamBuffer

__all__ = ["encode", "decode", "CODECS", "codec_stats", "reset_codec_stats"]

CODECS = ("none", "quant8", "sparse")

#: meta keys describing the WIRE form of a buffer — stamped by encode,
#: stripped by decode (a decoded frame carries neither)
_WIRE_META = ("codec", "sparse_dropped")

# process-wide lossy-encode accounting (benchmarks / Runtime.stats surface
# this; tests reset it)
_CODEC_STATS = {"sparse_truncated_tensors": 0, "sparse_dropped_values": 0}


def codec_stats() -> Dict[str, int]:
    return dict(_CODEC_STATS)


def reset_codec_stats():
    for k in _CODEC_STATS:
        _CODEC_STATS[k] = 0


def _quant8_enc(x: jnp.ndarray):
    from ..kernels import ops as kops
    from ..kernels.ops import _as2d
    q, scale = kops.quantize8(x)
    m, n = _as2d(x).shape
    return {"q": q, "scale": scale, "dtype": str(x.dtype),
            "shape": tuple(x.shape), "view2d": (m, n)}


def _quant8_dec(enc) -> jnp.ndarray:
    from ..kernels import ops as kops
    x = kops.dequantize8(enc["q"], enc["scale"])
    m, n = enc["view2d"]
    return x[:m, :n].astype(jnp.dtype(enc["dtype"])).reshape(enc["shape"])


def _sparse_enc(x: jnp.ndarray, density: float = 0.25
                ) -> Tuple[SparsePayload, int]:
    """Returns (payload, dropped): ``dropped`` counts true nonzeros the
    capacity-bounded COO could not carry (0 = lossless encode)."""
    from ..kernels import ops as kops
    cap = max(1, int(x.size * density))
    flat = x.reshape(-1)
    values, indices, nnz = kops.sparse_enc(flat, cap, 0.0)
    # truncation detection costs ONE host sync: true-nnz minus kept, fused
    # into a single scalar (two separate int() reads would sync twice on
    # every encode to account a loss that is almost always zero)
    dropped = max(0, int(jnp.sum(jnp.abs(flat) > 0.0).astype(jnp.int32)
                         - nnz))
    return SparsePayload(values=values, indices=indices, nnz=nnz,
                         dense_shape=tuple(x.shape)), dropped


def _sparse_dec(sp: SparsePayload) -> jnp.ndarray:
    from ..kernels import ops as kops
    n = int(np.prod(sp.dense_shape))
    return kops.sparse_dec(sp.values, sp.indices, sp.nnz, n).reshape(sp.dense_shape)


def encode(buf: StreamBuffer, codec: str) -> Tuple[StreamBuffer, int]:
    """Returns (encoded buffer, wire bytes).  ``codec`` may carry a parameter:
    "sparse:0.15" bounds the COO capacity at 15% density."""
    codec, _, arg = codec.partition(":")
    if codec == "none":
        return buf, buf.nbytes()
    if codec == "quant8":
        enc = tuple(_quant8_enc(t) for t in buf.tensors)
        # wire framing carries the logical elements (1B each) + scales; the
        # padded tile layout is a kernel-side detail, not wire format
        nbytes = sum(int(np.prod(e["shape"])) * 1 + e["scale"].size * 4
                     for e in enc)
        out = buf.with_(tensors=enc, meta={**buf.meta, "codec": "quant8"})
        return out, nbytes
    if codec == "sparse":
        density = float(arg) if arg else 0.25
        pairs = tuple(_sparse_enc(t, density) for t in buf.tensors)
        enc = tuple(p for p, _ in pairs)
        dropped = sum(d for _, d in pairs)
        nbytes = sum(e.wire_nbytes for e in enc)
        meta = {**buf.meta, "codec": "sparse"}
        if dropped:
            # lossy encode: the capacity bound truncated the COO — say so on
            # the wire buffer and in the process-wide codec stats, so the
            # receiver and the bandwidth analysis both see the loss
            meta["sparse_dropped"] = dropped
            _CODEC_STATS["sparse_truncated_tensors"] += \
                sum(1 for _, d in pairs if d)
            _CODEC_STATS["sparse_dropped_values"] += dropped
        out = buf.with_(tensors=enc, meta=meta)
        return out, nbytes
    raise ValueError(f"unknown codec {codec!r}")


def decode(buf: StreamBuffer, codec: str) -> StreamBuffer:
    codec, _, _ = codec.partition(":")
    if codec == "none":
        return buf
    if codec == "quant8":
        tensors = tuple(_quant8_dec(e) for e in buf.tensors)
    elif codec == "sparse":
        tensors = tuple(_sparse_dec(e) for e in buf.tensors)
    else:
        raise ValueError(f"unknown codec {codec!r}")
    # the payload is dense again: drop the wire-form meta — a stale
    # meta["codec"] on a decoded frame is a double-decode hazard and
    # mis-counts decoded frames as compressed in wire accounting
    meta = {k: v for k, v in buf.meta.items() if k not in _WIRE_META}
    return buf.with_(tensors=tensors, meta=meta)
