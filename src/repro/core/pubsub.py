"""Pub/Sub stream elements — ``mqttsink`` / ``mqttsrc`` (paper §4.2.1).

Transports:

* ``RELAY``  — data plane goes through the broker (pure MQTT). Every frame is
  serialized, accounted on the broker, and copied an extra hop.  This is the
  configuration the paper shows to bottleneck at VGA/FullHD 60 Hz.
* ``HYBRID`` — broker only does discovery/control; frames travel on a direct
  channel between the two pipelines (the paper's MQTT-hybrid, planned for
  pub/sub in "subsequent releases" — we implement it, see DESIGN.md §8
  beyond-paper items).
* ``DIRECT`` — no broker at all (ZeroMQ/TCP counterpart used as the paper's
  normalization baseline; no discovery, fixed endpoint).

On the TPU mesh the data plane of HYBRID/DIRECT lowers to a
``collective_permute`` across the ``pod`` axis (see launch/steps.py); this
module provides the host-level (multi-process simulation) path used by the
runtime scheduler, examples, and the Fig.-7 benchmark.
"""
from __future__ import annotations

import enum
from collections import deque
from typing import Deque, Dict, Optional

import jax.numpy as jnp

from .broker import Broker, BrokerError
from .buffers import StreamBuffer
from .element import Element, PipelineContext, register_element
from .formats import Caps
from . import compression as comp

__all__ = ["Transport", "Channel", "MqttSink", "MqttSrc"]


class Transport(enum.Enum):
    RELAY = "relay"      # pure MQTT: broker carries data
    HYBRID = "hybrid"    # MQTT-hybrid: broker control, direct data
    DIRECT = "direct"    # raw TCP/ZeroMQ: no broker involvement


class Channel:
    """Bounded FIFO standing in for a network socket between two pipelines.
    Tracks bytes for the benchmark harness. ``latency_ns`` models link delay
    (used by the sync tests).

    Pub/sub semantics: a publisher Channel with attached consumers BROADCASTS
    every frame to each consumer queue (MQTT: every subscriber gets every
    message).  With no consumers it queues locally (point-to-point: the query
    protocol's request/response channels)."""

    def __init__(self, capacity: int = 16, latency_ns: int = 0):
        self.q: Deque = deque()
        self.capacity = capacity
        self.latency_ns = latency_ns
        self.bytes_sent = 0
        self.msgs_sent = 0
        self.drops = 0
        self.consumers = []

    def attach_consumer(self, capacity: Optional[int] = None) -> "Channel":
        ch = Channel(capacity=capacity or self.capacity,
                     latency_ns=self.latency_ns)
        self.consumers.append(ch)
        # late subscriber still sees queued history (MQTT retained-ish), but
        # only the newest `capacity` frames survive the replay — flooding a
        # small consumer with the publisher's whole backlog is pointless
        # copying; the skipped frames are accounted as leaky drops
        history = list(self.q)
        survivors = history[-ch.capacity:]
        ch.drops += len(history) - len(survivors)
        ch.q.extend(survivors)
        return ch

    def _enqueue(self, buf: StreamBuffer) -> bool:
        """Returns False iff the append displaced a queued frame."""
        dropped = len(self.q) >= self.capacity
        if dropped:
            self.drops += 1
            self.q.popleft()  # leaky=2 downstream semantics: drop oldest
        self.q.append(buf)
        return not dropped

    def push(self, buf: StreamBuffer, nbytes: Optional[int] = None) -> bool:
        """Returns False iff enqueueing displaced a frame anywhere (locally
        or on any consumer queue).  The displaced frame is booked on the
        displacing queue's ``drops``; returning the fact lets the CALLER
        fold the loss into its own ledger too (serversink answer drops,
        stage-hop push failures) so the conservation laws can't leak."""
        self.bytes_sent += buf.nbytes() if nbytes is None else nbytes
        self.msgs_sent += 1
        if self.consumers:
            ok = True
            for c in self.consumers:
                ok = c._enqueue(buf) and ok
            return ok
        return self._enqueue(buf)

    def pop(self) -> Optional[StreamBuffer]:
        return self.q.popleft() if self.q else None

    def pop_n(self, max_n: int) -> list:
        """Drain up to ``max_n`` queued buffers in FIFO order (the gather
        half of the query batcher's queue-gather-flush)."""
        out = []
        while len(out) < max_n and self.q:
            out.append(self.q.popleft())
        return out

    def __len__(self):
        return len(self.q)


@register_element("mqttsink")
class MqttSink(Element):
    """Publish the incoming stream under ``pub-topic``.

    Properties: pub_topic, transport (relay|hybrid|direct), codec
    (none|quant8|sparse) — codec implements the paper's compressed
    transmission (R3 note: "Sparse tensors and gst-gz support compressed
    transmissions").
    """

    n_src_pads = 0
    host_impure = True
    is_host_sink = True

    def __init__(self, name=None, pub_topic="", transport="hybrid",
                 codec="none", broker: Optional[Broker] = None,
                 sync_clock=None, **props):
        super().__init__(name=name, **props)
        self.topic = props.get("pub-topic", pub_topic)
        self.transport = Transport(transport)
        self.codec = codec
        self.broker = broker
        self.channel = Channel()
        self.registration = None
        self.sync_clock = sync_clock  # PipelineClock for §4.2.3 timestamps

    def connect(self, broker: Broker):
        self.broker = broker
        return self

    def negotiate(self, in_caps):
        caps = in_caps[0] if in_caps else Caps.ANY
        if self.broker is not None and self.transport != Transport.DIRECT:
            # register once, idempotently: runtime re-wires and reconfig
            # commits re-realize the pipeline — a fresh registration per
            # realize would duplicate the topic (and a shadow realize during
            # a prepare would advertise a publisher nobody committed); caps
            # changes from an upstream edit update the standing registration
            if self.registration is None:
                self.registration = self.broker.register(
                    self.topic, caps, self.channel,
                    codec=self.codec, element=self.name)
            else:
                self.registration.caps = caps
        self._caps = caps
        return []

    def apply(self, params, inputs, ctx: PipelineContext = None):
        buf = inputs[0]
        payload, nbytes = comp.encode(buf, self.codec)
        if self.sync_clock is not None:
            payload = payload.with_(meta={**payload.meta,
                                          "base_time_utc": self.sync_clock.base_time_utc()})
        if self.transport == Transport.RELAY and self.broker is not None:
            self.broker.relay(nbytes)  # extra hop through the broker
        self.channel.push(payload, nbytes)
        return []


@register_element("mqttsrc")
class MqttSrc(Element):
    """Subscribe to ``sub-topic`` (wildcards allowed) and emit frames.

    Discovery resolves through the broker to a publisher Channel; if the bound
    publisher dies, the binding fails over automatically (R4).  DIRECT
    transport bypasses discovery — the channel must be wired explicitly
    (``connect_direct``), mirroring IP:port configs the paper argues against.
    """

    n_sink_pads = 0
    host_impure = True
    is_host_source = True

    def __init__(self, name=None, sub_topic="", transport="hybrid",
                 codec="none", broker: Optional[Broker] = None,
                 is_live="false", sync_clock=None, **props):
        super().__init__(name=name, **props)
        self.topic_filter = props.get("sub-topic", sub_topic)
        self.transport = Transport(transport)
        self.codec = codec
        self.broker = broker
        self.binding = None
        self._direct: Optional[Channel] = None
        self._rx: Optional[Channel] = None      # per-subscriber queue
        self._rx_src: Optional[Channel] = None  # publisher it's attached to
        #: one consumer queue per publisher ever bound (id(pub) -> (pub,
        #: rx)): re-binding back to a publisher REUSES its queue, so the
        #: retained history is never replayed twice and frames published
        #: while bound elsewhere are waiting, not stranded.  The publisher
        #: ref is stored alongside so its id() can never be recycled onto a
        #: new channel while the entry lives.
        self._rx_hist: Dict[int, tuple] = {}
        self._pushback: Deque = deque()         # decoded frames handed back
        self.sync_clock = sync_clock

    def connect(self, broker: Broker):
        self.broker = broker
        return self

    def connect_direct(self, channel: Channel):
        self._direct = channel
        return self

    def _resolve(self) -> Channel:
        """Per-subscriber receive queue (broadcast fan-out), re-attached
        transparently after failover.  Frames already queued from the old
        publisher are NOT dropped on a rebind: they are decoded into the
        pushback line (in order, ahead of the new publisher's frames), so a
        live re-binding loses nothing (DESIGN.md §3)."""
        if self.transport == Transport.DIRECT:
            if self._direct is None:
                raise BrokerError(f"{self.name}: DIRECT transport needs connect_direct()")
            pub = self._direct
        else:
            if self.binding is None:
                self.binding = self.broker.subscribe(self.topic_filter)
            pub = self.binding.endpoint
        if self._rx_src is not pub:
            if self._rx is not None:
                while True:
                    raw = self._rx.pop()
                    if raw is None:
                        break
                    self._pushback.append(self._decode(raw))
            prev = self._rx_hist.get(id(pub))
            self._rx = prev[1] if prev is not None else pub.attach_consumer()
            self._rx_hist[id(pub)] = (pub, self._rx)
            self._rx_src = pub
        return self._rx

    @property
    def drops(self) -> int:
        """Leaky-queue drops across every publisher this subscriber has
        ever been bound to — rebinds must not reset the loss accounting."""
        return sum(rx.drops for _, rx in self._rx_hist.values())

    def negotiate(self, in_caps):
        # caps come from the discovered publisher when available; reuse the
        # binding across re-negotiations (runtime re-wires realize the
        # pipeline twice — a fresh binding each time would leak broker
        # watchers and double-deliver events)
        if self.broker is not None and self.transport != Transport.DIRECT:
            try:
                if self.binding is None:
                    self.binding = self.broker.subscribe(self.topic_filter)
                if self.binding.current is not None:
                    return [self.binding.current.caps]
            except BrokerError:
                pass
        return [Caps.ANY]

    def unread(self, bufs) -> None:
        """Hand already-decoded frames back to the source (front of the
        line).  Used by the scheduler when a burst pulled more frames than
        it could run; re-queueing on the raw channel would double-decode."""
        self._pushback.extendleft(reversed(list(bufs)))

    def _decode(self, raw: StreamBuffer) -> StreamBuffer:
        buf = comp.decode(raw, self.codec)
        if self.sync_clock is not None and "base_time_utc" in buf.meta:
            # §4.2.3: rebase the publisher's running-time into ours
            buf = self.sync_clock.rebase(buf)
        return buf

    def pull(self) -> Optional[StreamBuffer]:
        """Host-level receive (runtime scheduler path)."""
        if self._pushback:
            return self._pushback.popleft()
        chan = self._resolve()
        if self._pushback:
            # a rebind just carried the old publisher's queued frames over —
            # they precede anything the new publisher has for us
            return self._pushback.popleft()
        raw = chan.pop()
        if raw is None:
            return None
        return self._decode(raw)

    def queued(self) -> int:
        """Frames currently waiting (pushed-back + per-subscriber queue; 0
        when the binding cannot resolve) — the runtime's burst-sizing
        signal.  Resolve FIRST: a rebind moves the old publisher's stranded
        frames into the pushback line, which must count this very tick."""
        try:
            n = len(self._resolve())
        except BrokerError:
            return len(self._pushback)
        return len(self._pushback) + n

    def pull_burst(self, max_n: int) -> list:
        """Drain up to ``max_n`` decoded frames (host-level burst path).

        Decodes are batched: queued raw frames are popped first, grouped
        into consecutive same-structure runs, and each run decodes in ONE
        stacked codec dispatch (``compression.decode_batch``) instead of
        one per frame — bitwise the per-frame decode.  Pushed-back frames
        (already decoded) and rebind carry-overs keep their front-of-line
        order exactly as :meth:`pull` delivers them."""
        out = []
        while len(out) < max_n and self._pushback:
            out.append(self._pushback.popleft())
        if len(out) >= max_n:
            return out
        try:
            chan = self._resolve()
        except BrokerError:
            return out
        # a rebind inside _resolve may have carried the old publisher's
        # stranded frames into the pushback line — they go first
        while len(out) < max_n and self._pushback:
            out.append(self._pushback.popleft())
        raws = []
        while len(out) + len(raws) < max_n:
            raw = chan.pop()
            if raw is None:
                break
            raws.append(raw)
        out.extend(self._decode_burst(raws))
        return out

    def _decode_burst(self, raws: list) -> list:
        """Batched :meth:`_decode`: consecutive same-structure runs share
        one stacked codec dispatch; clock rebase stays per frame."""
        from .buffers import structure_key
        decoded = []
        i = 0
        while i < len(raws):
            j = i + 1
            # tensors-only key: per-frame meta (pts bases, sync tags) must
            # not split a decodable run — decode_batch stacks payloads and
            # keeps each frame's own meta
            key = structure_key(raws[i].tensors)
            while j < len(raws) and structure_key(raws[j].tensors) == key:
                j += 1
            decoded.extend(comp.decode_batch(raws[i:j], self.codec))
            i = j
        if self.sync_clock is not None:
            decoded = [self.sync_clock.rebase(b) if "base_time_utc" in b.meta
                       else b for b in decoded]
        return decoded

    def apply(self, params, inputs, ctx=None):
        buf = self.pull()
        if buf is None:
            raise BrokerError(
                f"{self.name}: no frame available (drive via runtime scheduler "
                f"or push to the publisher channel first)")
        return [buf]
