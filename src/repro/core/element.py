"""Element base class + factory registry (GStreamer element analogue).

An Element is a pure transformation over StreamBuffers with typed pads.
Caps negotiation happens at *link* time (Pipeline.link), mirroring
GStreamer's link-time caps intersection — incompatible pipelines fail at
construction, not mid-stream (the paper's argument for schema'd streams).

Elements are pure w.r.t. ``apply``: state (e.g. KV caches, RG-LRU state,
query connections) is carried in the params/state pytree, so a compiled
pipeline is a single jittable function.
"""
from __future__ import annotations

import enum
from typing import Callable, Dict, List, Optional, Sequence, Type

import jax

from .buffers import StreamBuffer
from .formats import Caps, CapsError

__all__ = ["Element", "register_element", "element_factory", "FACTORY"]

FACTORY: Dict[str, Type["Element"]] = {}


def register_element(factory_name: str):
    def deco(cls: Type["Element"]):
        cls.factory_name = factory_name
        FACTORY[factory_name] = cls
        return cls
    return deco


def element_factory(factory_name: str, name: Optional[str] = None, **props) -> "Element":
    try:
        cls = FACTORY[factory_name]
    except KeyError as e:
        raise KeyError(
            f"no such element factory {factory_name!r}; "
            f"known: {sorted(FACTORY)}") from e
    return cls(name=name, **props)


class Element:
    """Base element.  Subclasses declare pad counts and caps templates and
    implement ``apply``.

    * ``n_sink_pads`` / ``n_src_pads`` — fixed pad counts (None = request pads,
      grown on demand like GStreamer request pads on mux/compositor/tee).
    * ``sink_caps_template()`` — what the element accepts.
    * ``negotiate(in_caps)`` — given negotiated input caps, return output caps.
    * ``apply(params, inputs, ctx)`` — list[StreamBuffer] -> list[StreamBuffer].
    """

    factory_name = "element"
    n_sink_pads: Optional[int] = 1
    n_src_pads: Optional[int] = 1

    #: element performs host-level side effects in ``apply`` (channel I/O,
    #: broker traffic) and therefore cannot be traced into a compiled plan
    host_impure = False
    #: host-impure *source* whose frame the scheduler can pull & inject
    #: (mqttsrc) — hoistable out of a compiled burst
    is_host_source = False
    #: host-impure *terminal sink* whose input frame a compiled burst can
    #: capture for post-hoc replay (mqttsink)
    is_host_sink = False

    _uid = 0

    def __init__(self, name: Optional[str] = None, **props):
        if name is None:
            Element._uid += 1
            name = f"{self.factory_name}{Element._uid}"
        self.name = name
        self.props = props
        self.in_caps: List[Caps] = []
        self.out_caps: List[Caps] = []

    # -- caps ---------------------------------------------------------------
    def sink_caps_template(self, pad: int = 0) -> Caps:
        return Caps.ANY

    def negotiate(self, in_caps: Sequence[Caps]) -> List[Caps]:
        """Default: single pass-through pad."""
        n_out = self.n_src_pads if self.n_src_pads is not None else 1
        base = in_caps[0] if in_caps else Caps.ANY
        return [base] * n_out

    def accept_caps(self, pad: int, caps: Caps) -> Caps:
        tmpl = self.sink_caps_template(pad)
        try:
            return caps.intersect(tmpl)
        except CapsError as e:
            raise CapsError(f"{self.name}.sink_{pad}: {e}") from e

    # -- plan fingerprinting -------------------------------------------------
    def plan_signature(self) -> tuple:
        """Static-config fingerprint used as part of the executable-cache
        key.  Must cover everything that changes ``apply``'s traced
        behavior: class, name, scalar/tuple config attributes, props, and
        negotiated caps.  Subclasses with behavior carried by non-attribute
        config (callables, registries) extend via ``plan_signature_extra``.
        """
        cfg = []
        for k, v in sorted(vars(self).items()):
            if k.startswith("_") or k in ("in_caps", "out_caps", "props"):
                continue
            if isinstance(v, (str, int, float, bool, type(None))):
                cfg.append((k, v))
            elif isinstance(v, (tuple, list, dict, enum.Enum)):
                cfg.append((k, repr(v)))
        return (type(self).__name__, self.factory_name, self.name,
                tuple(cfg), repr(sorted(self.props.items())),
                tuple(c.describe() for c in self.in_caps),
                tuple(c.describe() for c in self.out_caps),
                self.plan_signature_extra())

    def plan_signature_extra(self) -> tuple:
        return ()

    # -- params / state ------------------------------------------------------
    def init_params(self, rng) -> dict:
        return {}

    def init_state(self) -> dict:
        """Per-stream mutable state threaded through compiled steps."""
        return {}

    # -- execution ------------------------------------------------------------
    def apply(self, params, inputs: List[StreamBuffer], ctx=None) -> List[StreamBuffer]:
        raise NotImplementedError(self.factory_name)

    def __repr__(self):
        kv = " ".join(f"{k}={v}" for k, v in self.props.items())
        return f"<{self.factory_name} {self.name}{' ' + kv if kv else ''}>"


class StatefulElement(Element):
    """Element whose apply also consumes/produces state:
    apply(params, inputs, ctx) may read ctx.state[self.name] and write
    ctx.next_state[self.name] (both pytrees)."""


class PipelineContext:
    """Per-step context handed to elements: carries stream state in/out and
    static run info (step index is traced, wiring info is static)."""

    def __init__(self, state: dict, rng=None):
        self.state = state
        self.next_state = dict(state)
        self.rng = rng

    def get_state(self, name: str):
        return self.state.get(name)

    def set_state(self, name: str, value):
        self.next_state[name] = value
