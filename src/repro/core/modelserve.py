"""Model serving elements — a real autoregressive LM behind the query fabric.

``model_serve`` puts an actual ``models/`` network (transformer, rGLRU
hybrid, ...) behind ``tensor_query_serversrc ! model_serve !
tensor_query_serversink``.  Decode state is PLAN STATE: a slot-stacked
KV-cache / recurrent-state pytree plus an active-slot mask, carried across
ticks through the pipeline state dict.  Continuous batching happens INSIDE
one jitted decode dispatch — requests join (slot allocation, prefilled
cache merged in under the admit mask) and leave (slots freed when
``remaining`` hits zero) mid-generation without retracing, because the
traced program only sees fixed slot-axis shapes (DESIGN.md §7).

Parity-by-construction: the decode tick runs each slot as an independent
``b=1`` ``lm_decode`` via ``lax.scan`` over the slot axis — the identical
traced program a per-request sequential decode runs — and commits state
with a ``where(active, new, old)`` select, so continuous-batched output is
bitwise the sequential output regardless of join/leave order (pinned in
tests/test_model_serving.py).

The host half (prefill, admit-bundle assembly) lives on the element too:
the StreamingQueryBatcher calls ``host_prefill`` when a request arrives,
``build_admit``/``empty_admit`` each tick, and reads the (token, emitted,
finished) lanes the dispatch captured at the serversink.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .buffers import StreamBuffer
from .element import Element, PipelineContext, register_element
from .formats import Caps

__all__ = ["ModelServeElement", "ModelServeStageElement", "TokenPromptSrc",
           "SERVE_MODELS", "register_serve_model"]

# Preset registry: ``model_serve model=<key>`` resolves through here, so
# pipeline descriptions stay gst-launch strings.  Values are zero-arg
# callables returning a ModelConfig (lazy: configs import only on use).
SERVE_MODELS: Dict[str, Callable] = {}


def register_serve_model(key: str, cfg_fn: Callable):
    SERVE_MODELS[key] = cfg_fn
    return cfg_fn


def _default_presets():
    """Tier-1 CPU presets: a small dense transformer (flash-attention on the
    serve path) and one recurrent (rGLRU hybrid) so the stateful-plan
    contract covers both KV-cache and SSM-style state."""
    if "stablelm-smoke-flash" not in SERVE_MODELS:
        def _stablelm():
            import dataclasses
            from ..configs import stablelm_1_6b
            return dataclasses.replace(stablelm_1_6b.config().smoke(),
                                       use_flash_attn=True)
        SERVE_MODELS["stablelm-smoke-flash"] = _stablelm
    if "recurrentgemma-smoke" not in SERVE_MODELS:
        def _rglru():
            from ..configs import recurrentgemma_9b
            return recurrentgemma_9b.config().smoke()
        SERVE_MODELS["recurrentgemma-smoke"] = _rglru


@register_element("model_serve")
class ModelServeElement(Element):
    """Autoregressive decode as plan state with continuous batching.

    Props (gst-launch strings, coerced like TestSrc):
      * ``model``   — SERVE_MODELS preset key
      * ``slots``   — decode-batch capacity S (the slot axis of every state
                      leaf; requests beyond S wait in the batcher's FIFO)
      * ``max_seq`` — KV-cache length (prompt length + generation budget
                      must fit)

    State (pytree, per slot):
      ``cache[S, ...]``   — slot-stacked b=1 decode caches
      ``active[S]``       — bool mask, THE fingerprint-relevant lane
      ``token[S]``        — last emitted token (next decode input)
      ``remaining[S]``    — decode steps left before the slot frees

    Input frame (injected by the batcher at the hoisted serversrc):
      ``(admit_mask[S], admit_tok[S], admit_rem[S], *admit_cache_leaves)``,
      or the structurally tiny ``(mask,)`` + ``meta={"empty": True}`` on a
      no-join tick (static aux — its own cached trace, no cache transfer)
    Output frame (captured at the serversink):
      ``(token[S], emitted[S], finished[S])``
    """

    #: streaming serve workload: ExecutionPlan routes this pipeline through
    #: the stateful ``compiled_serve_tick`` path, the scheduler wires a
    #: StreamingQueryBatcher instead of the stateless stack-scan batcher
    is_stream_serve = True

    def __init__(self, name=None, model="stablelm-smoke-flash", slots=8,
                 max_seq=64, **props):
        super().__init__(name=name, **props)
        self.model = str(props.get("model", model))
        self.slots = int(props.get("slots", slots))
        self.max_seq = int(props.get("max_seq", max_seq))
        self._cfg = None
        self._prefill_jit = None

    # -- config / cache templates (host-side, cached) -------------------------
    @property
    def cfg(self):
        if self._cfg is None:
            _default_presets()
            try:
                self._cfg = SERVE_MODELS[self.model]()
            except KeyError as e:
                raise KeyError(
                    f"model_serve model={self.model!r} not registered; "
                    f"known: {sorted(SERVE_MODELS)}") from e
        return self._cfg

    def _cache_template(self):
        """Zero b=1 decode cache: the per-slot state an admitted request's
        prefilled cache must structurally match."""
        from ..models import transformer
        return transformer.cache_init(self.cfg, 1, self.max_seq)

    def negotiate(self, in_caps):
        return [Caps(media="other/tensors")]

    # -- params / state -------------------------------------------------------
    def init_params(self, rng) -> dict:
        from ..models import transformer
        return transformer.init_params(rng, self.cfg)

    def init_state(self) -> dict:
        s = self.slots
        cache = jax.tree_util.tree_map(
            lambda l: jnp.zeros((s,) + tuple(jnp.shape(l)), l.dtype),
            self._cache_template())
        return {"cache": cache,
                "active": jnp.zeros((s,), jnp.bool_),
                "token": jnp.zeros((s,), jnp.int32),
                "remaining": jnp.zeros((s,), jnp.int32)}

    # -- the jitted decode tick ----------------------------------------------
    def apply(self, params, inputs: List[StreamBuffer],
              ctx: PipelineContext = None) -> List[StreamBuffer]:
        from ..models import transformer
        cfg = self.cfg
        st = ctx.get_state(self.name)
        admit = inputs[0].tensors

        # 1. admit: merge prefilled caches under the admit mask (slot rows
        #    of leaving/free slots keep their old — soon overwritten —
        #    values).  A no-join tick carries the STRUCTURALLY tiny empty
        #    bundle (mask only — ``meta["empty"]`` is static aux), so the
        #    steady-state decode tick neither ships a zero slot-stacked
        #    cache over the host edge nor pays the full-state select.
        if inputs[0].meta.get("empty"):
            cache, token = st["cache"], st["token"]
            remaining, active = st["remaining"], st["active"]
        else:
            treedef = jax.tree_util.tree_structure(self._cache_template())
            admit_mask, admit_tok, admit_rem = admit[0], admit[1], admit[2]
            admit_cache = jax.tree_util.tree_unflatten(treedef,
                                                       list(admit[3:]))

            def merge(old, new):
                m = admit_mask.reshape((-1,) + (1,) * (old.ndim - 1))
                return jnp.where(m, new, old)
            cache = jax.tree_util.tree_map(merge, st["cache"], admit_cache)
            token = jnp.where(admit_mask, admit_tok, st["token"])
            remaining = jnp.where(admit_mask, admit_rem, st["remaining"])
            active = st["active"] | admit_mask

        # 2. decode tick: each slot is an independent b=1 lm_decode — the
        #    same traced program sequential per-request decode runs — vmapped
        #    over the slot axis, so the S slots' matmuls fuse into batched
        #    contractions (the continuous-batching throughput lever) while
        #    each slot's values stay the per-request values (slot rows are
        #    independent rows of every batched matmul — bitwise parity is
        #    pinned in tests/test_model_serving.py).  Inactive slots compute
        #    on zero caches and are discarded by the select below.
        def slot_step(c, tok, act):
            logits, new_c = transformer.lm_decode(params, cfg, tok[None], c)
            new_tok = jnp.argmax(logits[0], axis=-1).astype(jnp.int32)
            c_out = jax.tree_util.tree_map(
                lambda old, new: jnp.where(act, new, old), c, new_c)
            return c_out, jnp.where(act, new_tok, tok)

        cache, token = jax.vmap(slot_step)(cache, token, active)

        # 3. retire: a slot leaves the batch the tick its budget hits zero
        emitted = active
        rem_after = remaining - active.astype(jnp.int32)
        finished = active & (rem_after <= 0)
        ctx.set_state(self.name, {
            "cache": cache,
            "active": active & ~finished,
            "token": token,
            "remaining": jnp.maximum(rem_after, 0),
        })
        return [inputs[0].with_(tensors=(token, emitted, finished), meta={})]

    # -- host half (StreamingQueryBatcher calls) ------------------------------
    def active_slots(self, state) -> int:
        """Occupied decode slots right now — the serve-capacity half of the
        broker's scaling signal (DESIGN.md §9: a streaming server's load is
        its queue depth PLUS the streams already holding slots across
        ticks).  Reads the plan-state active mask; cheap enough for the
        per-tick heartbeat.

        Slot admission is where tenant priority acts (the batcher's waiting
        pool orders by the admission record's ``(priority, deadline,
        arrival)`` key); once a stream holds a slot it is NEVER evicted
        before its ``finished`` lane fires — preemption happens only at
        generation boundaries, so a slot's cache lineage stays intact."""
        st = state.get(self.name, {})
        active = st.get("active")
        if active is None:
            return 0
        import numpy as _np
        return int(_np.asarray(jax.device_get(active)).sum())

    def host_prefill(self, params, prompt):
        """Prefill one request: prompt int32[L] -> (first token int, b=1
        decode cache).  Jitted per prompt length (element-local cache, NOT
        the plan exec cache — the retrace set is per-length and bounded by
        the workload, not the topology)."""
        from ..models import transformer
        if self._prefill_jit is None:
            cfg, max_seq = self.cfg, self.max_seq

            def prefill(p, toks):
                logits, cache = transformer.lm_prefill(p, cfg, toks[None],
                                                       max_seq)
                return jnp.argmax(logits[0], axis=-1).astype(jnp.int32), cache
            self._prefill_jit = jax.jit(prefill)
        tok, cache = self._prefill_jit(params, jnp.asarray(prompt, jnp.int32))
        return int(tok), cache

    def empty_admit(self) -> StreamBuffer:
        """No-join tick: a structurally TINY bundle (mask only, flagged by
        static meta) — the steady-state decode tick must not ship a zero
        slot-stacked cache across the host edge just to say 'nobody
        joined'.

        A FRESH buffer (fresh meta dict) every call: ``apply`` hands
        ``inputs[0].with_(...)`` downstream and the serversink routing
        idiom re-attaches meta, so one cached buffer shared across every
        no-join tick of every stage would let any consumer's meta mutation
        corrupt all later ticks.  The mask ndarray is shared but
        write-protected — aliasing it is safe, writing it raises."""
        if getattr(self, "_empty_mask", None) is None:
            mask = np.zeros((self.slots,), np.bool_)
            mask.flags.writeable = False
            self._empty_mask = mask
        return StreamBuffer(tensors=(self._empty_mask,),
                            meta={"empty": True})

    def _zero_admit(self):
        """Zero full-width admit rows build_admit scatters into."""
        if getattr(self, "_zero_admit_base", None) is None:
            s = self.slots
            leaves = [np.zeros((s,) + tuple(jnp.shape(l)),
                               np.dtype(str(l.dtype)))
                      for l in jax.tree_util.tree_leaves(self._cache_template())]
            self._zero_admit_base = (
                np.zeros((s,), np.bool_), np.zeros((s,), np.int32),
                np.zeros((s,), np.int32), *leaves)
        return self._zero_admit_base

    def build_admit(self, admits) -> StreamBuffer:
        """Assemble the admit bundle for one tick.  ``admits`` is a list of
        ``(slot, first_token, remaining, b1_cache)``; rows outside the admit
        mask are zero (ignored by the masked merge)."""
        if not admits:
            return self.empty_admit()
        base = self._zero_admit()
        mask = base[0].copy()
        tok = base[1].copy()
        rem = base[2].copy()
        leaves = [l.copy() for l in base[3:]]
        for slot, t, r, cache in admits:
            mask[slot] = True
            tok[slot] = t
            rem[slot] = r
            for dst, src in zip(leaves, jax.tree_util.tree_leaves(
                    jax.device_get(cache))):
                dst[slot] = src
        return StreamBuffer(tensors=(mask, tok, rem, *leaves), meta={})


@register_element("model_serve_stage")
class ModelServeStageElement(ModelServeElement):
    """One pipeline-parallel stage of a model behind the query fabric
    (DESIGN.md §8): layers ``[stage*R/N, (stage+1)*R/N)`` of the preset
    plus that slice of the slot-stacked decode cache as plan state.  The
    first stage embeds tokens, the last norms + unembeds; per-slot
    boundary activations hop stage → stage over the pub/sub + query
    fabric, driven by the StagedStreamingBatcher on stage 0.

    State is the stage cache ONLY — the coordinator owns the slot table
    (active/token/remaining lanes) and ships ``active`` as a tensor each
    hop, so downstream stages are pure cache-holders whose stale rows are
    inert until re-admitted.

    Input frame (a hop bundle assembled host-side):
      ``(x_in[S,...], active[S])`` + ``meta={"empty": True}`` steady-state,
      or ``(x_in, active, admit_mask[S], *admit_cache_leaves)`` on a tick
      with joins (parked b=1 prefill caches scattered into slot rows).
      ``x_in`` is ``token[S] int32`` on stage 0, acts ``[S, 1, d]`` after.
    Output frame: next-stage acts ``[S, 1, d]`` (zeroed where inactive),
      or ``token[S] int32`` from the last stage.
    """

    #: stage pipelines get hop-serving batchers, not the client-facing
    #: streaming lifecycle (scheduler._wire dispatches on this + stage)
    is_stage_serve = True

    def __init__(self, name=None, model="stablelm-smoke-flash", slots=8,
                 max_seq=64, stage=0, n_stages=1, **props):
        super().__init__(name=name, model=model, slots=slots,
                         max_seq=max_seq, **props)
        self.stage = int(props.get("stage", stage))
        self.n_stages = int(props.get("n_stages", n_stages))

    @property
    def is_first(self) -> bool:
        return self.stage == 0

    @property
    def is_last(self) -> bool:
        return self.stage == self.n_stages - 1

    def _cache_template(self):
        from ..models import transformer
        return transformer.stage_cache_init(self.cfg, self.stage,
                                            self.n_stages, 1, self.max_seq)

    # -- params / state -------------------------------------------------------
    def init_params(self, rng) -> dict:
        """Init the FULL model from ``rng`` then slice this stage's share.
        Every stage pipeline puts its model element at the same position
        (``ssrc ! stage ! ssink``), so Pipeline.init hands each stage the
        SAME sub-rng the monolithic server's model element gets — the full
        trees are identical and the slices compose back to the monolithic
        params exactly (the staged-vs-single bitwise pin rests on this)."""
        from ..models import transformer
        full = transformer.init_params(rng, self.cfg)
        return transformer.stage_params(full, self.cfg, self.stage,
                                        self.n_stages)

    def init_state(self) -> dict:
        s = self.slots
        cache = jax.tree_util.tree_map(
            lambda l: jnp.zeros((s,) + tuple(jnp.shape(l)), l.dtype),
            self._cache_template())
        return {"cache": cache}

    # -- the jitted stage hop -------------------------------------------------
    def apply(self, params, inputs: List[StreamBuffer],
              ctx: PipelineContext = None) -> List[StreamBuffer]:
        from ..models import transformer
        cfg = self.cfg
        st = ctx.get_state(self.name)
        ts = inputs[0].tensors
        x_in, active = ts[0], ts[1]
        if inputs[0].meta.get("empty"):
            cache = st["cache"]
        else:
            treedef = jax.tree_util.tree_structure(self._cache_template())
            admit_mask = ts[2]
            admit_cache = jax.tree_util.tree_unflatten(treedef, list(ts[3:]))

            def merge(old, new):
                m = admit_mask.reshape((-1,) + (1,) * (old.ndim - 1))
                return jnp.where(m, new, old)
            cache = jax.tree_util.tree_map(merge, st["cache"], admit_cache)

        stage, n_stages = self.stage, self.n_stages

        def slot_step(c, x, act):
            out, new_c = transformer.stage_decode(params, cfg, stage,
                                                  n_stages, x[None], c)
            c_out = jax.tree_util.tree_map(
                lambda old, new: jnp.where(act, new, old), c, new_c)
            if stage == n_stages - 1:
                y = jnp.argmax(out[0], axis=-1).astype(jnp.int32)
                return c_out, jnp.where(act, y, 0)
            return c_out, jnp.where(act, out[0], jnp.zeros_like(out[0]))

        cache, y = jax.vmap(slot_step)(cache, x_in, active)
        ctx.set_state(self.name, {"cache": cache})
        return [inputs[0].with_(tensors=(y,), meta={})]

    # -- host half (hop bundle assembly + stage-local prefill/replay) ---------
    def _zero_hop(self):
        """Zero admit-cache rows ``build_hop`` scatters parked caches into."""
        if getattr(self, "_zero_hop_base", None) is None:
            s = self.slots
            leaves = [np.zeros((s,) + tuple(jnp.shape(l)),
                               np.dtype(str(l.dtype)))
                      for l in jax.tree_util.tree_leaves(self._cache_template())]
            self._zero_hop_base = (np.zeros((s,), np.bool_), *leaves)
        return self._zero_hop_base

    def build_hop(self, x_in, active, admits) -> StreamBuffer:
        """Assemble one decode-hop bundle.  ``admits`` is a list of
        ``(slot, b1_cache)`` parked prefill caches joining this tick; empty
        admits give the structurally tiny steady-state bundle."""
        if not admits:
            return StreamBuffer(tensors=(x_in, active),
                                meta={"empty": True})
        base = self._zero_hop()
        mask = base[0].copy()
        leaves = [l.copy() for l in base[1:]]
        for slot, cache in admits:
            mask[slot] = True
            for dst, src in zip(leaves, jax.tree_util.tree_leaves(
                    jax.device_get(cache))):
                dst[slot] = src
        return StreamBuffer(tensors=(x_in, active, mask, *leaves), meta={})

    def host_stage_prefill(self, params, x):
        """Stage-local prefill: tokens int32[L] (stage 0) or boundary acts
        float[1, L, d] -> (boundary out, b=1 stage cache).  Jitted per
        input shape (element-local cache, workload-bounded like
        ``host_prefill``).  The last stage argmaxes inside the jit — the
        same program position the monolithic ``host_prefill`` uses."""
        from ..models import transformer
        if getattr(self, "_stage_prefill_jits", None) is None:
            self._stage_prefill_jits = {}
        x = np.asarray(x)
        key = (x.shape, str(x.dtype))
        fn = self._stage_prefill_jits.get(key)
        if fn is None:
            cfg, max_seq = self.cfg, self.max_seq
            stage, n_stages = self.stage, self.n_stages

            def prefill(p, xx):
                if stage == 0:
                    xx = xx[None]       # [L] tokens -> [1, L]
                out, cache = transformer.stage_prefill(p, cfg, stage,
                                                       n_stages, xx, max_seq)
                if stage == n_stages - 1:
                    out = jnp.argmax(out[0], axis=-1).astype(jnp.int32)
                return out, cache
            fn = self._stage_prefill_jits[key] = jax.jit(prefill)
        out, cache = fn(params, jnp.asarray(x))
        return np.asarray(out), cache

    def host_stage_decode(self, params, x, cache):
        """One b=1 decode step through this stage against a parked cache —
        the stage-local REPLAY primitive (DESIGN.md §8): re-running the
        retained boundary activations through this rebuilds a dead stage's
        cache without touching any other stage, bitwise by construction
        (identical traced program on identical inputs)."""
        from ..models import transformer
        if getattr(self, "_stage_decode_jit", None) is None:
            cfg = self.cfg
            stage, n_stages = self.stage, self.n_stages

            def decode(p, xx, c):
                if stage == 0:
                    xx = xx.reshape((1,)).astype(jnp.int32)
                out, new_c = transformer.stage_decode(p, cfg, stage,
                                                      n_stages, xx, c)
                if stage == n_stages - 1:
                    out = jnp.argmax(out[0], axis=-1).astype(jnp.int32)
                return out, new_c
            self._stage_decode_jit = jax.jit(decode)
        out, cache = self._stage_decode_jit(params, jnp.asarray(x), cache)
        return np.asarray(out), cache

    def host_stage_decode_idempotent(self, params, x, cache, hop_id=None):
        """``host_stage_decode`` with at-most-once effect per ``hop_id``
        (the hop's §10 delivery id): a replayed hop whose id was already
        served returns the memoized (out, cache) instead of advancing the
        parked cache a second time.  This is the stage element's backstop
        BENEATH the batcher's dedup window — a duplicate that slips past
        an evicted window still cannot double-step generation state.
        ``hop_id=None`` (delivery off) is plain ``host_stage_decode``."""
        if hop_id is None:
            return self.host_stage_decode(params, x, cache)
        if getattr(self, "_hop_memo", None) is None:
            from collections import OrderedDict
            self._hop_memo = OrderedDict()
        hit = self._hop_memo.get(hop_id)
        if hit is not None:
            return hit
        out = self.host_stage_decode(params, x, cache)
        self._hop_memo[hop_id] = out
        while len(self._hop_memo) > 64:
            self._hop_memo.popitem(last=False)
        return out


@register_element("token_prompt_src")
class TokenPromptSrc(Element):
    """Deterministic streaming-workload source: emits one prompt request per
    frame, cycling through ``prompts`` ("1,2,3;4,5" — ';'-separated prompt
    lists) and ``gens`` ("6;4" — total tokens to generate per request),
    tagging ``gen`` into meta for the streaming server.  The frame counter
    lives in pipeline state (TestSrc idiom) so soak workloads replay
    deterministically.

    Host-impure on purpose: per-frame ``gen`` meta and prompt-list cycling
    are host decisions (meta is static pytree aux — a compiled deferred
    segment would bake one gen per trace), so client pipelines carrying
    this source keep the interpreted deferral path."""

    host_impure = True
    n_sink_pads = 0

    def __init__(self, name=None, prompts="1,2,3", gens="4", **props):
        super().__init__(name=name, **props)
        self.prompts = str(props.get("prompts", prompts))
        self.gens = str(props.get("gens", gens))
        self._prompt_list = [
            tuple(int(t) for t in p.split(",") if t)
            for p in self.prompts.split(";") if p]
        self._gen_list = [int(g) for g in self.gens.split(";") if g]

    def negotiate(self, in_caps):
        return [Caps(media="other/tensors")]

    def init_state(self):
        return {"frame": jnp.int32(0)}

    def apply(self, params, inputs, ctx: PipelineContext = None):
        i = int(ctx.get_state(self.name)["frame"])
        prompt = self._prompt_list[i % len(self._prompt_list)]
        gen = self._gen_list[i % len(self._gen_list)]
        ctx.set_state(self.name, {"frame": jnp.int32(i + 1)})
        return [StreamBuffer(tensors=(jnp.asarray(prompt, jnp.int32),),
                             meta={"gen": gen})]
