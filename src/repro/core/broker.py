"""Control-plane broker — the MQTT analogue (paper §4.2).

The broker carries *control* traffic only in HYBRID mode (discovery,
capability negotiation, liveness, failover), exactly like the paper's
MQTT-hybrid protocol; in RELAY mode it also relays the data plane (pure MQTT),
which the paper measures to be the bandwidth bottleneck (Fig. 7).

Topics follow MQTT semantics: '/'-separated levels, subscriptions may use
'+' (one level) and '#' (all remaining levels) wildcards — the paper's
example: servers "/objdetect/mobilev3" and "/objdetect/yolov2", client
subscribes "/objdetect/#" and the broker picks one (R3), failing over to the
alternative when the connected one dies (R4).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from .formats import Caps

__all__ = ["Broker", "Registration", "topic_matches", "BrokerError"]


class BrokerError(RuntimeError):
    pass


def topic_matches(pattern: str, topic: str) -> bool:
    """MQTT topic-filter matching with '+' and '#'."""
    pp = pattern.strip("/").split("/")
    tt = topic.strip("/").split("/")
    for i, p in enumerate(pp):
        if p == "#":
            return True
        if i >= len(tt):
            return False
        if p != "+" and p != tt[i]:
            return False
    return len(pp) == len(tt)


@dataclass
class Registration:
    """A published service/stream: topic + caps + declared specs (the paper:
    servers may declare 'workload status' and 'model and version' for clients
    to choose)."""

    topic: str
    caps: Caps
    endpoint: Any                      # publisher object (data-plane handle)
    specs: Dict[str, Any] = field(default_factory=dict)
    alive: bool = True
    reg_id: int = 0

    def describe(self) -> str:
        extra = ", ".join(f"{k}={v}" for k, v in self.specs.items())
        return f"{self.topic} [{self.caps.describe()}] {extra}".strip()


class Broker:
    """In-process MQTT-analogue. Subscribers get *bindings* that auto-fail-over
    across compatible registrations (R4)."""

    def __init__(self, name: str = "broker"):
        self.name = name
        self._regs: Dict[int, Registration] = {}
        self._ids = itertools.count(1)
        self._watchers: List[Callable[[str, Registration], None]] = []
        # data-plane accounting for RELAY transport benchmarking
        self.relay_bytes = 0
        self.relay_msgs = 0

    # -- publish side ----------------------------------------------------------
    def register(self, topic: str, caps: Caps, endpoint: Any,
                 **specs) -> Registration:
        reg = Registration(topic=topic, caps=caps, endpoint=endpoint,
                           specs=specs, reg_id=next(self._ids))
        self._regs[reg.reg_id] = reg
        self._notify("register", reg)
        return reg

    def unregister(self, reg: Registration):
        reg.alive = False
        self._regs.pop(reg.reg_id, None)
        self._notify("unregister", reg)

    def mark_down(self, reg: Registration):
        """Liveness loss without clean unregister (device crash)."""
        reg.alive = False
        self._notify("down", reg)

    # -- discovery -------------------------------------------------------------
    def discover(self, topic_filter: str,
                 require: Optional[Dict[str, Any]] = None) -> List[Registration]:
        out = []
        for reg in self._regs.values():
            if not reg.alive:
                continue
            if not topic_matches(topic_filter, reg.topic):
                continue
            if require and any(reg.specs.get(k) != v for k, v in require.items()):
                continue
            out.append(reg)
        return sorted(out, key=lambda r: r.reg_id)

    def subscribe(self, topic_filter: str, **require) -> "Binding":
        return Binding(self, topic_filter, require or None)

    def _notify(self, event: str, reg: Registration):
        for w in list(self._watchers):
            w(event, reg)

    def watch(self, fn: Callable[[str, Registration], None]):
        self._watchers.append(fn)

    # -- RELAY data plane -------------------------------------------------------
    def relay(self, payload_nbytes: int):
        """Account one broker-relayed frame (pure-MQTT data plane)."""
        self.relay_bytes += payload_nbytes
        self.relay_msgs += 1


class Binding:
    """A live subscription that resolves to one concrete registration and
    transparently fails over (R4)."""

    def __init__(self, broker: Broker, topic_filter: str,
                 require: Optional[Dict[str, Any]]):
        self.broker = broker
        self.topic_filter = topic_filter
        self.require = require
        self.current: Optional[Registration] = None
        self.failovers = 0
        broker.watch(self._on_event)
        self._rebind()

    def _rebind(self):
        cands = self.broker.discover(self.topic_filter, self.require)
        prev = self.current
        self.current = cands[0] if cands else None
        if prev is not None and self.current is not None and prev is not self.current:
            self.failovers += 1

    def _on_event(self, event: str, reg: Registration):
        if event in ("down", "unregister") and reg is self.current:
            self._rebind()
        elif event == "register" and self.current is None \
                and topic_matches(self.topic_filter, reg.topic):
            self._rebind()

    @property
    def endpoint(self):
        if self.current is None:
            raise BrokerError(
                f"no live publisher for {self.topic_filter!r}"
                + (f" with {self.require}" if self.require else ""))
        return self.current.endpoint
