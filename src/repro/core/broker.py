"""Control-plane broker — the MQTT analogue (paper §4.2).

The broker carries *control* traffic only in HYBRID mode (discovery,
capability negotiation, liveness, failover), exactly like the paper's
MQTT-hybrid protocol; in RELAY mode it also relays the data plane (pure MQTT),
which the paper measures to be the bandwidth bottleneck (Fig. 7).

Topics follow MQTT semantics: '/'-separated levels, subscriptions may use
'+' (one level) and '#' (all remaining levels) wildcards — the paper's
example: servers "/objdetect/mobilev3" and "/objdetect/yolov2", client
subscribes "/objdetect/#" and the broker picks one (R3), failing over to the
alternative when the connected one dies (R4).

Liveness (DESIGN.md §3): a registration may carry a **lease** — it must be
refreshed by :meth:`Broker.heartbeat` or it expires ``lease_ticks`` broker
ticks after the last beat (``Broker.tick`` is the lease clock; the runtime
scheduler drives it once per scheduler tick and heartbeats on behalf of its
live devices).  ``mark_down`` (crash notice) and lease expiry both fire a
single ``"down"`` watch event; a downed registration does NOT come back by
merely heartbeating again — the device must :meth:`Broker.revive` (or
re-register), which fires ``"register"``, exactly like an MQTT client
reconnecting with a fresh CONNECT after its keep-alive lapsed.

Selection (R3) is capability-aware: ``Binding`` ranks matching registrations
by :meth:`Broker.rank_key` — preferred codec support, declared throughput,
current load (maintained by the runtime from its stats), registration order
as the deterministic tiebreak — instead of first-match.  A newly registered
(or revived) publisher that outranks the bound one wins the binding back.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from .formats import Caps

__all__ = ["Broker", "Registration", "Binding", "topic_matches",
           "BrokerError"]


class BrokerError(RuntimeError):
    pass


def topic_matches(pattern: str, topic: str) -> bool:
    """MQTT topic-filter matching with '+' and '#'."""
    pp = pattern.strip("/").split("/")
    tt = topic.strip("/").split("/")
    for i, p in enumerate(pp):
        if p == "#":
            return True
        if i >= len(tt):
            return False
        if p != "+" and p != tt[i]:
            return False
    return len(pp) == len(tt)


@dataclass
class Registration:
    """A published service/stream: topic + caps + declared specs (the paper:
    servers may declare 'workload status' and 'model and version' for clients
    to choose)."""

    topic: str
    caps: Caps
    endpoint: Any                      # publisher object (data-plane handle)
    specs: Dict[str, Any] = field(default_factory=dict)
    alive: bool = True
    reg_id: int = 0
    #: missed-heartbeat tolerance in broker ticks; None = no lease (the
    #: registration never expires on its own)
    lease_ticks: Optional[int] = None
    #: broker tick of the last heartbeat (or registration/revival)
    last_beat: int = 0
    #: current workload — refreshed by the runtime from its stats; lower
    #: ranks better (the paper's "server workload status")
    load: float = 0.0
    #: why the registration went down ("crash" | "lease-expired"), for
    #: diagnostics and the chaos harness's assertions
    down_reason: Optional[str] = None
    #: lease-expiry is SUSPICION, not declared death (DESIGN.md §10): under
    #: a lossy control plane a silent server may be alive behind a
    #: partition.  A suspected registration fails over exactly like a
    #: crashed one (clients must not wait on a maybe-corpse), but it stays
    #: eligible for :meth:`Broker.heal` when its beats resume — a crash
    #: notice clears the flag (that death is declared, not inferred).
    suspected: bool = False

    def describe(self) -> str:
        extra = ", ".join(f"{k}={v}" for k, v in self.specs.items())
        return f"{self.topic} [{self.caps.describe()}] {extra}".strip()


def _as_float(v, default: float = 0.0) -> float:
    try:
        return float(v)
    except (TypeError, ValueError):
        return default


class Broker:
    """In-process MQTT-analogue. Subscribers get *bindings* that auto-fail-over
    across compatible registrations (R4)."""

    def __init__(self, name: str = "broker",
                 lease_ticks: Optional[int] = None):
        self.name = name
        self._regs: Dict[int, Registration] = {}
        self._ids = itertools.count(1)
        self._watchers: List[Callable[[str, Registration], None]] = []
        #: lease applied to registrations that don't declare their own
        self.default_lease_ticks = lease_ticks
        #: lease clock (advanced by :meth:`tick`)
        self.now = 0
        self.expiries = 0
        # suspicion ledger (DESIGN.md §10): lease expiries that were only
        # ever suspicion, and how many of those healed when beats resumed
        self.suspicions = 0
        self.heals = 0
        # data-plane accounting for RELAY transport benchmarking
        self.relay_bytes = 0
        self.relay_msgs = 0

    # -- publish side ----------------------------------------------------------
    def register(self, topic: str, caps: Caps, endpoint: Any,
                 lease_ticks: Optional[int] = None, **specs) -> Registration:
        reg = Registration(
            topic=topic, caps=caps, endpoint=endpoint, specs=specs,
            reg_id=next(self._ids),
            lease_ticks=(lease_ticks if lease_ticks is not None
                         else self.default_lease_ticks),
            last_beat=self.now)
        self._regs[reg.reg_id] = reg
        self._notify("register", reg)
        return reg

    def unregister(self, reg: Registration):
        if reg.reg_id not in self._regs:
            return  # already gone — never double-deliver the event
        reg.alive = False
        self._regs.pop(reg.reg_id, None)
        self._notify("unregister", reg)

    def mark_down(self, reg: Registration, reason: str = "crash"):
        """Liveness loss without clean unregister (device crash / lease
        expiry).  Idempotent: a registration already down fires nothing.
        A crash is DECLARED death — it clears any standing suspicion (the
        device really is gone; there is nothing left to heal)."""
        if reason == "crash":
            reg.suspected = False
        if not reg.alive:
            return
        reg.alive = False
        reg.down_reason = reason
        self._notify("down", reg)

    # -- liveness: leases & heartbeats -----------------------------------------
    def heartbeat(self, reg: Registration) -> bool:
        """Refresh a live registration's lease.  A downed registration stays
        down (it must :meth:`revive` — the MQTT reconnect) — returns False."""
        if reg.reg_id not in self._regs or not reg.alive:
            return False
        reg.last_beat = self.now
        return True

    def revive(self, reg: Registration) -> Registration:
        """Re-register a previously downed registration under its original
        ``reg_id`` — the device came back and reclaims the rank it held
        before the outage.  Fires ``"register"``; idempotent on live regs."""
        self._regs.setdefault(reg.reg_id, reg)
        reg.suspected = False
        if reg.alive:
            return reg
        reg.alive = True
        reg.down_reason = None
        reg.last_beat = self.now
        self._notify("register", reg)
        return reg

    def heal(self, reg: Registration) -> bool:
        """Clear a FALSE suspicion: the device's heartbeats resumed, so the
        lease expiry was delay/partition, not death (DESIGN.md §10).  The
        win-back is the ordinary revive ``"register"`` event — in-flight
        work already re-dispatched to survivors is NOT recalled (it was
        at-least-once the moment it retransmitted; receiver dedup makes the
        double-serve harmless).  Returns False unless the registration is
        down under standing suspicion."""
        if reg.alive or not reg.suspected:
            return False
        self.heals += 1
        self.revive(reg)
        return True

    def tick(self, n: int = 1):
        """Advance the lease clock; expire registrations whose lease lapsed.
        Expiry is a ``mark_down`` (fires ``"down"``) — bindings fail over
        exactly as on a crash notice."""
        for _ in range(n):
            self.now += 1
            for reg in list(self._regs.values()):
                if reg.alive and reg.lease_ticks is not None and \
                        self.now - reg.last_beat > reg.lease_ticks:
                    self.expiries += 1
                    # silence is evidence, not proof: the expiry fails the
                    # registration over like a crash, but as SUSPICION —
                    # resumed beats can heal it (§10)
                    reg.suspected = True
                    self.suspicions += 1
                    self.mark_down(reg, reason="lease-expired")

    # -- discovery -------------------------------------------------------------
    def discover(self, topic_filter: str,
                 require: Optional[Dict[str, Any]] = None) -> List[Registration]:
        out = []
        for reg in self._regs.values():
            if not reg.alive:
                continue
            if not topic_matches(topic_filter, reg.topic):
                continue
            if require and any(reg.specs.get(k) != v for k, v in require.items()):
                continue
            out.append(reg)
        return sorted(out, key=lambda r: r.reg_id)

    def rank_key(self, reg: Registration,
                 prefer: Optional[Dict[str, Any]] = None) -> Tuple:
        """Sort key for capability-aware selection — LOWER ranks better.

        Order of importance: (1) pipeline-stage fit (an among-device chain
        coordinator asking for stage k ranks servers declaring a DIFFERENT
        ``stage`` behind those declaring k or nothing — a wildcard
        subscription over a chain's topics must never bind a hop to the
        wrong layer slice), (2) tenant affinity (a replica declaring
        ``tenants=(...)`` that lacks the client's tenant ranks behind one
        that pins it or declares nothing — soft isolation, DESIGN.md §9),
        (3) preferred-codec support (a server declaring ``codecs=(...)``
        that lacks the client's codec ranks behind one that has it — absent
        declaration means "anything goes"), (4) declared ``throughput``
        (higher better), (5) current ``load`` (lower better),
        (6) registration order — the deterministic tiebreak that preserves
        the pre-ranking first-match behavior when nobody declares anything.
        """
        prefer = prefer or {}
        stage = prefer.get("stage")
        declared_stage = reg.specs.get("stage")
        stage_miss = 1 if (stage is not None and declared_stage is not None
                           and int(_as_float(declared_stage, -1))
                           != int(stage)) else 0
        tenant = prefer.get("tenant")
        declared_tenants = reg.specs.get("tenants")
        tenant_miss = 1 if (tenant is not None
                            and declared_tenants is not None
                            and tenant not in declared_tenants) else 0
        codec = prefer.get("codec")
        declared = reg.specs.get("codecs")
        codec_miss = 1 if (codec not in (None, "none") and declared is not None
                           and codec not in declared) else 0
        return (stage_miss, tenant_miss, codec_miss,
                -_as_float(reg.specs.get("throughput")),
                _as_float(reg.load), reg.reg_id)

    def scaling_signal(self, topic_filter: str = "query/#"
                       ) -> Dict[str, Dict[str, float]]:
        """Per-topic capacity picture for elastic serving (DESIGN.md §9):
        live replica count plus summed / mean / max observed ``reg.load``
        (the runtime refreshes load every heartbeat from each endpoint's
        queue depth + admission backlog + active decode slots).  The
        autoscaler turns this into §6 add/remove reconfigurations — the
        broker only OBSERVES; it never owns replica lifecycle."""
        topics: Dict[str, Dict[str, float]] = {}
        for reg in self._regs.values():
            if not reg.alive or not topic_matches(topic_filter, reg.topic):
                continue
            t = topics.setdefault(reg.topic, {"replicas": 0, "load": 0.0,
                                              "max_load": 0.0})
            t["replicas"] += 1
            t["load"] += _as_float(reg.load)
            t["max_load"] = max(t["max_load"], _as_float(reg.load))
        for t in topics.values():
            t["mean_load"] = t["load"] / t["replicas"] if t["replicas"] \
                else 0.0
        return topics

    def subscribe(self, topic_filter: str,
                  prefer: Optional[Dict[str, Any]] = None,
                  **require) -> "Binding":
        return Binding(self, topic_filter, require or None, prefer=prefer)

    def _notify(self, event: str, reg: Registration):
        for w in list(self._watchers):
            w(event, reg)

    def watch(self, fn: Callable[[str, Registration], None]):
        self._watchers.append(fn)

    def unwatch(self, fn: Callable[[str, Registration], None]):
        try:
            self._watchers.remove(fn)
        except ValueError:
            pass

    # -- RELAY data plane -------------------------------------------------------
    def relay(self, payload_nbytes: int):
        """Account one broker-relayed frame (pure-MQTT data plane)."""
        self.relay_bytes += payload_nbytes
        self.relay_msgs += 1


class Binding:
    """A live subscription that resolves to the best-ranked registration and
    transparently fails over (R4).

    Candidates are ranked by :meth:`Broker.rank_key` (codec support,
    throughput, load, registration order) and filtered by data-plane
    liveness (an endpoint whose ``alive`` flag dropped is skipped even
    before the broker learns of the death — lease expiry lags a silent
    crash by up to ``lease_ticks``).  A registration that appears (or
    revives) and outranks the current one wins the binding back.
    """

    def __init__(self, broker: Broker, topic_filter: str,
                 require: Optional[Dict[str, Any]],
                 prefer: Optional[Dict[str, Any]] = None):
        self.broker = broker
        self.topic_filter = topic_filter
        self.require = require
        self.prefer = prefer
        self.current: Optional[Registration] = None
        self.failovers = 0
        self.closed = False
        broker.watch(self._on_event)
        self._rebind()

    def _candidates(self) -> List[Registration]:
        cands = [r for r in self.broker.discover(self.topic_filter, self.require)
                 if getattr(r.endpoint, "alive", True)]
        cands.sort(key=lambda r: self.broker.rank_key(r, self.prefer))
        return cands

    def _rebind(self) -> Optional[Registration]:
        cands = self._candidates()
        prev = self.current
        self.current = cands[0] if cands else None
        if prev is not None and self.current is not None and prev is not self.current:
            self.failovers += 1
        return self.current

    def _on_event(self, event: str, reg: Registration):
        if event in ("down", "unregister") and reg is self.current:
            self._rebind()
        elif event == "register" and \
                topic_matches(self.topic_filter, reg.topic):
            if self.current is None:
                self._rebind()
            elif reg is not self.current and \
                    self.broker.rank_key(reg, self.prefer) < \
                    self.broker.rank_key(self.current, self.prefer):
                # a better publisher appeared (or the preferred one came
                # back): win the binding over exactly once
                self._rebind()

    def close(self):
        """Stop receiving broker events (drop the watcher registration)."""
        if not self.closed:
            self.broker.unwatch(self._on_event)
            self.closed = True

    @property
    def endpoint(self):
        if self.current is None:
            raise BrokerError(
                f"no live publisher for {self.topic_filter!r}"
                + (f" with {self.require}" if self.require else ""))
        return self.current.endpoint
