"""Pipeline: a DAG of Elements compiled into one jittable step function,
plus ``parse_launch`` — a gst-launch-style textual pipeline description
parser so the paper's Listing 1/2 pipelines can be written as strings.

Grammar subset (sufficient for the paper's examples)::

    v4l2src ! videoconvert ! video/x-raw,width=300,height=300,format=RGB !
      tensor_converter ! tensor_filter model=ssd ! appsink name=out
    ts. queue leaky=2 ! videoconvert ! mix.sink_1
    compositor name=mix sink_0::zorder=2 sink_1::zorder=1 ! appsink

* ``!`` links elements left to right.
* ``name=x`` names an element; ``x.`` continues a chain from it (tee/demux
  request pads); ``x.sink_N`` / ``x.src_N`` addresses a specific pad.
* A token containing ``/`` is a caps filter.
* ``pad::prop=v`` sets a pad property (compositor zorder/xpos/ypos).
"""
from __future__ import annotations

import shlex
from collections import defaultdict, deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .buffers import StreamBuffer
from .element import Element, PipelineContext, element_factory
from .elements import AppSink, AppSrc, CapsFilter, Compositor, TestSrc
from .formats import Caps, CapsError, TensorFormat, TensorSpec

__all__ = ["Pipeline", "parse_launch", "parse_caps"]


# ---------------------------------------------------------------------------
# Caps string parsing
# ---------------------------------------------------------------------------

_VIDEO_CHANNELS = {"RGB": 3, "BGR": 3, "RGBA": 4, "BGRA": 4, "GRAY8": 1}


def _split_caps_fields(body: str) -> Dict[str, str]:
    """Split "k=v,k2=v2,cont,k3=v3" where a comma-segment without '=' continues
    the previous value (NNStreamer dimension lists)."""
    fields: Dict[str, str] = {}
    last_key = None
    for seg in body.split(","):
        seg = seg.strip().strip('"')
        if "=" in seg:
            k, v = seg.split("=", 1)
            fields[k.strip()] = v.strip().strip('"')
            last_key = k.strip()
        elif last_key is not None:
            fields[last_key] += "," + seg
    return fields


def _dims_to_shape(dims: str) -> Tuple[int, ...]:
    """NNStreamer dims are innermost-first ("4:20:1:1"); convert to row-major
    shape dropping leading 1s."""
    parts = [int(p) for p in dims.split(":")]
    shape = tuple(reversed(parts))
    while len(shape) > 1 and shape[0] == 1:
        shape = shape[1:]
    return shape


def parse_caps(token: str) -> Caps:
    media, _, body = token.partition(",")
    media = media.strip()
    fields = _split_caps_fields(body) if body else {}
    if media == "video/x-raw":
        h = int(fields.get("height", 0))
        w = int(fields.get("width", 0))
        c = _VIDEO_CHANNELS.get(fields.get("format", "RGB"), 3)
        tensors = (TensorSpec((h, w, c), "uint8"),) if h and w else ()
        return Caps(media=media, tensors=tensors)
    if media in ("other/tensor", "other/tensors"):
        fmt = TensorFormat(fields.get("format", "static"))
        if "dimensions" in fields:
            dims = fields["dimensions"].split(",")
            types = fields.get("types", "float32").split(",")
            if len(types) == 1:
                types = types * len(dims)
            tensors = tuple(TensorSpec(_dims_to_shape(d), t.strip(), fmt)
                            for d, t in zip(dims, types))
        else:
            tensors = ()
        return Caps(media="other/tensors", tensors=tensors)
    if media == "other/flexbuf":
        return Caps(media="other/flexbuf")
    return Caps(media=media)


# ---------------------------------------------------------------------------
# Pipeline graph
# ---------------------------------------------------------------------------

class Link:
    __slots__ = ("src", "src_pad", "dst", "dst_pad")

    def __init__(self, src, src_pad, dst, dst_pad):
        self.src, self.src_pad, self.dst, self.dst_pad = src, src_pad, dst, dst_pad

    def __repr__(self):
        return f"{self.src.name}.src_{self.src_pad}->{self.dst.name}.sink_{self.dst_pad}"


class Pipeline:
    def __init__(self, name: str = "pipeline"):
        self.name = name
        self.elements: Dict[str, Element] = {}
        self.links: List[Link] = []
        self.plan = None  # ExecutionPlan, built by realize()
        self._realized = False

    # -- construction ---------------------------------------------------------
    def add(self, elem: Element) -> Element:
        if elem.name in self.elements:
            raise ValueError(f"duplicate element name {elem.name!r}")
        self.elements[elem.name] = elem
        return elem

    def link(self, src: Element, dst: Element,
             src_pad: Optional[int] = None, dst_pad: Optional[int] = None):
        if src.name not in self.elements:
            self.add(src)
        if dst.name not in self.elements:
            self.add(dst)
        if src_pad is None:
            used = [l.src_pad for l in self.links if l.src is src]
            if src.n_src_pads is None:
                src_pad = (max(used) + 1) if used else 0  # request pad
            else:
                src_pad = 0
                if src.n_src_pads == 0:
                    raise CapsError(f"{src.name} has no src pads")
        if dst_pad is None:
            used = [l.dst_pad for l in self.links if l.dst is dst]
            if dst.n_sink_pads is None:
                dst_pad = (max(used) + 1) if used else 0
            else:
                taken = set(used)
                dst_pad = next(i for i in range(dst.n_sink_pads or 1) if i not in taken) \
                    if dst.n_sink_pads else 0
        self.links.append(Link(src, src_pad, dst, dst_pad))
        self._realized = False
        return dst

    # -- realization: topo sort + caps negotiation -----------------------------
    def _toposort(self) -> List[Element]:
        indeg = {n: 0 for n in self.elements}
        succ = defaultdict(list)
        for l in self.links:
            indeg[l.dst.name] += 1
            succ[l.src.name].append(l.dst.name)
        # deque keeps Kahn's algorithm O(V+E); popleft preserves the exact
        # FIFO visit order the seed's list.pop(0) produced (deterministic)
        order, stack = [], deque(sorted(n for n, d in indeg.items() if d == 0))
        while stack:
            n = stack.popleft()
            order.append(n)
            for m in succ[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    stack.append(m)
        if len(order) != len(self.elements):
            cyc = set(self.elements) - set(order)
            raise CapsError(f"pipeline has a cycle involving {sorted(cyc)}")
        return [self.elements[n] for n in order]

    def realize(self):
        """Negotiate caps along every link (GStreamer link-time checks)."""
        from .elements import VideoScale
        # videoscale takes its target from the *downstream* capsfilter (real
        # GStreamer negotiates bidirectionally; we fold the one pattern the
        # paper's pipelines use: `videoscale ! video/x-raw,width=..,height=..`)
        for l in self.links:
            if isinstance(l.src, VideoScale) and l.src.target is None \
                    and isinstance(l.dst, CapsFilter) and l.dst.filter_caps.tensors:
                h, w = l.dst.filter_caps.tensors[0].shape[:2]
                l.src.target = (h, w)
        order = self._toposort()
        in_links: Dict[str, List[Link]] = defaultdict(list)
        for l in self.links:
            in_links[l.dst.name].append(l)
        for elem in order:
            links = sorted(in_links[elem.name], key=lambda l: l.dst_pad)
            in_caps = []
            for l in links:
                up = l.src.out_caps[l.src_pad] if l.src.out_caps else Caps.ANY
                in_caps.append(elem.accept_caps(l.dst_pad, up))
            elem.in_caps = in_caps
            out = elem.negotiate(in_caps)
            # grow request src pads (tee): replicate caps across linked pads
            n_links_out = max([l.src_pad for l in self.links if l.src is elem],
                              default=-1) + 1
            if elem.n_src_pads is None and len(out) < n_links_out:
                out = out * n_links_out if len(out) == 1 else out
            elem.out_caps = out
        self._order = order
        self._in_links = in_links
        # compile the graph once: flatten topo order + wiring into a static
        # slot-indexed schedule (see core/plan.py) — stepping never re-sorts
        from .plan import ExecutionPlan
        self.plan = ExecutionPlan(self)
        self._realized = True
        return self

    # -- live reconfiguration ---------------------------------------------------
    def reconfig(self) -> "ReconfigPlan":
        """Start a topology edit script against this pipeline (DESIGN.md §6).
        The returned :class:`~repro.core.reconfig.ReconfigPlan` records
        swap/relink/add/remove edits; hand it to ``Runtime.reconfigure`` to
        prepare, warm and commit the edit while the stream runs."""
        from .reconfig import ReconfigPlan
        return ReconfigPlan(self)

    # -- params / state --------------------------------------------------------
    def init(self, rng) -> Dict[str, dict]:
        if not self._realized:
            self.realize()
        params = {}
        for elem in self._order:
            rng, sub = jax.random.split(rng)
            p = elem.init_params(sub)
            if p:
                params[elem.name] = p
        return params

    def init_state(self) -> Dict[str, dict]:
        if not self._realized:
            self.realize()
        state = {}
        for elem in self._order:
            s = elem.init_state()
            if s:
                state[elem.name] = s
        return state

    # -- execution --------------------------------------------------------------
    def sources(self) -> List[str]:
        return [e.name for e in self.elements.values()
                if isinstance(e, AppSrc)]

    def sinks(self) -> List[str]:
        return [e.name for e in self.elements.values() if isinstance(e, AppSink)]

    def step(self, params: dict, state: dict,
             inputs: Optional[Dict[str, StreamBuffer]] = None
             ) -> Tuple[Dict[str, StreamBuffer], dict]:
        """Run one frame through the precompiled plan schedule.  Pure — jit
        with ``jax.jit(pipe.step)`` or use :meth:`compiled_step` (cached,
        never retraces across structurally identical pipelines)."""
        if not self._realized:
            self.realize()
        return self.plan.run(params, state, inputs)

    def step_interpreted(self, params: dict, state: dict,
                         inputs: Optional[Dict[str, StreamBuffer]] = None
                         ) -> Tuple[Dict[str, StreamBuffer], dict]:
        """The seed per-frame interpreter (re-sorts links and rebuilds dicts
        every step).  Kept verbatim as the parity/benchmark baseline for the
        compiled plan; semantics must match :meth:`step` bitwise."""
        if not self._realized:
            self.realize()
        inputs = inputs or {}
        ctx = PipelineContext(state)
        produced: Dict[Tuple[str, int], StreamBuffer] = {}
        outputs: Dict[str, StreamBuffer] = {}
        for elem in self._order:
            links = sorted(self._in_links[elem.name], key=lambda l: l.dst_pad)
            ins = [produced[(l.src.name, l.src_pad)] for l in links]
            if isinstance(elem, AppSrc) and elem.name in inputs:
                ins = [inputs[elem.name]]
            outs = elem.apply(params.get(elem.name, {}), ins, ctx)
            for i, o in enumerate(outs):
                produced[(elem.name, i)] = o
            if isinstance(elem, AppSink) and outs:
                outputs[elem.name] = outs[0]
        return outputs, ctx.next_state

    def step_n(self, params: dict, state: dict,
               inputs: Optional[Dict[str, StreamBuffer]] = None,
               n: Optional[int] = None, hoist_queries: bool = False
               ) -> Tuple[Dict[str, StreamBuffer], dict]:
        """N-frame burst: one ``lax.scan`` dispatch through the whole DAG.
        ``inputs`` holds *stacked* per-source frames (leading axis N) or pass
        ``n`` for self-driven pipelines.  Frame ``i`` of the stacked outputs
        is bitwise what the ``i``-th sequential :meth:`step` would return."""
        if not self._realized:
            self.realize()
        return self.plan.step_n(params, state, inputs, n=n,
                                hoist_queries=hoist_queries)

    def compiled_step(self, donate: Optional[bool] = None):
        """Cached jitted step, shared process-wide across pipelines with the
        same topology fingerprint (failover reconnects never retrace)."""
        if not self._realized:
            self.realize()
        return self.plan.compiled_step(donate=donate)

    def compiled_step_n(self, hoist_io: bool = False,
                        hoist_queries: bool = False,
                        donate: Optional[bool] = None, mesh=None):
        """Cached jitted burst step (see :meth:`step_n`); ``mesh`` lays
        shardable hoisted bursts out along the mesh's data axes."""
        if not self._realized:
            self.realize()
        return self.plan.compiled_step_n(hoist_io=hoist_io,
                                         hoist_queries=hoist_queries,
                                         donate=donate, mesh=mesh)

    def describe(self) -> str:
        if not self._realized:
            self.realize()
        lines = [f"pipeline {self.name}:"]
        for l in self.links:
            caps = l.src.out_caps[l.src_pad].describe() if l.src.out_caps else "ANY"
            lines.append(f"  {l} [{caps}]")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# parse_launch
# ---------------------------------------------------------------------------

def _is_caps_token(tok: str) -> bool:
    head = tok.split(",")[0]
    return "/" in head and "=" not in head


def parse_launch(description: str, pipeline: Optional[Pipeline] = None) -> Pipeline:
    pipe = pipeline or Pipeline()
    # normalize: treat newlines as chain separators unless the line continues
    # with '!' — gst-launch is whitespace-insensitive, we keep that.
    toks: List[str] = []
    for line in description.strip().splitlines():
        line = line.strip()
        # '#' only comments whole lines: inline '#' is the MQTT wildcard
        # ("mqttsrc sub-topic=objdetect/#")
        if not line or line.startswith("#"):
            continue
        toks.extend(shlex.split(line, comments=False))
    # merge standalone '!' handling: tokens may contain '!' glued — split them
    tokens: List[str] = []
    for t in toks:
        while t.endswith("!") and t != "!":
            t = t[:-1]
            if t:
                tokens.append(t)
            tokens.append("!")
            t = ""
        if t:
            tokens.append(t)

    cur: Optional[Element] = None          # chain tail element
    cur_src_pad: Optional[int] = None      # explicit src pad for next link
    pending_link = False                   # saw '!' awaiting next element
    deferred: List[tuple] = []             # forward refs: (src, src_pad, name, pad)

    def attach(elem: Element, dst_pad: Optional[int] = None):
        nonlocal cur, cur_src_pad, pending_link
        if elem.name not in pipe.elements and elem not in pipe.elements.values():
            pipe.add(elem)
        if pending_link and cur is not None:
            pipe.link(cur, elem, src_pad=cur_src_pad, dst_pad=dst_pad)
        cur, cur_src_pad, pending_link = elem, None, False

    i = 0
    while i < len(tokens):
        tok = tokens[i]
        i += 1
        if tok == "!":
            pending_link = True
            continue
        # pad / element reference:  name.  |  name.sink_0  |  name.src_2
        if "." in tok and not _is_caps_token(tok) and "=" not in tok:
            ref, _, pad = tok.partition(".")
            if ref not in pipe.elements and pad.startswith("sink_") \
                    and pending_link and cur is not None:
                # forward reference (gst-launch resolves these at the end)
                deferred.append((cur, cur_src_pad, ref, int(pad[5:])))
                cur, cur_src_pad, pending_link = None, None, False
                continue
            if ref in pipe.elements:
                elem = pipe.elements[ref]
                if pad.startswith("sink_"):
                    attach(elem, dst_pad=int(pad[5:]))
                elif pad.startswith("src_"):
                    # starts a new chain from a specific src pad; the next
                    # element links implicitly (gst-launch `dmux.src_0 !` or
                    # bare `ts. queue` both work)
                    cur, cur_src_pad, pending_link = elem, int(pad[4:]), True
                else:
                    cur, cur_src_pad, pending_link = elem, None, True
                continue
        if _is_caps_token(tok):
            attach(CapsFilter(caps=parse_caps(tok)))
            continue
        if "=" in tok and cur is not None and "::" in tok:
            padspec, _, val = tok.partition("=")
            pad, _, prop = padspec.partition("::")
            if isinstance(cur, Compositor):
                cur.set_pad_prop(int(pad.split("_")[-1]), prop, val)
            continue
        if "=" in tok and not _is_caps_token(tok):
            # property of current element — must re-create with prop (elements
            # take props in __init__), so collect props *before* instantiation:
            # handled below by look-ahead at element creation.  If we reach
            # here the element already exists: name= is the only mutable prop.
            key, _, val = tok.partition("=")
            if key == "name" and cur is not None:
                pipe.elements.pop(cur.name, None)
                cur.name = val
                pipe.elements[val] = cur
            else:
                cur.props[key] = val
                _late_prop(cur, key, val)
            continue
        # factory name: gather following k=v props via look-ahead
        props = {}
        j = i
        while j < len(tokens):
            t2 = tokens[j]
            if t2 == "!" or _is_caps_token(t2) or "=" not in t2 or "::" in t2:
                break
            k, _, v = t2.partition("=")
            props[k.replace("-", "_")] = v
            j += 1
        i = j
        name = props.pop("name", None)
        # v4l2src in descriptions maps to our deterministic testsrc
        factory = {"v4l2src": "testsrc", "ximagesink": "appsink",
                   "autovideosink": "appsink"}.get(tok, tok)
        elem = element_factory(factory, name=name, **props)
        attach(elem)
    for src, src_pad, ref, dst_pad in deferred:
        if ref not in pipe.elements:
            raise KeyError(f"dangling pad reference {ref}.sink_{dst_pad}")
        pipe.link(src, pipe.elements[ref], src_pad=src_pad, dst_pad=dst_pad)
    return pipe


def _late_prop(elem: Element, key: str, val: str):
    """Apply a property set after element construction (rare path)."""
    if key == "leaky" and hasattr(elem, "leaky"):
        elem.leaky = int(val)
