"""Adversarial network fabric: lossy-transport faults + delivery semantics.

Every among-device hop in this repo rides an in-process :class:`Channel`,
which never loses, duplicates, reorders, corrupts, or delays a frame — the
chaos harness (tests/chaoslib.py) kills *processes*, never *messages*.
Real consumer fleets (the among-device setting the paper targets) see all
of those as the norm, so this module supplies both halves of the story
(DESIGN.md §10):

* **Fault model** — a :class:`FaultPolicy` installed on any channel by a
  :class:`FaultFabric` wraps ``Channel.push`` and deterministically
  (seeded LCG, fault clock driven by scheduler ticks — no wall clock, no
  threads) injects drop, duplication, payload corruption (bit flips),
  reordering, delay (frames held N ticks), and scripted directional
  partition windows.  Every injected fault is counted on the link ledger.

* **Delivery protocol** — senders stamp each frame with a ``(sender_id,
  seq)`` delivery id (``meta["dseq"]``) and a CRC32 payload checksum
  (``meta["crc"]``); a receiver-side :class:`DeliveryGuard` rejects
  corrupt frames (counted, never silently consumed), dedups by delivery
  id through a bounded LRU window, and replays the cached answer for a
  retransmit whose original answer was lost.  Senders retransmit on
  timeout with exponential backoff (:class:`DeliveryPolicy`).  Retries
  are idempotent by dedup, so at-least-once + dedup = effectively-once:
  answers stay bitwise a fault-free twin's.

The message-layer conservation law, asserted per link::

    sent == accepted + dropped_by_fault + rejected_corrupt + deduped
            + in_flight + overflow_drops + purged

where ``sent`` counts sender pushes plus injected duplicates, receiver
verdicts (``accepted``/``rejected_corrupt``/``deduped``) are booked back
onto the link by :func:`note`, ``in_flight`` covers frames held by the
fabric or still queued in the channel, and ``purged`` counts frames an
endpoint teardown deliberately cleared (they land on the reconfig orphan
ledger — accounted, not lost).

Pure numpy + stdlib; deliberately importable everywhere (no jax).
"""
from __future__ import annotations

import zlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "DeliveryPolicy", "DeliveryGuard", "FaultPolicy", "FaultFabric",
    "checksum", "memoize_crc", "stamp", "link_for", "note", "note_purged",
    "lcg_stream",
]


def lcg_stream(seed: int = 0):
    """Deterministic uniform(0,1) stream (32-bit LCG) — same generator the
    chaos harness uses, duplicated here so core/ stays test-free."""
    state = (int(seed) & 0xFFFFFFFF) or 1
    while True:
        state = (1664525 * state + 1013904223) & 0xFFFFFFFF
        yield state / 2.0 ** 32


# -- integrity ----------------------------------------------------------------

def checksum(buf) -> int:
    """CRC32 over the payload's HOST-RESIDENT bytes: every numpy tensor's
    dtype/shape framing and raw bytes, plus the presentation timestamp when
    it is a host scalar.

    Integrity attaches to serialized bytes.  Tensors still device-resident
    (jax arrays queued behind async dispatch) have no wire bytes to cover —
    they ride the in-process reference fabric, which cannot flip a bit, and
    forcing a device sync per frame to hash them would serialize the very
    pipeline the delivery layer must not slow (the fault-free overhead
    gate).  The moment a payload materializes to host bytes — an edge wire
    frame, a numpy payload, or the fault model's bit-flip copy (``_flip``
    always produces numpy) — it is covered in full.  Both ends apply the
    same rule to the same objects, so stamp and verify stay symmetric, and
    injected corruption can never hide behind the device-resident
    exemption: the flip itself materializes the tensor it damages, which
    pulls it into the verifier's CRC domain.

    The value is memoized on the buffer object (``_crc_memo``): buffers are
    immutable by repo convention and every mutation path — ``with_``, codec
    encode, ``_flip`` — constructs a FRESH object that does not carry the
    memo, so a suspect frame is always recomputed in full.  The memo only
    short-circuits re-verifying the exact object the sender stamped."""
    c = getattr(buf, "_crc_memo", None)
    if c is not None:
        return c
    pts = getattr(buf, "pts", None)
    c = zlib.crc32(b"%d" % pts) if isinstance(pts, (int, np.integer)) \
        else zlib.crc32(b"-")
    for t in buf.tensors:
        if isinstance(t, np.ndarray):
            c = zlib.crc32(t.dtype.str.encode(), c)
            c = zlib.crc32(repr(t.shape).encode(), c)
            c = zlib.crc32(t.tobytes(), c)
    c &= 0xFFFFFFFF
    memoize_crc(buf, c)
    return c


def memoize_crc(buf, c: int) -> None:
    """Attach a computed payload checksum to ``buf``.  Callers that copy a
    just-checksummed buffer (stamp, the send paths) re-attach the memo to
    the copy — the payload is identical, ``meta`` is not part of the CRC
    domain.  Never attach a value the payload was not computed from."""
    try:
        buf._crc_memo = c
    except Exception:
        pass


def stamp(buf, dseq: Tuple[int, int]):
    """Return ``buf`` with delivery id + checksum in its routing meta."""
    c = checksum(buf)
    out = buf.with_(meta={**buf.meta, "dseq": dseq, "crc": c})
    memoize_crc(out, c)
    return out


# -- delivery protocol --------------------------------------------------------

@dataclass(frozen=True)
class DeliveryPolicy:
    """Knobs for the at-least-once + dedup delivery layer.

    ``timeout_ticks`` is the wait before the FIRST retransmit; each further
    retransmit waits ``backoff``x longer, capped at ``max_backoff_ticks``.
    ``window`` bounds the receiver's dedup LRU and answer replay cache —
    size it above the worst-case in-flight population or an evicted id can
    be re-served.  ``hop_retries`` bounds the synchronous §8 stage-hop
    retransmit loop (hops can't wait a tick: the chain holds the slot)."""
    timeout_ticks: int = 2
    backoff: float = 2.0
    max_backoff_ticks: int = 16
    window: int = 1024
    hop_retries: int = 4

    def __post_init__(self):
        # the schedule reaches its fixed point (the cap) within a few
        # retries; precompute that prefix so the per-dispatch lookup is a
        # tuple index, not a float pow (frozen dataclass: set via object)
        object.__setattr__(self, "_retry_table", tuple(
            self._retry_at(k) for k in range(16)))

    def _retry_at(self, retries: int) -> int:
        t = self.timeout_ticks * (self.backoff ** int(retries))
        return max(1, min(int(t), self.max_backoff_ticks))

    def retry_in(self, retries: int) -> int:
        """Ticks to wait after the ``retries``-th send (0 = the original)."""
        if 0 <= retries < 16:
            return self._retry_table[retries]
        return self._retry_at(retries)


class DeliveryGuard:
    """Receiver-side delivery guard: CRC verification, bounded-LRU dedup by
    delivery id, and a bounded replay cache of committed answers.

    ``check(raw, channel)`` returns one of ``"ok"`` / ``"dup"`` /
    ``"corrupt"`` and books the verdict on the channel's fault link (if
    any) via :func:`note` so the per-link conservation law stays exact.
    Frames without a ``dseq`` (pre-delivery senders, edge clients) pass
    through as ``"ok"`` — the guard never breaks old traffic."""

    def __init__(self, policy: Optional[DeliveryPolicy] = None):
        self.policy = policy or DeliveryPolicy()
        self._seen: "OrderedDict[Tuple[int, int], bool]" = OrderedDict()
        self._answers: "OrderedDict[Tuple[int, int], object]" = OrderedDict()
        self.accepted = 0
        self.deduped = 0
        self.rejected_corrupt = 0
        self.replayed = 0

    def check(self, raw, channel=None) -> str:
        meta = raw.meta or {}
        crc = meta.get("crc")
        if crc is not None and checksum(raw) != int(crc):
            self.rejected_corrupt += 1
            note(channel, "rejected_corrupt")
            return "corrupt"
        dseq = meta.get("dseq")
        if dseq is not None and dseq in self._seen:
            self._seen.move_to_end(dseq)
            self.deduped += 1
            note(channel, "deduped")
            return "dup"
        if dseq is not None:
            self._seen[dseq] = True
            while len(self._seen) > self.policy.window:
                self._seen.popitem(last=False)
        self.accepted += 1
        note(channel, "accepted")
        return "ok"

    def seen(self, dseq) -> bool:
        return dseq in self._seen

    def forget(self, dseq) -> None:
        """Evict a delivery id whose request was shed UNSERVED (endpoint
        death mid-queue): the failover re-dispatch reuses the id, and a
        window that still held it would dedup the retry into a void."""
        if dseq is None:
            return
        self._seen.pop(dseq, None)
        self._answers.pop(dseq, None)

    # -- answer replay cache --------------------------------------------------
    def record_answer(self, dseq, replay_fn) -> None:
        """Remember how to re-send the committed answer for ``dseq``: the
        closure re-pushes the exact payload object already shipped, so a
        replay is bitwise the original by construction."""
        if dseq is None:
            return
        self._answers[dseq] = replay_fn
        while len(self._answers) > self.policy.window:
            self._answers.popitem(last=False)

    def replay_answer(self, dseq) -> bool:
        fn = self._answers.get(dseq)
        if fn is None:
            return False
        fn()
        self.replayed += 1
        return True

    def stats(self) -> Dict[str, int]:
        return {"accepted": self.accepted, "deduped": self.deduped,
                "rejected_corrupt": self.rejected_corrupt,
                "replayed": self.replayed}


# -- fault model --------------------------------------------------------------

@dataclass(frozen=True)
class FaultPolicy:
    """Per-link fault rates + scripted partition windows.  Rates are carved
    out of ONE uniform draw per frame (disjoint bands), so e.g. enabling
    duplication does not perturb which frames drop — schedules stay
    comparable across policies sharing a seed.  ``partitions`` is a tuple
    of ``(t0, t1)`` fault-clock windows during which the link silently
    eats every frame (directional: a link wraps ONE channel)."""
    seed: int = 0
    drop: float = 0.0
    dup: float = 0.0
    corrupt: float = 0.0
    reorder: float = 0.0
    delay: float = 0.0
    delay_ticks: Tuple[int, int] = (1, 3)
    partitions: Tuple[Tuple[int, int], ...] = ()


class FaultLink:
    """One faulty unidirectional link: wraps a channel's ``push``."""

    def __init__(self, channel, policy: FaultPolicy, fabric: "FaultFabric",
                 name: str):
        self.channel = channel
        self.policy = policy
        self.fabric = fabric
        self.name = name
        self._rng = lcg_stream(policy.seed)
        self._orig_push = channel.push
        self._held: List[Tuple[int, object, Optional[int]]] = []
        self._swap: Optional[Tuple[object, Optional[int]]] = None
        # sender side
        self.sent = 0
        self.injected_dups = 0
        self.dropped_fault = 0
        self.corrupted = 0
        self.delayed = 0
        self.reordered = 0
        self.delivered = 0
        self.overflow_drops = 0
        # receiver side, booked back by note()
        self.accepted = 0
        self.deduped = 0
        self.rejected_corrupt = 0
        self.purged = 0
        channel.push = self.push

    # -- the faulty push ------------------------------------------------------
    def partitioned(self, t: int) -> bool:
        return any(t0 <= t < t1 for t0, t1 in self.policy.partitions)

    def push(self, buf, nbytes=None) -> bool:
        p = self.policy
        self.sent += 1
        if self.partitioned(self.fabric.now):
            self.dropped_fault += 1
            return True     # the network ate it; the sender can't know
        r = next(self._rng)
        edge = p.drop
        if r < edge:
            self.dropped_fault += 1
            return True
        edge += p.dup
        if r < edge:
            self.sent += 1  # the injected copy counts as a send
            self.injected_dups += 1
            ok = self._deliver(buf, nbytes)
            self._deliver(buf, nbytes)
            return ok
        edge += p.corrupt
        if r < edge:
            self.corrupted += 1
            return self._deliver(self._flip(buf), nbytes)
        edge += p.delay
        if r < edge:
            lo, hi = p.delay_ticks
            hold = int(lo) + int(next(self._rng) * (int(hi) - int(lo) + 1))
            self.delayed += 1
            self._held.append((self.fabric.now + max(1, hold), buf, nbytes))
            return True
        edge += p.reorder
        if r < edge:
            if self._swap is None:
                self._swap = (buf, nbytes)
                self.reordered += 1
                return True
            held, self._swap = self._swap, None
            ok = self._deliver(buf, nbytes)
            self._deliver(*held)
            return ok
        return self._deliver(buf, nbytes)

    def _deliver(self, buf, nbytes) -> bool:
        ok = self._orig_push(buf, nbytes)
        self.delivered += 1
        if not ok:
            self.overflow_drops += 1
        return ok

    def _flip(self, buf):
        """Flip one payload bit (rng-chosen tensor/offset).  Structure —
        dtype, shape, meta — survives, so only the checksum can tell."""
        tensors = [np.asarray(t).copy() for t in buf.tensors]
        flippable = [i for i, t in enumerate(tensors) if t.nbytes > 0]
        if not flippable:
            # nothing to flip in the payload: corrupt the checksum itself
            meta = dict(buf.meta or {})
            if "crc" in meta:
                meta["crc"] = int(meta["crc"]) ^ 1
                return buf.with_(meta=meta)
            return buf
        i = flippable[int(next(self._rng) * len(flippable)) % len(flippable)]
        flat = tensors[i].reshape(-1).view(np.uint8)
        pos = int(next(self._rng) * flat.size) % flat.size
        flat[pos] ^= 1 << (int(next(self._rng) * 8) % 8)
        return buf.with_(tensors=tuple(tensors))

    # -- fault clock ----------------------------------------------------------
    def step(self, now: int) -> None:
        """Release due delayed frames (and any straggling reorder stash) —
        called once per scheduler tick by the owning fabric."""
        if self._swap is not None:
            held, self._swap = self._swap, None
            self._deliver(*held)
        if not self._held:
            return
        due = [h for h in self._held if h[0] <= now]
        if not due:
            return
        self._held = [h for h in self._held if h[0] > now]
        for _, buf, nbytes in due:
            self._deliver(buf, nbytes)

    def uninstall(self) -> None:
        if self.channel.push == self.push:
            self.channel.push = self._orig_push
        _REGISTRY.pop(id(self.channel), None)

    # -- ledger ---------------------------------------------------------------
    def queued(self) -> int:
        ch = self.channel
        return len(ch.q) + sum(len(rx.q) for rx in ch.consumers)

    def in_flight(self) -> int:
        return len(self._held) + (1 if self._swap is not None else 0) \
            + self.queued()

    def conservation(self) -> Tuple[int, Dict[str, int]]:
        terms = {"accepted": self.accepted,
                 "dropped_by_fault": self.dropped_fault,
                 "rejected_corrupt": self.rejected_corrupt,
                 "deduped": self.deduped,
                 "in_flight": self.in_flight(),
                 "overflow_drops": self.overflow_drops,
                 "purged": self.purged}
        return self.sent, terms

    def stats(self) -> Dict[str, int]:
        sent, terms = self.conservation()
        return {"sent": sent, "delivered": self.delivered,
                "injected_dups": self.injected_dups,
                "corrupted": self.corrupted, "delayed": self.delayed,
                "reordered": self.reordered, **terms}


class FaultFabric:
    """The set of faulty links in one scenario + the shared fault clock.

    Drive the clock from the scheduler: set ``rt.fabric = fabric`` and the
    runtime steps it at the top of every tick (releasing delayed frames
    before that tick's dispatch), or call ``step()`` by hand in
    tick-for-tick harnesses.  Deterministic end to end: link seeds fix the
    fault schedule, the tick clock fixes *when*."""

    def __init__(self):
        self.links: Dict[int, FaultLink] = {}
        self.now = 0

    def install(self, channel, policy: FaultPolicy, name: Optional[str] = None
                ) -> FaultLink:
        link = FaultLink(channel, policy, self,
                         name or f"link{len(self.links)}")
        self.links[id(channel)] = link
        _REGISTRY[id(channel)] = link
        return link

    def uninstall(self, channel) -> None:
        link = self.links.pop(id(channel), None)
        if link is not None:
            link.uninstall()

    def step(self, now: Optional[int] = None) -> None:
        self.now = self.now + 1 if now is None else int(now)
        for link in list(self.links.values()):
            link.step(self.now)

    def stats(self) -> Dict[str, Dict[str, int]]:
        return {link.name: link.stats() for link in self.links.values()}

    def assert_conservation(self) -> None:
        """The message-layer conservation law, per link: every frame ever
        pushed is accounted for — delivered-and-accepted, eaten by a
        scripted fault, rejected as corrupt, deduped, still in flight,
        overflowed, or purged by an endpoint teardown.  Zero silent loss."""
        for link in self.links.values():
            sent, terms = link.conservation()
            total = sum(terms.values())
            assert sent == total, (
                f"message conservation violated on {link.name}: "
                f"sent={sent} != {total} = sum({terms})")


# -- link registry ------------------------------------------------------------
# Receiver-side verdicts happen far from the FaultLink that carried the
# frame (a guard pops from a channel it never installed anything on), so
# the registry maps channel identity -> link and note() books the verdict
# back.  A no-op for channels with no link: delivery-guarded traffic over
# clean channels costs nothing extra.

_REGISTRY: Dict[int, FaultLink] = {}


def link_for(channel) -> Optional[FaultLink]:
    return _REGISTRY.get(id(channel)) if channel is not None else None


def note(channel, field: str, n: int = 1) -> None:
    if not _REGISTRY:        # no chaos scenario installed: stay off the path
        return
    link = _REGISTRY.get(id(channel)) if channel is not None else None
    if link is not None:
        setattr(link, field, getattr(link, field) + n)


def note_purged(channel, n: int) -> None:
    """An endpoint teardown cleared ``n`` queued frames (they move to the
    reconfig orphan ledger) — keep the message ledger exact."""
    if n:
        note(channel, "purged", n)
