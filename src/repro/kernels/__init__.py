# Pallas TPU kernels for the stream-codec hot spots the paper optimizes on
# the transmission path: block-COO sparse encode/decode (tensor_sparse_enc/
# dec) and per-tile int8 quantization (gst-gz analogue).  Validated against
# ref.py oracles in interpret mode on CPU; compiled BlockSpec tiling on TPU.
from . import ops, ref
