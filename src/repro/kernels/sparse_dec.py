"""Pallas TPU kernel: block-COO sparse decode (``tensor_sparse_dec``).

Inverse of sparse_enc: each grid step reconstructs one B=512 dense block from
its KB coordinate slots.  GPU would scatter with atomics; TPU has no scatter
in VMEM, so we again use a one-hot MXU matmul:

    local   = indices - block_base                 # [KB]
    onehot  = (local[:,None] == arange(B)[None,:]) # [KB, B]
    dense   = values @ onehot                      # MXU   [B]

Empty slots carry (value=0, index=block_base): their one-hot row is real but
the zero value contributes nothing — the "no-op scatter" trick that keeps
the framing fixed-capacity and the kernel branch-free.

Off-TPU the interpreter's per-block one-hot emulation is ~10× slower than
XLA's native scatter-add, which IS the decode contract (real indices are
unique within a block; empty slots add 0):  :func:`sparse_dec_xla` is the
bitwise-identical fast path ``ops.sparse_dec`` dispatches to on non-TPU
backends (pinned by tests/test_wire_path.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import SPARSE_B


def _dec_kernel(vals_ref, idx_ref, out_ref):
    kb = vals_ref.shape[1]
    b = out_ref.shape[1]
    v = vals_ref[0, :].astype(jnp.float32)                    # [KB]
    local = idx_ref[0, :] - pl.program_id(0) * b              # [KB]
    cols = jax.lax.broadcasted_iota(jnp.int32, (kb, b), 1)
    onehot = (jnp.broadcast_to(local[:, None], (kb, b)) == cols).astype(jnp.float32)
    out_ref[0, :] = (v @ onehot).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_dec_pallas(v2: jnp.ndarray, i2: jnp.ndarray, *, interpret: bool = True):
    """v2/i2: [nb, kb] block-COO -> dense [nb*B] (block b owns indices
    [b*B, (b+1)*B))."""
    nb, kb = v2.shape
    out = pl.pallas_call(
        _dec_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, kb), lambda i: (i, 0)),
            pl.BlockSpec((1, kb), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((1, SPARSE_B), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, SPARSE_B), v2.dtype)],
        interpret=interpret,
    )(v2, i2)[0]
    return out.reshape(-1)


@jax.jit
def sparse_dec_xla(v2: jnp.ndarray, i2: jnp.ndarray):
    """Scatter-add statement of the block decode: same signature and
    bitwise-same output as :func:`sparse_dec_pallas` (each dense position
    receives exactly one real value or only zero-valued empty slots)."""
    nb, _ = v2.shape
    dense = jnp.zeros((nb * SPARSE_B,), v2.dtype)
    return dense.at[i2.reshape(-1)].add(v2.reshape(-1))
