"""Pure-jnp oracles for the Pallas stream-codec and attention kernels.

Semantics (shared contract between ref and kernels):

* quantize8: per (BM, BN) tile symmetric int8 quantization.  scale =
  absmax/127 (1.0 for all-zero tiles); q = round(x/scale).
* sparse_enc ("block-COO"): the flat input is split into blocks of B
  elements; each block keeps its first KB nonzeros (|x| > threshold) in
  position order — value and *global* flat index; empty slots hold
  (value=0, index=block_base), which decode treats as a no-op because the
  contribution is zero.  Capacity overflow inside a block drops the tail
  (bounded-capacity framing, like any fixed-size wire format).
* sparse_dec: scatter-add values at indices into a zeroed dense vector.
* attn_ref / attn_decode_ref: FULL-softmax f32 attention matching the
  flash kernels' signatures — the serve-path trust anchor (the flash
  online-softmax results must land within fp32 tolerance of these before
  the kernel sits under model-serving traffic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

QUANT_BM, QUANT_BN = 32, 128
SPARSE_B = 512  # elements per sparse block


def _pad2d(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def quantize8_ref(x: jnp.ndarray):
    """x: [M, N] float -> (q int8 [Mp, Np], scales f32 [Mp/BM, Np/BN])."""
    xp = _pad2d(x.astype(jnp.float32), QUANT_BM, QUANT_BN)
    mp, np_ = xp.shape
    gm, gn = mp // QUANT_BM, np_ // QUANT_BN
    tiles = xp.reshape(gm, QUANT_BM, gn, QUANT_BN).transpose(0, 2, 1, 3)
    amax = jnp.max(jnp.abs(tiles), axis=(2, 3))
    scales = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.round(tiles / scales[:, :, None, None]).astype(jnp.int8)
    q = q.transpose(0, 2, 1, 3).reshape(mp, np_)
    return q, scales


def dequantize8_ref(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    mp, np_ = q.shape
    gm, gn = scales.shape
    tiles = q.reshape(gm, QUANT_BM, gn, QUANT_BN).transpose(0, 2, 1, 3)
    x = tiles.astype(jnp.float32) * scales[:, :, None, None]
    return x.transpose(0, 2, 1, 3).reshape(mp, np_)


def _sparse_dims(n: int, cap: int):
    nb = max(1, -(-n // SPARSE_B))
    kb = max(1, cap // nb)
    # sublane-align (8) the per-block capacity: the MXU one-hot matmul pads
    # lanes to 128 internally (VMEM cost only) but the wire format carries
    # the logical kb, so compression ratio follows the requested capacity
    kb = min(SPARSE_B, -(-kb // 8) * 8)
    return nb, kb


def sparse_enc_ref(flat: jnp.ndarray, cap: int, threshold: float = 0.0):
    """flat: [N] -> (values [nb*kb], indices int32 [nb*kb], nnz int32)."""
    n = flat.shape[0]
    nb, kb = _sparse_dims(n, cap)
    xp = jnp.pad(flat, (0, nb * SPARSE_B - n)).reshape(nb, SPARSE_B)
    mask = jnp.abs(xp) > threshold
    rank = jnp.cumsum(mask, axis=1) - 1                       # [nb, B]
    keep = mask & (rank < kb)
    base = (jnp.arange(nb, dtype=jnp.int32) * SPARSE_B)[:, None]
    gidx = base + jnp.arange(SPARSE_B, dtype=jnp.int32)[None, :]
    slot = jnp.where(keep, rank, kb)                          # dropped -> scratch slot
    vals = jnp.zeros((nb, kb + 1), xp.dtype)
    idxs = jnp.zeros((nb, kb + 1), jnp.int32) + base          # empty slot -> base
    row = jnp.arange(nb)[:, None]
    vals = vals.at[row, slot].set(jnp.where(keep, xp, 0.0))
    idxs = idxs.at[row, slot].set(jnp.where(keep, gidx, base))
    nnz = jnp.sum(jnp.minimum(jnp.sum(mask, axis=1), kb)).astype(jnp.int32)
    return vals[:, :kb].reshape(-1), idxs[:, :kb].reshape(-1), nnz


def sparse_dec_ref(values: jnp.ndarray, indices: jnp.ndarray,
                   nnz: jnp.ndarray, n: int) -> jnp.ndarray:
    del nnz  # zero-valued empty slots make the scatter-add a no-op
    total = int(np.prod(values.shape))
    dense = jnp.zeros((max(n, int(indices.max(initial=0)) + 1),), values.dtype) \
        if False else jnp.zeros((n + SPARSE_B,), values.dtype)
    dense = dense.at[indices.reshape(-1)].add(values.reshape(-1))
    return dense[:n]


NEG_INF = -1e30


def attn_ref(q, k, v, *, causal: bool = True, kv_groups: int = 1):
    """Full-softmax reference for ``flash_attention``: q [BH, Sq, dk],
    k/v [BH//kv_groups, Sk, d*] -> [BH, Sq, dv].  Materializes the whole
    [BH, Sq, Sk] score tensor (the thing flash exists to avoid) in f32."""
    bh, sq, dk = q.shape
    if kv_groups > 1:
        k = jnp.repeat(k, kv_groups, axis=0)
        v = jnp.repeat(v, kv_groups, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dk ** -0.5)
    if causal:
        sk = k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def attn_decode_ref(q, k, v, pos, *, kv_groups: int = 1):
    """Full-softmax reference for ``flash_decode_step``: q [BH, dk] (one
    query position), cached k/v [BKV, Sk, d*], ``pos`` the last valid cache
    index -> [BH, dv]."""
    dk = q.shape[-1]
    if kv_groups > 1:
        k = jnp.repeat(k, kv_groups, axis=0)
        v = jnp.repeat(v, kv_groups, axis=0)
    s = jnp.einsum("hd,hkd->hk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dk ** -0.5)
    sk = k.shape[1]
    s = jnp.where((jnp.arange(sk) <= pos)[None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hk,hkd->hd", p, v.astype(jnp.float32)).astype(q.dtype)
