"""Pallas TPU kernel: per-tile symmetric int8 quantization (stream codec).

The among-device transport compresses activation streams by narrowing
bf16/f32 frames to int8 + per-tile scales (the TPU-native analogue of the
paper's gst-gz/JPEG frame codecs — on TPU, bandwidth is saved by dtype
narrowing, not byte-level entropy coding).

Tiling: (32, 128) blocks — int8 native tile on TPU (sublane 32 × lane 128);
one f32 scale per tile.  Grid = (M/32, N/128); each program reads one VMEM
tile, computes absmax, writes the quantized tile + its scale.

:func:`quantize8_xla`/:func:`dequantize8_xla` are the bitwise-identical
vectorized XLA statements of the same per-tile contract — the fast path
``ops`` dispatches to off-TPU, where the Pallas interpreter pays a Python
grid loop per (32, 128) tile (pinned by tests/test_wire_path.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import QUANT_BM, QUANT_BN


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q_ref[...] = jnp.round(x / scale).astype(jnp.int8)
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize8_pallas(x: jnp.ndarray, *, interpret: bool = True):
    """x: [M, N] (M % 32 == 0, N % 128 == 0) -> (q int8 [M,N], scales [M/32, N/128])."""
    m, n = x.shape
    gm, gn = m // QUANT_BM, n // QUANT_BN
    return pl.pallas_call(
        _quant_kernel,
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((QUANT_BM, QUANT_BN), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((QUANT_BM, QUANT_BN), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
        ],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize8_pallas(q: jnp.ndarray, scales: jnp.ndarray, *,
                       interpret: bool = True):
    m, n = q.shape
    gm, gn = scales.shape
    return pl.pallas_call(
        _dequant_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((QUANT_BM, QUANT_BN), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((QUANT_BM, QUANT_BN), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.float32)],
        interpret=interpret,
    )(q, scales)[0]


def _as_tiles(x: jnp.ndarray):
    """[M, N] -> [gm, gn, BM, BN] tile view (M % BM == 0, N % BN == 0)."""
    m, n = x.shape
    gm, gn = m // QUANT_BM, n // QUANT_BN
    return x.reshape(gm, QUANT_BM, gn, QUANT_BN).transpose(0, 2, 1, 3)


def _from_tiles(t: jnp.ndarray):
    gm, gn, bm, bn = t.shape
    return t.transpose(0, 2, 1, 3).reshape(gm * bm, gn * bn)


@jax.jit
def quantize8_xla(x: jnp.ndarray):
    """Same contract and bitwise-same outputs as :func:`quantize8_pallas`."""
    tiles = _as_tiles(x.astype(jnp.float32))
    amax = jnp.max(jnp.abs(tiles), axis=(2, 3))
    scales = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.round(tiles / scales[:, :, None, None]).astype(jnp.int8)
    return _from_tiles(q), scales


@jax.jit
def dequantize8_xla(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    tiles = _as_tiles(q).astype(jnp.float32) * scales[:, :, None, None]
    return _from_tiles(tiles)
