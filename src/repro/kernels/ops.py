"""jit'd public wrappers over the Pallas stream-codec kernels.

Handles shape canonicalization (padding to tile multiples), the
interpret-mode switch (Pallas executes the kernel body in Python on CPU;
compiled on TPU), and the block-COO capacity bookkeeping.  ``ref.py`` holds
the pure-jnp oracles the kernels are tested against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .quant8 import dequantize8_pallas, quantize8_pallas
from .ref import QUANT_BM, QUANT_BN, SPARSE_B, _sparse_dims
from .sparse_dec import sparse_dec_pallas
from .sparse_enc import sparse_enc_pallas

__all__ = ["quantize8", "dequantize8", "sparse_enc", "sparse_dec", "use_interpret"]


def use_interpret() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def _as2d(x: jnp.ndarray):
    if x.ndim == 0:
        x = x.reshape(1, 1)
    elif x.ndim == 1:
        x = x.reshape(1, -1)
    elif x.ndim > 2:
        x = x.reshape(-1, x.shape[-1])
    return x


def quantize8(x: jnp.ndarray):
    """Any-shape float array -> (q int8 [Mp,Np], scales f32 [Mp/BM, Np/BN]).

    The original shape is the caller's to remember (compression.py keeps it
    in the codec header, like any wire format)."""
    x2 = _as2d(x.astype(jnp.float32))
    m, n = x2.shape
    pm, pn = (-m) % QUANT_BM, (-n) % QUANT_BN
    if pm or pn:
        x2 = jnp.pad(x2, ((0, pm), (0, pn)))
    return quantize8_pallas(x2, interpret=use_interpret())


def dequantize8(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    return dequantize8_pallas(q, scales, interpret=use_interpret())


def sparse_enc(flat: jnp.ndarray, cap: int, threshold: float = 0.0):
    """flat [N] -> (values [nb*kb], indices [nb*kb], nnz scalar int32).

    Block-COO semantics of ref.sparse_enc_ref; kb is lane-aligned from cap."""
    n = int(flat.shape[0])
    nb, kb = _sparse_dims(n, cap)
    pad = nb * SPARSE_B - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    vals, idxs, cnts = sparse_enc_pallas(
        flat, kb=kb, threshold=float(threshold), interpret=use_interpret())
    return vals, idxs, jnp.sum(cnts).astype(jnp.int32)


def sparse_dec(values: jnp.ndarray, indices: jnp.ndarray, nnz, n: int) -> jnp.ndarray:
    """Block-COO -> dense flat [n]."""
    del nnz
    total = int(values.shape[0])
    nb = -(-n // SPARSE_B)
    kb = total // nb
    dense = sparse_dec_pallas(values.reshape(nb, kb), indices.reshape(nb, kb),
                              interpret=use_interpret())
    return dense[:n]
