"""jit'd public wrappers over the Pallas stream-codec kernels.

Handles shape canonicalization (padding to tile multiples), backend
dispatch, and the block-COO capacity bookkeeping.  ``ref.py`` holds the
pure-jnp oracles the kernels are tested against.

Backend dispatch (``impl``): on TPU silicon the Pallas kernels run
compiled; everywhere else Pallas only *interprets* — a Python loop per
grid step — which made the codec layer the slowest thing on the wire path
(~100 ms per sparse encode of one LM-activation frame).  Each kernel
module therefore carries a vectorized XLA statement of the identical
contract (``*_xla``), bitwise-equal to the kernel and ~10-40× faster under
jit on CPU; ``impl=None`` picks per backend, tests force either.

Stacked entry points (``*_stacked``): the codecs' tile/block framing is
*local* — quant8 scales live per (32, 128) tile and sparse COO slots per
512-element block — so a whole batch of same-shape tensors encodes in ONE
kernel dispatch by merging the batch axis into the tile/block axis (frame
boundaries land on tile/block boundaries by construction).  The merged
call is bitwise what per-frame calls produce, which is what lets a
QueryBatcher flush encode/decode ``batch × tensors`` payloads in one
dispatch — or inside one jit — without touching numerics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .quant8 import (dequantize8_pallas, dequantize8_xla, quantize8_pallas,
                     quantize8_xla)
from .ref import QUANT_BM, QUANT_BN, SPARSE_B, _sparse_dims
from .sparse_dec import sparse_dec_pallas, sparse_dec_xla
from .sparse_enc import sparse_enc_pallas, sparse_enc_xla

__all__ = ["quantize8", "dequantize8", "sparse_enc", "sparse_dec",
           "quantize8_stacked", "dequantize8_stacked", "sparse_enc_stacked",
           "sparse_dec_stacked", "use_interpret"]


def use_interpret() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def _impl(impl) -> str:
    if impl is None:
        return "xla" if use_interpret() else "pallas"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"impl {impl!r} not in ('pallas', 'xla')")
    return impl


def _as2d(x: jnp.ndarray):
    if x.ndim == 0:
        x = x.reshape(1, 1)
    elif x.ndim == 1:
        x = x.reshape(1, -1)
    elif x.ndim > 2:
        x = x.reshape(-1, x.shape[-1])
    return x


def _pad_tiles(x2: jnp.ndarray):
    m, n = x2.shape[-2:]
    pm, pn = (-m) % QUANT_BM, (-n) % QUANT_BN
    if pm or pn:
        pad = [(0, 0)] * (x2.ndim - 2) + [(0, pm), (0, pn)]
        x2 = jnp.pad(x2, pad)
    return x2


def quantize8(x: jnp.ndarray, impl=None):
    """Any-shape float array -> (q int8 [Mp,Np], scales f32 [Mp/BM, Np/BN]).

    The original shape is the caller's to remember (compression.py keeps it
    in the codec header, like any wire format)."""
    x2 = _pad_tiles(_as2d(x.astype(jnp.float32)))
    if _impl(impl) == "xla":
        return quantize8_xla(x2)
    return quantize8_pallas(x2, interpret=use_interpret())


def dequantize8(q: jnp.ndarray, scales: jnp.ndarray, impl=None) -> jnp.ndarray:
    if _impl(impl) == "xla":
        return dequantize8_xla(q, scales)
    return dequantize8_pallas(q, scales, interpret=use_interpret())


def quantize8_stacked(x: jnp.ndarray, impl=None):
    """Stacked frames [B, *shape] -> (q int8 [B, Mp, Np], scales
    [B, Mp/BM, Np/BN]) in ONE kernel dispatch.

    Frame i's slice is bitwise ``quantize8(x[i])``: frames are merged along
    the tile-row axis after padding, so every (32, 128) tile — and with it
    every absmax scale — stays wholly inside its frame."""
    b = x.shape[0]
    # per-frame 2d view (same rules as _as2d on one frame)
    fshape = x.shape[1:]
    if len(fshape) == 0:
        x3 = x.reshape(b, 1, 1)
    elif len(fshape) == 1:
        x3 = x.reshape(b, 1, fshape[0])
    else:
        x3 = x.reshape(b, -1, fshape[-1])
    x3 = _pad_tiles(x3.astype(jnp.float32))
    _, mp, np_ = x3.shape
    q, s = (quantize8_xla(x3.reshape(b * mp, np_)) if _impl(impl) == "xla"
            else quantize8_pallas(x3.reshape(b * mp, np_),
                                  interpret=use_interpret()))
    return (q.reshape(b, mp, np_),
            s.reshape(b, mp // QUANT_BM, np_ // QUANT_BN))


def dequantize8_stacked(q: jnp.ndarray, scales: jnp.ndarray,
                        impl=None) -> jnp.ndarray:
    """Inverse of :func:`quantize8_stacked`: [B, Mp, Np] int8 + [B, gm, gn]
    scales -> [B, Mp, Np] f32, one dispatch, bitwise per-frame."""
    b, mp, np_ = q.shape
    _, gm, gn = scales.shape
    x = dequantize8(q.reshape(b * mp, np_), scales.reshape(b * gm, gn),
                    impl=impl)
    return x.reshape(b, mp, np_)


def _sparse_enc_blocks(flat: jnp.ndarray, kb: int, threshold: float, impl):
    """Shared core: padded flat [nb*B] -> (vals, idxs, per-block counts)."""
    if _impl(impl) == "xla":
        return sparse_enc_xla(flat, kb=kb, threshold=float(threshold))
    return sparse_enc_pallas(flat, kb=kb, threshold=float(threshold),
                             interpret=use_interpret())


def sparse_enc(flat: jnp.ndarray, cap: int, threshold: float = 0.0,
               impl=None):
    """flat [N] -> (values [nb*kb], indices [nb*kb], nnz scalar int32).

    Block-COO semantics of ref.sparse_enc_ref; kb is lane-aligned from cap."""
    n = int(flat.shape[0])
    nb, kb = _sparse_dims(n, cap)
    pad = nb * SPARSE_B - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    vals, idxs, cnts = _sparse_enc_blocks(flat, kb, threshold, impl)
    return vals, idxs, jnp.sum(cnts).astype(jnp.int32)


def sparse_enc_stacked(x: jnp.ndarray, cap: int, threshold: float = 0.0,
                       impl=None):
    """Stacked flat frames [B, N] -> (values [B, nb*kb], indices
    [B, nb*kb], nnz int32 [B]) in ONE dispatch.

    The block-COO framing is per-512-block, so the batch axis merges into
    the block axis: frame i's slice is bitwise ``sparse_enc(x[i], cap)``
    (indices are rebased to each frame's own flat coordinates)."""
    b, n = x.shape
    nb, kb = _sparse_dims(n, cap)
    pad = nb * SPARSE_B - n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    vals, idxs, cnts = _sparse_enc_blocks(x.reshape(-1), kb, threshold, impl)
    off = (jnp.arange(b, dtype=jnp.int32) * (nb * SPARSE_B))[:, None]
    return (vals.reshape(b, nb * kb),
            idxs.reshape(b, nb * kb) - off,
            jnp.sum(cnts.reshape(b, nb), axis=1).astype(jnp.int32))


def sparse_dec(values: jnp.ndarray, indices: jnp.ndarray, nnz, n: int,
               impl=None) -> jnp.ndarray:
    """Block-COO -> dense flat [n]."""
    del nnz
    total = int(values.shape[0])
    nb = -(-n // SPARSE_B)
    kb = total // nb
    v2, i2 = values.reshape(nb, kb), indices.reshape(nb, kb)
    dense = (sparse_dec_xla(v2, i2) if _impl(impl) == "xla"
             else sparse_dec_pallas(v2, i2, interpret=use_interpret()))
    return dense[:n]


def sparse_dec_stacked(values: jnp.ndarray, indices: jnp.ndarray, nnz,
                       n: int, impl=None) -> jnp.ndarray:
    """Stacked block-COO [B, nb*kb] -> dense [B, n], one dispatch, bitwise
    per-frame (inverse of :func:`sparse_enc_stacked`)."""
    del nnz
    b, total = values.shape
    nb = -(-n // SPARSE_B)
    kb = total // nb
    off = (jnp.arange(b, dtype=jnp.int32) * (nb * SPARSE_B))[:, None]
    v2 = values.reshape(b * nb, kb)
    i2 = (indices + off).reshape(b * nb, kb)
    dense = (sparse_dec_xla(v2, i2) if _impl(impl) == "xla"
             else sparse_dec_pallas(v2, i2, interpret=use_interpret()))
    return dense.reshape(b, nb * SPARSE_B)[:, :n]
