"""Pallas TPU kernel: causal flash attention (online softmax).

The §Roofline analysis shows unfused attention's S×S score tensor is touched
~10× per layer in HLO (dot, mask, sub, exp, div, ...): at 32k context it is
the dominant memory-roofline term for every full-attention arch (deepseek
prefill: 56s of the 56–71s memory term).  Flash attention keeps each
(bq × bk) score block in VMEM and never materializes S×S in HBM:

  grid (batch·heads, q_blocks, kv_blocks)  — kv innermost, sequential;
  scratch (m, l, acc) persists across the kv sweep (online softmax);
  causal masking skips whole blocks above the diagonal.

HBM traffic per layer drops to Q+K+V+O (+negligible scratch), i.e. the
attention term leaves the memory roofline entirely on TPU.  Validated in
interpret mode against the pure-jnp oracle (models.layers._sdpa semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, causal: bool, scale: float):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (j * bk <= i * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, dk]
        k = k_ref[0].astype(jnp.float32)                  # [bk, dk]
        v = v_ref[0].astype(jnp.float32)                  # [bk, dv]
        s = q @ k.T                                       # [bq, bk]
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]                               # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)                            # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)                   # [bq, 1]
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret",
                                    "kv_groups"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, interpret: bool = True,
                    kv_groups: int = 1):
    """q: [BH, Sq, dk]; k: [BH//kv_groups, Sk, dk]; v: likewise [.., dv]
    -> [BH, Sq, dv].

    GQA: ``kv_groups`` q-heads share one kv head — handled in the BlockSpec
    index map (no broadcast materialization).  Sq/Sk must be multiples of
    bq/bk (pad upstream)."""
    bh, sq, dk = q.shape
    _, sk, dv = v.shape
    bq = min(bq, sq)
    bk = min(bk, sk)
    scale = dk ** -0.5
    grid = (bh, sq // bq, sk // bk)
    g = kv_groups
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dk), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dk), lambda b, i, j: (b // g, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max m
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom l
            pltpu.VMEM((bq, dv), jnp.float32),    # output accumulator
        ],
        compiler_params=getattr(pltpu, "CompilerParams",
                                getattr(pltpu, "TPUCompilerParams", None))(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.jit, static_argnames=("bk", "kv_groups"))
def flash_decode_step(q, k, v, pos, *, bk: int = 128, kv_groups: int = 1):
    """One cached-KV decode step with online softmax: q is a SINGLE query
    position attending to ``k[:, :pos+1]`` of a ring/linear KV cache.

    q: [BH, dk]; k: [BKV, Sk, dk]; v: [BKV, Sk, dv]; pos: int32 scalar
    (last valid cache index) -> [BH, dv].

    Decode attention is memory-roofline-bound on the KV stream (one query
    row cannot feed the MXU) — the win is never materializing the [BH, Sk]
    score row in one piece at long context.  ``lax.scan`` over KV blocks
    carries the flash (m, l, acc) triple, so per-block peak memory is
    [BH, bk] regardless of Sk; masking ``idx > pos`` inside each block
    makes the result exact for any fill level.  GQA repeats kv heads into
    the q-head axis (a [BKV → BH] broadcast of the small cache slice, not
    an S×S tensor).  f32 accumulation throughout, cast back to q.dtype —
    bitwise the serve-path reference (kernels/ref.py attn_decode_ref)."""
    bh, dk = q.shape
    bkv, sk, dv = v.shape
    g = kv_groups
    if g > 1:
        k = jnp.repeat(k, g, axis=0)
        v = jnp.repeat(v, g, axis=0)
    bk = min(bk, sk)
    nb = -(-sk // bk)
    pad = nb * bk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    qf = q.astype(jnp.float32) * (dk ** -0.5)             # [BH, dk]
    kb = k.astype(jnp.float32).reshape(bh, nb, bk, dk).transpose(1, 0, 2, 3)
    vb = v.astype(jnp.float32).reshape(bh, nb, bk, dv).transpose(1, 0, 2, 3)
    base = jnp.arange(nb, dtype=jnp.int32) * bk

    def block(carry, xs):
        m, l, acc = carry
        kj, vj, b0 = xs
        s = jnp.einsum("hd,hkd->hk", qf, kj)              # [BH, bk]
        idx = b0 + jnp.arange(bk, dtype=jnp.int32)
        s = jnp.where((idx <= pos)[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, -1, keepdims=True)
        acc = acc * alpha + jnp.einsum("hk,hkd->hd", p, vj)
        return (m_new, l, acc), None

    init = (jnp.full((bh, 1), NEG_INF, jnp.float32),
            jnp.zeros((bh, 1), jnp.float32),
            jnp.zeros((bh, dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(block, init, (kb, vb, base))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
