"""Pallas TPU kernel: block-COO sparse encoding (``tensor_sparse_enc``).

GPU stream-compaction uses warp ballots + shared-memory prefix sums — none of
which exist on TPU.  The TPU-native adaptation reformulates compaction as a
**one-hot matmul on the MXU**:

    mask    = |x| > threshold                      # [B]   VPU compare
    rank    = cumsum(mask) - 1                     # [B]   VPU scan
    onehot  = (rank[None,:] == slots[:,None]) & mask   # [KB, B]
    values  = onehot @ x                           # MXU   [KB]
    indices = onehot @ arange(B) + block_base      # MXU   [KB]

Each grid step compacts one B=512-element block into its KB capacity slots;
empty slots produce (0, block_base) which decode treats as a no-op.  All
operands are VMEM-resident (B*KB one-hot = 512×512 f32 = 1 MiB worst case,
well under the ~16 MiB VMEM budget), and both matmul dims are 128-multiples.

Off-TPU the Pallas interpreter executes the grid loop step by step, and the
one-hot's O(nb·kb·B) materialization makes the *emulation* the slowest thing
on the wire path (~100 ms for one LM-activation frame).  The XLA fast path
(:func:`sparse_enc_xla`) states the identical block-COO contract as a rank
search instead: the k-th kept slot of a block is the position of the k-th
nonzero, i.e. ``searchsorted(cumsum(mask), k+1)`` — O(nb·kb·log B) gathers,
~36× faster under jit on CPU, and **bitwise identical** to the kernel
(pinned by tests/test_wire_path.py).  ``ops.sparse_enc`` dispatches: Pallas
on TPU silicon, XLA everywhere else.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import SPARSE_B


def _enc_kernel(x_ref, vals_ref, idx_ref, cnt_ref, *, kb: int, threshold: float):
    b = x_ref.shape[1]
    x = x_ref[0, :].astype(jnp.float32)
    mask = jnp.abs(x) > threshold
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1            # [B]
    slots = jax.lax.broadcasted_iota(jnp.int32, (kb, b), 0)
    ranks = jnp.broadcast_to(rank[None, :], (kb, b))
    onehot = ((ranks == slots) & mask[None, :]).astype(jnp.float32)  # [KB, B]
    vals_ref[0, :] = (onehot @ x).astype(vals_ref.dtype)
    base = pl.program_id(0) * b
    local = jax.lax.broadcasted_iota(jnp.float32, (b, 1), 0)  # exact ints < 2^24
    idx_ref[0, :] = (onehot @ local)[:, 0].astype(jnp.int32) + base
    cnt_ref[0, 0] = jnp.minimum(jnp.sum(mask.astype(jnp.int32)), kb)


@functools.partial(jax.jit, static_argnames=("kb", "threshold", "interpret"))
def sparse_enc_pallas(flat: jnp.ndarray, *, kb: int, threshold: float = 0.0,
                      interpret: bool = True):
    """flat: [nb*B] -> (values [nb*kb], indices int32 [nb*kb], counts int32 [nb])."""
    n = flat.shape[0]
    nb = n // SPARSE_B
    x2 = flat.reshape(nb, SPARSE_B)
    vals, idxs, cnts = pl.pallas_call(
        functools.partial(_enc_kernel, kb=kb, threshold=threshold),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, SPARSE_B), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, kb), lambda i: (i, 0)),
            pl.BlockSpec((1, kb), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, kb), flat.dtype),
            jax.ShapeDtypeStruct((nb, kb), jnp.int32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x2)
    return vals.reshape(-1), idxs.reshape(-1), cnts.reshape(-1)


@functools.partial(jax.jit, static_argnames=("kb", "threshold"))
def sparse_enc_xla(flat: jnp.ndarray, *, kb: int, threshold: float = 0.0):
    """Vectorized XLA statement of the block-COO encode (module docstring):
    same signature and bitwise-same outputs as :func:`sparse_enc_pallas`.

    ``pos[r, k] = searchsorted(cumsum(mask[r]), k+1)`` is the position of
    the (k+1)-th nonzero of block ``r`` (B for an exhausted block — masked
    to the (0, block_base) empty-slot framing the kernel emits)."""
    n = flat.shape[0]
    nb = n // SPARSE_B
    x2 = flat.reshape(nb, SPARSE_B)
    mask = jnp.abs(x2.astype(jnp.float32)) > threshold
    csum = jnp.cumsum(mask.astype(jnp.int32), axis=1)          # [nb, B]
    ks = jnp.arange(1, kb + 1, dtype=jnp.int32)                # [kb]
    pos = jax.vmap(lambda c: jnp.searchsorted(c, ks, side="left"))(csum)
    valid = pos < SPARSE_B                                     # k < block nnz
    posc = jnp.minimum(pos, SPARSE_B - 1).astype(jnp.int32)
    base = (jnp.arange(nb, dtype=jnp.int32) * SPARSE_B)[:, None]
    vals = jnp.where(valid, jnp.take_along_axis(x2, posc, axis=1), 0)
    idxs = jnp.where(valid, base + posc, base).astype(jnp.int32)
    cnts = jnp.minimum(csum[:, -1], kb).astype(jnp.int32)
    return (vals.astype(flat.dtype).reshape(-1), idxs.reshape(-1), cnts)
