"""mamba2-130m [ssm] — SSD (state-space duality), attention-free,
state=128. [arXiv:2405.21060]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", arch_type="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280,
        norm="rmsnorm", layer_pattern="S",
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        # production default: sequence-parallel SSD (§Perf H3 — 10.7× on the
        # dominant roofline term vs channel-sharded GSPMD); params replicate,
        # the sequence shards over `model`
        ssm_seq_parallel=True,
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )
