"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed
experts top-6, first layer dense. [arXiv:2405.04434]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", arch_type="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=12288, vocab=102400,
        norm="rmsnorm", act="silu", mlp_glu=True, rope_theta=10_000.0,
        mla=True, kv_lora_rank=512, q_lora_rank=1536,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=160, top_k=6, n_shared_experts=2, d_ff_expert=1536,
        first_dense=1,
        source="arXiv:2405.04434",
    )
