"""Assigned-architecture registry: --arch <id> resolves here.

Each module carries the exact published spec (cited in its docstring) and a
reduced smoke() variant for CPU tests.
"""
from __future__ import annotations

from typing import Dict

from ..models.config import ModelConfig

from . import (deepseek_v2_236b, gemma3_4b, granite_20b, internvl2_76b,
               mamba2_130m, mixtral_8x22b, qwen1_5_110b, recurrentgemma_9b,
               stablelm_1_6b, whisper_large_v3)

_MODULES = {
    "qwen1.5-110b": qwen1_5_110b,
    "internvl2-76b": internvl2_76b,
    "granite-20b": granite_20b,
    "gemma3-4b": gemma3_4b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "stablelm-1.6b": stablelm_1_6b,
    "whisper-large-v3": whisper_large_v3,
    "mixtral-8x22b": mixtral_8x22b,
    "mamba2-130m": mamba2_130m,
    "recurrentgemma-9b": recurrentgemma_9b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    try:
        return _MODULES[arch].config()
    except KeyError as e:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}") from e


def all_configs() -> Dict[str, ModelConfig]:
    return {k: m.config() for k, m in _MODULES.items()}
