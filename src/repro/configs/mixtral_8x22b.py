"""mixtral-8x22b [moe] — 8 experts top-2, GQA kv=8, sliding-window
attention. [arXiv:2401.04088]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", arch_type="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab=32768,
        norm="rmsnorm", act="silu", mlp_glu=True, rope_theta=1_000_000.0,
        layer_pattern="L", window=4096,
        n_experts=8, top_k=2, d_ff_expert=16384,
        source="arXiv:2401.04088",
    )
