"""internvl2-76b [vlm] — InternViT (stub) + Llama3-70B-style LM, GQA kv=8.
[arXiv:2404.16821]  The vision tower is the allowed stub: input_specs
supplies projected patch embeddings [B, 256, d_model]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", arch_type="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=28672, vocab=128256,
        norm="rmsnorm", act="silu", mlp_glu=True, rope_theta=500_000.0,
        frontend="vision", n_patches=256,
        source="arXiv:2404.16821",
    )
