"""whisper-large-v3 [audio] — encoder-decoder; mel+conv frontend is the
allowed stub (input_specs supplies 1500 frame embeddings); decoder context
is architecturally capped at 448 tokens. [arXiv:2212.04356]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", arch_type="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
        d_ff=5120, vocab=51866,
        norm="layernorm", act="gelu", mlp_glu=False,
        enc_dec=True, n_enc_layers=32, enc_seq=1500, max_seq=448,
        frontend="audio", tie_embeddings=True,
        source="arXiv:2212.04356",
    )
