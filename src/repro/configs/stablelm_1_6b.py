"""stablelm-1.6b [dense] — MHA, partial rotary (25%), LayerNorm.
[hf:stabilityai/stablelm-2-1_6b]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", arch_type="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=5632, vocab=100352,
        norm="layernorm", act="silu", mlp_glu=True,
        rope_theta=10_000.0, rope_frac=0.25,
        source="hf:stabilityai/stablelm-2-1_6b",
    )
