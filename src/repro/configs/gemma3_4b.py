"""gemma3-4b [dense] — 5:1 local:global attention, sliding window 1024,
128k context, 262k vocab. [hf:google/gemma-3-1b-pt family]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", arch_type="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=10240, vocab=262144,
        norm="rmsnorm", act="gelu", mlp_glu=True,
        layer_pattern="LLLLLG", window=1024,
        rope_theta=1_000_000.0, tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt (4b spec)",
    )
