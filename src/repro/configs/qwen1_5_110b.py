"""qwen1.5-110b [dense] — QKV bias, GQA kv=8. [hf:Qwen/Qwen1.5-0.5B family]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", arch_type="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=49152, vocab=152064,
        qkv_bias=True, norm="rmsnorm", act="silu", mlp_glu=True,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen1.5-0.5B (scaled family spec)",
    )
