"""recurrentgemma-9b [hybrid] — RG-LRU recurrent blocks + local attention,
2:1 pattern (R,R,L), MQA kv=1, window 2048. [arXiv:2402.19427]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", arch_type="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
        d_ff=12288, vocab=256000,
        norm="rmsnorm", act="gelu", mlp_glu=True,
        layer_pattern="RRL", window=2048, lru_width=4096,
        tie_embeddings=True,
        source="arXiv:2402.19427",
    )
