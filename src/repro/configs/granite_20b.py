"""granite-20b [dense] — code model, llama arch, MQA (kv=1). [arXiv:2405.04324]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", arch_type="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
        d_ff=24576, vocab=49152,
        norm="rmsnorm", act="gelu", mlp_glu=False, rope_theta=10_000.0,
        source="arXiv:2405.04324",
    )
