"""Version-compat shims for jax APIs that moved between 0.4.x and 0.5+.

The codebase targets current jax naming (``jax.shard_map``,
``jax.set_mesh``); this module maps those onto the experimental homes they
had in 0.4.x so the same source runs on both.  Keep every shim tiny and
delete it when the minimum supported jax passes the new API.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "pvary"]


def pvary(x, axis_name):
    """``jax.lax.pvary`` fallback: 0.4.x shard_map has no varying-axis
    bookkeeping (its ``check_rep`` analysis predates VMA types), so marking
    a value as varying is simply the identity there."""
    fn = getattr(jax.lax, "pvary", None)
    if fn is not None:
        return fn(x, axis_name)
    return x


def shard_map(f, mesh=None, in_specs=None, out_specs=None,
              axis_names=None, check_vma=None):
    """``jax.shard_map`` with graceful fallback to
    ``jax.experimental.shard_map.shard_map`` (jax 0.4.x).

    Newer-API spellings are translated for the old entry point:
    ``check_vma`` -> ``check_rep`` and ``axis_names={...}`` (manual axes)
    -> ``auto=`` (every other mesh axis).
    """
    new = getattr(jax, "shard_map", None)
    if new is not None:
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    # 0.4.x's check_rep analysis predates VMA types and miscounts scan
    # carries (jax recommends check_rep=False as the workaround), so rep
    # checking is off unless the caller asked for it explicitly
    kwargs = {"check_rep": bool(check_vma)}
    if axis_names is not None:
        kwargs["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
