"""LR schedules as jnp-safe callables (traced step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def lr(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / total_steps
        return base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         min_frac: float = 0.05):
    cos = cosine_schedule(base_lr, max(1, total_steps - warmup), min_frac)

    def lr(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(1, warmup)
        return jnp.where(s < warmup, warm, cos(jnp.maximum(s - warmup, 0)))
    return lr
