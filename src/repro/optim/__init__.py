from .adamw import OptState, adamw_init, adamw_update, clip_by_global_norm
from .schedule import cosine_schedule, linear_warmup_cosine
