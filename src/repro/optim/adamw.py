"""AdamW + global-norm clipping, pure JAX (no optax on this box).

State is a pytree mirroring params (m, v in f32 regardless of param dtype —
the standard mixed-precision arrangement).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.int32(0), m=zeros,
                    v=jax.tree_util.tree_map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def adamw_update(params, grads, state: OptState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 max_grad_norm: float = 1.0) -> Tuple[Any, OptState, Dict]:
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v), {"grad_norm": gnorm}
