"""Data pipeline: deterministic synthetic LM corpus + shard-aware batcher.

The corpus is a Zipf-ish Markov stream (so the loss actually goes down when
training — unlike uniform noise, bigram structure is learnable by a tiny
model in a few hundred steps, which the e2e example exploits).  Generation
is pure numpy, seeded, and shard-aware: worker ``(i, n)`` produces the i-th
of n disjoint slices of the same logical stream, so the global batch is
identical regardless of topology (the standard deterministic-input
requirement for multi-pod training).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class SyntheticLM:
    """Markov-chain corpus with Zipf marginals and local structure."""

    vocab: int
    seed: int = 0
    branching: int = 8         # out-degree per state: smaller = more learnable

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab
        self.successors = rng.integers(0, v, size=(v, self.branching))
        zipf = 1.0 / np.arange(1, self.branching + 1)
        self.probs = zipf / zipf.sum()

    def stream(self, seed: int) -> Iterator[int]:
        rng = np.random.default_rng((self.seed << 20) ^ seed)
        tok = int(rng.integers(0, self.vocab))
        while True:
            yield tok
            tok = int(self.successors[tok, rng.choice(self.branching, p=self.probs)])

    def sample_tokens(self, n: int, seed: int) -> np.ndarray:
        it = self.stream(seed)
        return np.fromiter((next(it) for _ in range(n)), np.int32, count=n)


class TokenBatcher:
    """Yields {tokens, labels} batches of [local_batch, seq+?]. Labels are the
    next-token shift (the model shifts internally; labels kept for parity
    with real loaders)."""

    def __init__(self, corpus: SyntheticLM, global_batch: int, seq: int,
                 shard_index: int = 0, num_shards: int = 1):
        if global_batch % num_shards:
            raise ValueError(f"global_batch {global_batch} % shards {num_shards} != 0")
        self.corpus = corpus
        self.global_batch = global_batch
        self.local_batch = global_batch // num_shards
        self.seq = seq
        self.shard_index = shard_index
        self.num_shards = num_shards
        self._step = 0

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rows = []
        for b in range(self.local_batch):
            gslot = self.shard_index * self.local_batch + b
            # stream id mixes step & global slot -> disjoint, reproducible
            rows.append(self.corpus.sample_tokens(
                self.seq, seed=self._step * self.global_batch + gslot))
        self._step += 1
        toks = np.stack(rows)
        return {"tokens": toks, "labels": np.roll(toks, -1, axis=1)}


def make_train_iterator(vocab: int, global_batch: int, seq: int,
                        shard_index: int = 0, num_shards: int = 1,
                        seed: int = 0) -> TokenBatcher:
    return TokenBatcher(SyntheticLM(vocab=vocab, seed=seed),
                        global_batch, seq, shard_index, num_shards)
