from .pipeline import SyntheticLM, TokenBatcher, make_train_iterator
