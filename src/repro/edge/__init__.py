from .edge import EdgeSensor, EdgeOutput, EdgeQueryClient, pack_buffer, unpack_buffer
