"""NNStreamer-Edge analogue: a minimal, numpy-only client library.

The paper ships NNStreamer-Edge so devices that cannot afford GStreamer (or
any heavy runtime) still interoperate: RTOS sensors publish tensor streams,
third-party frameworks join the pipeline mesh.  Here the analogue is a
module that deliberately imports ONLY numpy + stdlib — no jax — and speaks
the same wire format (packed header + raw bytes) and broker protocol, so a
plain python process can act as a remote sensor ("edge_sensor"), a display
("edge_output"), or an offloading client ("edge_query_client").

Wire format (little-endian):
  magic 'NNSE' | version u16 | num_tensors u16 | pts i64
  per tensor: dtype_tag u16 | ndim u16 | dims u32[ndim] | nbytes u64 | raw
  v2 appends: crc32 u32 over every preceding byte

Version 2 adds the CRC32 trailer (the lossy-transport fault model,
DESIGN.md §10): structure checks catch protocol damage, the checksum
catches BIT damage — a flipped payload bit parses fine and silently
becomes a corrupt inference three devices later.  v1 frames (no trailer)
still parse, so pre-§10 senders interoperate.
"""
from __future__ import annotations

import struct
import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

_MAGIC = b"NNSE"
_VERSION = 2


class ChecksumError(ValueError):
    """The frame parsed structurally but failed its CRC32 trailer — bit
    corruption in transit, distinct from protocol damage (bad magic,
    truncation, unknown dtype): the sender spoke the format fine and a
    retransmit of the same frame may well succeed."""


_DTYPES = ("int8", "uint8", "int16", "uint16", "int32", "uint32",
           "int64", "uint64", "float16", "float32", "float64")


def pack_buffer(tensors: Sequence[np.ndarray], pts: int = 0) -> bytes:
    parts = [_MAGIC, struct.pack("<HHq", _VERSION, len(tensors), pts)]
    for t in tensors:
        # NOT ascontiguousarray: that promotes 0-dim scalars to shape (1,),
        # silently changing the tensor's rank on the wire
        t = np.asarray(t, order="C")
        tag = _DTYPES.index(t.dtype.name)
        parts.append(struct.pack("<HH", tag, t.ndim))
        parts.append(struct.pack(f"<{t.ndim}I", *t.shape) if t.ndim else b"")
        raw = t.tobytes()
        parts.append(struct.pack("<Q", len(raw)))
        parts.append(raw)
    body = b"".join(parts)
    return body + struct.pack("<I", zlib.crc32(body) & 0xFFFFFFFF)


def unpack_buffer(data: bytes) -> Tuple[List[np.ndarray], int]:
    """Strict inverse of :func:`pack_buffer`.

    A sensor on a flaky link can hand us anything: wrong protocol, a future
    wire version, a frame cut mid-payload, or a bit flipped in transit.
    Every such case raises ``ValueError`` — silently misparsing tensor
    bytes is how a corrupt frame becomes a corrupt *inference* three
    devices later.  Structural checks run FIRST and keep their specific
    errors; the checksum is verified LAST, so a frame that parses but
    fails its CRC raises the distinct :class:`ChecksumError`.
    """
    data = bytes(data)
    if len(data) < 16:
        raise ValueError(f"truncated header: {len(data)} bytes, need 16")
    if data[:4] != _MAGIC:
        raise ValueError("bad magic")
    ver, n, pts = struct.unpack_from("<HHq", data, 4)
    if ver == _VERSION:
        if len(data) < 20:
            raise ValueError(f"truncated checksum trailer: {len(data)} "
                             f"bytes, need 20")
        (crc,) = struct.unpack_from("<I", data, len(data) - 4)
        body = data[:-4]
    elif ver == 1:
        crc, body = None, data      # pre-§10 sender: no trailer
    else:
        raise ValueError(f"unsupported wire version {ver} (speaks {_VERSION})")
    off = 16
    tensors = []
    for i in range(n):
        if off + 4 > len(body):
            raise ValueError(f"tensor {i}: truncated tensor header")
        tag, ndim = struct.unpack_from("<HH", body, off)
        off += 4
        if tag >= len(_DTYPES):
            raise ValueError(f"tensor {i}: unknown dtype tag {tag}")
        if off + 4 * ndim + 8 > len(body):
            raise ValueError(f"tensor {i}: truncated dims/size fields")
        shape = struct.unpack_from(f"<{ndim}I", body, off) if ndim else ()
        off += 4 * ndim
        (nbytes,) = struct.unpack_from("<Q", body, off)
        off += 8
        dt = np.dtype(_DTYPES[tag])
        expected = int(np.prod(shape, dtype=np.uint64)) * dt.itemsize
        if nbytes != expected:
            raise ValueError(
                f"tensor {i}: payload size {nbytes} != shape {tuple(shape)} "
                f"x {dt.name} = {expected}")
        if off + nbytes > len(body):
            raise ValueError(f"tensor {i}: truncated payload "
                             f"({len(body) - off} of {nbytes} bytes)")
        arr = np.frombuffer(body, dtype=dt, count=nbytes // dt.itemsize,
                            offset=off).reshape(shape)
        tensors.append(arr.copy())
        off += nbytes
    if off != len(body):
        raise ValueError(f"{len(body) - off} trailing bytes after {n} tensors")
    if crc is not None and (zlib.crc32(body) & 0xFFFFFFFF) != crc:
        raise ChecksumError(
            f"checksum mismatch: trailer {crc:#010x} != computed "
            f"{zlib.crc32(body) & 0xFFFFFFFF:#010x}")
    return tensors, pts


class _BrokerPort:
    """Adapter hiding the in-process broker behind a socket-like API, so this
    module keeps zero jax/repro.core imports at module scope."""

    def __init__(self, broker):
        self.broker = broker


class EdgeSensor:
    """edge_sensor: publish tensor frames under a topic (mqttsink-compatible)."""

    def __init__(self, broker, topic: str):
        from ..core.formats import Caps
        from ..core.pubsub import Channel
        self.channel = Channel()
        self.registration = broker.register(topic, Caps(media="other/tensors"),
                                            self.channel, element="edge_sensor")

    def publish(self, tensors: Sequence[np.ndarray], pts: int = 0):
        from ..core.buffers import StreamBuffer
        wire = pack_buffer(tensors, pts)
        buf = StreamBuffer(tensors=tuple(np.asarray(t) for t in tensors),
                           pts=np.int64(pts), meta={"wire_nbytes": len(wire)})
        self.channel.push(buf, nbytes=len(wire))


class EdgeOutput:
    """edge_output: subscribe to a topic and hand frames to a callback."""

    def __init__(self, broker, topic_filter: str):
        self.binding = broker.subscribe(topic_filter)
        self._rx = self.binding.endpoint.attach_consumer()

    def poll(self) -> Optional[Tuple[List[np.ndarray], int]]:
        buf = self._rx.pop()
        if buf is None:
            return None
        return [np.asarray(t) for t in buf.tensors], int(buf.pts)


class EdgeQueryClient:
    """edge_query_client: offload inference without running a pipeline."""

    def __init__(self, broker, operation: str):
        self.binding = broker.subscribe(f"query/{operation}")
        self.client_id = 1 << 16  # edge namespace, avoids pipeline client ids

    def infer(self, tensors: Sequence[np.ndarray]) -> List[np.ndarray]:
        from ..core.buffers import StreamBuffer
        ep = self.binding.endpoint
        buf = StreamBuffer(tensors=tuple(np.asarray(t) for t in tensors),
                           pts=np.int64(0),
                           meta={"client_id": self.client_id, "codec": "none"})
        ep.requests.push(buf)
        runner = ep.spec.get("inline_runner")
        if runner is not None:
            runner()
        out = ep.client_channel(self.client_id).pop()
        if out is None:
            raise RuntimeError("no answer from query server")
        return [np.asarray(t) for t in out.tensors]
