"""Render results/dryrun.json into the EXPERIMENTS.md markdown tables.

    PYTHONPATH=src python -m benchmarks.report [--mesh single|multi]
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")

ARCH_ORDER = ["qwen1.5-110b", "internvl2-76b", "granite-20b", "gemma3-4b",
              "deepseek-v2-236b", "stablelm-1.6b", "whisper-large-v3",
              "mixtral-8x22b", "mamba2-130m", "recurrentgemma-9b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt(x, pat="{:.2e}"):
    return pat.format(x) if x is not None else "—"


def roofline_table(data, variant=""):
    rows = [r for r in data if r.get("mesh") == "single"
            and r.get("variant", "") == variant]
    rows.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]),
                             SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | peak GB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skip | — | — |")
            continue
        if "roofline" not in r:
            out.append(f"| {r['arch']} | {r['shape']} | ? | ? | ? | "
                       f"{r['status']} | — | — |")
            continue
        t = r["roofline"]
        peak = (r["memory"]["peak_bytes"] or 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(t['compute_s'])} | "
            f"{_fmt(t['memory_s'])} | {_fmt(t['collective_s'])} | "
            f"{t['dominant'].replace('_s', '')} | "
            f"{_fmt(r.get('model_vs_hlo_flops'), '{:.2f}')} | {peak:.1f} |")
    return "\n".join(out)


def dryrun_table(data, mesh):
    rows = [r for r in data if r.get("mesh") == mesh
            and not r.get("variant")]
    rows.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]),
                             SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | status | chips | lower s | compile s | "
           "peak GB/dev | collectives (AG/AR/RS/A2A/CP GB/dev) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | skipped "
                       f"({r['reason'][:40]}…) | — | — | — | — | — |")
            continue
        peak = (r.get("memory", {}).get("peak_bytes") or 0) / 1e9
        c = r.get("scanned_cost_raw", {}).get("colls",
                                              r.get("collectives", {}))
        coll = "/".join(f"{c.get(k, 0) / 1e9:.2f}" for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['status']} | "
            f"{r.get('n_chips', '—')} | {r.get('lower_s', '—')} | "
            f"{r.get('compile_s', '—')} | {peak:.1f} | {coll} |")
    return "\n".join(out)


def variants_table(data):
    rows = [r for r in data if r.get("variant")]
    if not rows:
        return "(no perf variants recorded yet)"
    out = ["| arch | shape | variant | compute s | memory s | collective s "
           "| dominant |", "|---|---|---|---|---|---|---|"]
    for r in rows:
        if "roofline" not in r:
            continue
        t = r["roofline"]
        out.append(f"| {r['arch']} | {r['shape']} | {r['variant']} | "
                   f"{_fmt(t['compute_s'])} | {_fmt(t['memory_s'])} | "
                   f"{_fmt(t['collective_s'])} | {t['dominant'].replace('_s', '')} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--what", choices=("roofline", "dryrun-single",
                                       "dryrun-multi", "variants"),
                    default="roofline")
    ap.add_argument("--path", default=RESULTS)
    args = ap.parse_args()
    with open(args.path) as f:
        data = json.load(f)
    if args.what == "roofline":
        print(roofline_table(data))
    elif args.what == "dryrun-single":
        print(dryrun_table(data, "single"))
    elif args.what == "dryrun-multi":
        print(dryrun_table(data, "multi"))
    else:
        print(variants_table(data))


if __name__ == "__main__":
    main()
