"""Fig. 7 (left): stream pub/sub — broker-relayed MQTT vs MQTT-hybrid vs
direct (ZeroMQ/TCP counterpart), three bandwidths at a 60 Hz target.

Measurement isolates the TRANSPORT path (publish -> [broker hop] ->
subscribe), excluding synthetic frame generation, mirroring the paper's
network-bound result: host µs/frame is the CPU-usage analogue, and the
1 Gbps link model turns wire bytes into sustainable fps.

Reproduced claims:
  * RELAY (pure MQTT) pays the broker hop — double wire traffic + broker
    copy; it loses throughput at mid/high bandwidth and misses 60 Hz where
    direct still meets it (Fig. 7 M/H).
  * HYBRID matches DIRECT (overhead eliminated, discovery/failover kept).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Broker, StreamBuffer
from repro.core.pubsub import MqttSink, MqttSrc, Transport

from .common import BANDWIDTHS, TARGET_FPS, emit, sustainable_fps, time_us


def _transport_pair(transport: str):
    broker = Broker()
    sink = MqttSink(pub_topic="cam", transport=transport).connect(broker)
    sink.negotiate([])
    src = MqttSrc(sub_topic="cam", transport=transport).connect(broker)
    if transport == "direct":
        src.connect_direct(sink.channel)
    return broker, sink, src


def run(frames: int = 50):
    rows = []
    for band, (h, w) in BANDWIDTHS.items():
        frame = StreamBuffer(tensors=(jnp.zeros((h, w, 3), jnp.uint8),))
        per_transport = {}
        for transport, hops in (("direct", 0), ("hybrid", 0), ("relay", 1)):
            broker, sink, src = _transport_pair(transport)

            def roundtrip():
                sink.apply({}, [frame])
                out = src.pull()
                assert out is not None

            us = time_us(roundtrip, n=frames)
            bpf = sink.channel.bytes_sent / max(sink.channel.msgs_sent, 1)
            # the relay hop also costs the broker one full copy of the frame
            relay_cpu_us = us + (bpf / 4e9 * 1e6 if hops else 0.0)
            fps = sustainable_fps(bpf, hops, relay_cpu_us)
            per_transport[transport] = (us, bpf, fps)
            emit(f"pubsub/{band}/{transport}", us,
                 f"bytes_per_frame={bpf:.0f};fps_1gbps={fps:.1f};"
                 f"meets_60hz={fps >= TARGET_FPS}")
        base = per_transport["direct"][2]
        rows.append((band,
                     per_transport["relay"][2] / base,
                     per_transport["hybrid"][2] / base))
    for band, rel, hyb in rows:
        emit(f"pubsub_norm/{band}", 0.0,
             f"relay_vs_direct={rel:.3f};hybrid_vs_direct={hyb:.3f}")
    return rows


if __name__ == "__main__":
    run()
