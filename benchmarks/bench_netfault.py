"""Adversarial network fabric: delivery-layer overhead and lossy-link
recovery (DESIGN.md §10 — the PR-10 tentpole gates).

Two measurements:

* **fault-free overhead** — steady-state µs/tick of the identical serving
  workload with the delivery layer on (delivery ids + CRC stamping +
  guard triage on every frame) vs off (the PR-9 fabric).  GATE: <= 1.10x
  — reliability must be nearly free when the network behaves;
* **recovery under 5% loss** — both directions of the query fabric drop
  5% of frames; every client must still COMPLETE a fixed request budget
  (at-least-once retransmits + idempotent dedup), with the realized
  goodput and retransmit volume reported.  GATE: all requests complete,
  bitwise the fault-free answers, zero conservation leaks.
"""
from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.core.netfault import DeliveryPolicy, FaultFabric, FaultPolicy
from repro.runtime import Device, Runtime

from .common import emit

# reuse the chaos harness's lossy-link installer so the benchmark gates on
# exactly the fault semantics the tests pin — no second copy to drift
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from chaoslib import lossy_endpoint  # noqa: E402

GATE_OVERHEAD = 1.10
N_CLIENTS = 4
LOSS = FaultPolicy(seed=77, drop=0.05)


def _ensure_model():
    """A serving workload with real compute (48 -> 1024 -> 1024 -> 16 MLP):
    the overhead gate divides the delivery layer's fixed per-frame cost by
    a REALISTIC tick, not a degenerate 12-byte toy whose serve is cheaper
    than any bookkeeping — the paper's among-device hops carry model
    inference, so that is the denominator the 1.10x promise is about."""
    key = "netfault_svc"

    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {"w1": jax.random.normal(k1, (48, 1024)) * 0.05,
                "w2": jax.random.normal(k2, (1024, 1024)) * 0.05,
                "w3": jax.random.normal(k3, (1024, 16)) * 0.05}

    def apply(p, x):
        h = x.astype(jnp.float32).reshape(1, -1) @ p["w1"]
        h = jax.nn.relu(h) @ p["w2"]
        return jax.nn.relu(h) @ p["w3"]

    register_model(key, init, apply,
                   out_specs=(TensorSpec((1, 16), "float32"),))
    return key


def _fleet(delivery=None):
    rt = Runtime(query_batch=8, delivery=delivery)
    model = _ensure_model()
    dev = Device("hub")
    ps = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    clients = []
    for i in range(N_CLIENTS):
        cdev = Device(f"tv{i}")
        pc = parse_launch(
            "testsrc width=4 height=4 ! tensor_converter ! "
            "tensor_query_client operation=svc name=qc ! appsink name=res")
        clients.append(cdev.add_pipeline(pc, jit=False))
        rt.add_device(cdev)
    return rt, ps.elements["ssrc"], clients


def _barrier(clients):
    """Block until every client's newest answer is materialized.  The
    answers chain through the server's serve state, so this drains ALL
    device work queued behind jax's async dispatch — without it the timed
    window only charges dispatch, and whichever config ran second would
    absorb the other's background compute."""
    for c in clients:
        log = c.sink_log.get("res", ())
        if log:
            np.asarray(log[-1].tensor)


def bench_fault_free_overhead(rounds: int = 20, chunk: int = 10):
    """Interleave timed chunks of the two configs — ALTERNATING which goes
    first each round — and keep the per-config minimum (the heartbeat-
    penalty bench discipline, hardened): the delta is the delivery layer,
    not allocator drift, async-dispatch bleed, or which config happened to
    share its rounds with a noisy neighbor."""
    rts = {}
    for label, delivery in (("delivery_on", DeliveryPolicy()),
                            ("delivery_off", None)):
        rt, _, clients = _fleet(delivery)
        rt.run(10)                           # warm compile caches
        _barrier(clients)
        rts[label] = (rt, clients)
    best = {label: float("inf") for label in rts}
    order = list(rts.items())
    for r in range(rounds):
        for label, (rt, clients) in (order if r % 2 == 0 else order[::-1]):
            t0 = time.perf_counter()
            rt.run(chunk)
            _barrier(clients)
            best[label] = min(best[label],
                              (time.perf_counter() - t0) / chunk * 1e6)
    for label, us in best.items():
        emit(f"netfault/{label}", us, f"us_per_tick={us:.1f}")
    d = rts["delivery_on"][0].stats()["delivery"]
    overhead = best["delivery_on"] / best["delivery_off"]
    ok = overhead <= GATE_OVERHEAD
    emit("netfault/fault_free_overhead", 0.0,
         f"delivery_on_vs_off={overhead:.3f}x;gate<={GATE_OVERHEAD}x;"
         f"pass={ok}",
         overhead=round(overhead, 4), gate=GATE_OVERHEAD,
         gate_pass=bool(ok), retransmits=d["retransmits"])
    if d["retransmits"] or d["deduped"] or d["rejected_corrupt"]:
        raise AssertionError(
            f"clean links must never trip the delivery layer: {d}")
    if not ok:
        raise AssertionError(
            f"fault-free delivery overhead {overhead:.3f}x "
            f"exceeds {GATE_OVERHEAD}x")


def bench_recovery_under_loss(budget: int = 20, max_ticks: int = 80):
    """5% drop on the request link and every answer link.  Completion is
    the gate: every client accumulates its full answer budget, each answer
    bitwise the fault-free run's, and the per-link message ledgers
    balance exactly."""
    rt0, _, ref_clients = _fleet(DeliveryPolicy())
    rt0.run(budget)
    ref = [[np.asarray(b.tensor) for b in c.sink_log["res"]]
           for c in ref_clients]

    rt, ssrc, clients = _fleet(DeliveryPolicy())
    fabric = FaultFabric()
    rt.fabric = fabric
    lossy_endpoint(fabric, ssrc.endpoint, LOSS, LOSS, name="svc")
    ticks = 0
    t0 = time.perf_counter()
    while ticks < max_ticks and any(
            len(c.sink_log.get("res", ())) < budget for c in clients):
        rt.tick()
        ticks += 1
    us_per_tick = (time.perf_counter() - t0) / max(ticks, 1) * 1e6

    done = [len(c.sink_log.get("res", ())) for c in clients]
    complete = all(n >= budget for n in done)
    mismatches = 0
    for rc, c in zip(ref, clients):
        got = [np.asarray(b.tensor) for b in c.sink_log.get("res", ())]
        for x, y in zip(rc, got):
            if not np.array_equal(x, y):
                mismatches += 1
    fabric.assert_conservation()             # zero silent loss, exactly
    d = rt.stats()["delivery"]
    dropped = sum(link.dropped_fault for link in fabric.links.values())
    emit("netfault/lossy_recovery", us_per_tick,
         f"ticks_to_complete={ticks};budget={budget}x{N_CLIENTS};"
         f"dropped={dropped};retransmits={d['retransmits']};"
         f"replays={d['replayed']};complete={complete};"
         f"bitwise={mismatches == 0}",
         ticks_to_complete=ticks, budget=budget, dropped=dropped,
         retransmits=d["retransmits"], replayed=d["replayed"],
         deduped=d["deduped"], complete=bool(complete),
         gate_pass=bool(complete and mismatches == 0))
    if not complete:
        raise AssertionError(
            f"5% loss: clients finished {done}, wanted {budget} each "
            f"within {max_ticks} ticks")
    if mismatches:
        raise AssertionError(
            f"{mismatches} answers diverged from the fault-free run")
    if not dropped or not d["retransmits"]:
        raise AssertionError("the loss schedule never bit — vacuous gate")


def run():
    bench_fault_free_overhead()
    bench_recovery_under_loss()


if __name__ == "__main__":
    run()
