"""Tenant-aware admission under million-user-shaped load (DESIGN.md §9).

The PR-9 tentpole gates, measured on the admission core itself with the
chaos harness's deterministic traffic generators (1k+ synthetic clients,
Zipf tenant skew, scripted overload bursts — no wall-clock, no RNG, so
every run is bit-reproducible):

* **isolation** — under 2x sustained overload with bursts, the
  high-priority tenant's p99 queue latency stays <= 1.5x its UNCONTENDED
  p99 (GATE): overload lands on the best-effort tier, not on realtime;
* **explicit shedding** — the best-effort tier sheds, and every shed is
  accounted (reason-tagged) AND client-notified: zero silent drops (GATE);
* **goodput** — uncontended, the QoS path serves >= 0.9x the no-QoS
  pure-FIFO baseline (GATE): the scheduler's overhead cannot eat the
  fabric's throughput;
* **reaction** — on the live runtime, sustained overload drives the
  broker's scaling signal across threshold and the autoscaler grows a
  replica as a §6 reconfig; measured in ticks-to-first-commit.
"""
from __future__ import annotations

import os
import sys
import time

import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.admission import AdmissionQueue, percentile_from_hist
from repro.core.elements import register_model
from repro.launch.model_serve import three_tier_qos
from repro.runtime import Device, Runtime
from repro.runtime.autoscale import Autoscaler

from .common import emit

# reuse the deterministic traffic generators the qos tests pin — one copy
# of the Zipf/burst semantics, no drift between tests and gates
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from chaoslib import burst_schedule, tenant_arrivals, zipf_tenants  # noqa: E402

N_CLIENTS = 1024
#: best-effort is the Zipf-popular tier (the bulk tier dominates a real
#: fleet); realtime is the scarce, protected one
TENANTS = ["best-effort", "standard", "realtime"]
CAPACITY = 10          # served per tick
UNCONTENDED = 8        # arrivals/tick below capacity
OVERLOAD = 2 * CAPACITY
N_TICKS = 300

GATE_P99_BLOWUP = 1.5
GATE_GOODPUT = 0.9


class _Raw:
    __slots__ = ("meta",)

    def __init__(self, tenant, client):
        self.meta = {"tenant_id": tenant, "client_id": client}


def _simulate(qos, base, burst_at=(), burst=0, seed=0):
    """Drive one AdmissionQueue through a scripted load: returns (stats,
    notices_delivered, us_per_request)."""
    tick = [0]
    adm = AdmissionQueue(qos=qos, clock=lambda: tick[0])
    client_tenant = zipf_tenants(N_CLIENTS, TENANTS, seed=seed)
    sched = burst_schedule(N_TICKS, base=base, burst=burst,
                           burst_at=burst_at, width=10)
    script = tenant_arrivals(N_TICKS, TENANTS, sched, seed=seed + 1)
    cid = 0
    t0 = time.perf_counter()
    n_requests = 0
    for t in range(N_TICKS):
        tick[0] += 1
        for tenant in script[t]:
            # a fresh synthetic client each arrival, tenant from ITS OWN
            # Zipf assignment (the per-tick script keeps the burst shape)
            client = cid % N_CLIENTS
            cid += 1
            adm.ingest(_Raw(client_tenant[client], client))
            n_requests += 1
        adm.expire()
        for rec in adm.take(CAPACITY):
            adm.mark_served(rec)
    us = (time.perf_counter() - t0) / max(n_requests, 1) * 1e6
    notices = 0
    for client in range(N_CLIENTS):
        while adm.pop_notice(client) is not None:
            notices += 1
    return adm.stats(), notices, us


def _p99(stats, tenant):
    return percentile_from_hist(stats.get(tenant, {}).get("latency_hist",
                                                          {}), 0.99)


def run():
    qos = three_tier_qos(deadline_ticks=12, max_queue=200)

    # -- uncontended: QoS goodput vs the pure-FIFO baseline -----------------
    fifo_stats, _, fifo_us = _simulate(None, base=UNCONTENDED)
    q_stats, q_notices, q_us = _simulate(qos, base=UNCONTENDED)
    fifo_served = sum(t["served"] for t in fifo_stats.values())
    q_served = sum(t["served"] for t in q_stats.values())
    goodput = q_served / max(fifo_served, 1)
    assert goodput >= GATE_GOODPUT, \
        f"GATE: uncontended QoS goodput {goodput:.3f} < {GATE_GOODPUT}"
    base_p99 = _p99(q_stats, "realtime")
    emit("qos.uncontended_goodput", q_us,
         f"served {q_served}/{fifo_served} of FIFO baseline "
         f"(ratio {goodput:.3f}, gate >={GATE_GOODPUT}) "
         f"[{N_CLIENTS} clients, Zipf tenants]",
         goodput_ratio=round(goodput, 4), fifo_us=round(fifo_us, 3),
         realtime_p99_ticks=base_p99, n_clients=N_CLIENTS)

    # -- 2x sustained overload with scripted bursts -------------------------
    o_stats, o_notices, o_us = _simulate(
        qos, base=OVERLOAD, burst_at=(60, 180), burst=2 * OVERLOAD)
    over_p99 = _p99(o_stats, "realtime")
    bound = GATE_P99_BLOWUP * max(base_p99, 1.0)
    assert over_p99 <= bound, \
        f"GATE: realtime p99 {over_p99} ticks under 2x overload " \
        f"> {bound} (uncontended {base_p99})"
    be = o_stats["best-effort"]
    assert be["shed"] > 0, "GATE: overload must shed the best-effort tier"
    total_shed = sum(t["shed"] for t in o_stats.values())
    total_reasons = sum(sum(t["shed_reasons"].values())
                        for t in o_stats.values())
    assert total_shed == total_reasons == o_notices, \
        f"GATE: silent drops — shed {total_shed}, reasons " \
        f"{total_reasons}, notified {o_notices}"
    for tid, t in o_stats.items():   # conservation under the worst case
        assert t["admitted"] == t["served"] + t["shed"] + t["queued"] + \
            t["in_flight"], (tid, t)
    emit("qos.overload_2x_isolation", o_us,
         f"realtime p99 {over_p99:.0f} ticks (uncontended {base_p99:.0f}, "
         f"gate <={bound:.0f}); best-effort shed {be['shed']} "
         f"all-notified (zero silent drops)",
         realtime_p99_ticks=over_p99, best_effort_shed=be["shed"],
         shed_notified=o_notices,
         served={t: s["served"] for t, s in o_stats.items()})

    # -- elastic reaction on the live runtime -------------------------------
    def init(rng):
        return {"w": jnp.full((12, 4), 0.5)}

    def apply(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    register_model("qos_bench_svc", init, apply,
                   out_specs=(TensorSpec((1, 4), "float32"),))

    def serve_ps():
        ps = parse_launch(
            "tensor_query_serversrc operation=qb name=ssrc ! "
            "tensor_filter model=qos_bench_svc ! "
            "tensor_query_serversink name=ssink")
        ps.elements["ssink"].pair_with(ps.elements["ssrc"])
        return ps

    rt = Runtime(qos=three_tier_qos(serve_per_tick=2))
    hub = Device("hub")
    hub.add_pipeline(serve_ps(), jit=False)
    rt.add_device(hub)
    for i in range(6):
        dev = Device(f"tv{i}")
        dev.add_pipeline(parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_query_client operation=qb name=qc ! appsink name=res"),
            jit=False)
        rt.add_device(dev)
    asc = Autoscaler(rt, "query/qb", lambda i: serve_ps(),
                     high_load=3.0, low_load=0.5, max_replicas=3,
                     cooldown_ticks=2, warm_ticks=1)
    t0 = time.perf_counter()
    react = None
    for t in range(1, 31):
        rt.tick()
        if asc.scale_ups >= 1:
            react = t
            break
    us_tick = (time.perf_counter() - t0) / max(rt.ticks, 1) * 1e6
    assert react is not None, "autoscaler never scaled up under overload"
    emit("qos.autoscale_react", us_tick,
         f"overload -> first replica committed in {react} ticks "
         f"(signal + §6 grow reconfig)",
         react_ticks=react, scale_ups=asc.scale_ups)
