"""Roofline table: reads results/dryrun.json (produced by launch/dryrun.py)
and prints the per-(arch × shape) three-term roofline + bottleneck — the
§Roofline deliverable, derived from the compiled single-pod dry-run.
"""
from __future__ import annotations

import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def run(path: str = RESULTS):
    if not os.path.exists(path):
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all --mesh single` first")
        return
    with open(path) as f:
        data = json.load(f)
    rows = [r for r in data if r.get("mesh") == "single"]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    for r in rows:
        tag = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            emit(tag, 0.0, f"skipped:{r['reason'][:60]}")
            continue
        if "roofline" not in r:
            emit(tag, 0.0, f"status={r['status']}")
            continue
        t = r["roofline"]
        emit(tag, 0.0,
             f"compute_s={t['compute_s']:.3e};memory_s={t['memory_s']:.3e};"
             f"collective_s={t['collective_s']:.3e};dominant={t['dominant']};"
             f"model_vs_hlo={r.get('model_vs_hlo_flops', 0):.3f};"
             f"peak_GB_per_dev={(r['memory']['peak_bytes'] or 0) / 1e9:.2f}")


if __name__ == "__main__":
    run()
