# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV on stdout AND dumps every row as machine-readable JSON (BENCH_PR5.json
# at the repo root) so the perf trajectory is tracked across PRs.  Each
# suite's wall time is recorded in the JSON (``suite_seconds``) so bench
# regressions are diffable across PRs, not just the measured rows.
#
#   Fig. 7 pub/sub  -> bench_pubsub         (RELAY vs HYBRID vs DIRECT, 3 bands)
#   Fig. 7 query    -> bench_query          (MQTT-hybrid vs TCP + failover)
#   §4.2.3 sync     -> bench_sync           (NTP rebase vs raw clocks)
#   §3/§4.1 codecs  -> bench_compression    (sparse/quant8 wire bytes)
#   kernels         -> bench_kernels        (Pallas codec kernels, interpret)
#   §Roofline       -> bench_roofline       (reads results/dryrun.json)
#   engine          -> bench_step_overhead  (compiled plan + burst vs seed loop)
#   serving         -> bench_query_batching (micro-batched offloading, >=2x gate
#                                            + batched-beats-sequential e2e gate)
#   failover        -> bench_failover       (ticks-to-recovery <=2 gate, heartbeat cost)
#   reconfig        -> bench_reconfig       (hot-swap cutover pause <=2 ticks gate,
#                                            post-swap throughput >=0.95x gate)
#   mesh serving    -> bench_sharded_serving (calibrated mesh placement, >=2x gate)
#   wire path       -> bench_wire_path      (fused codec serving >=2x e2e gate,
#                                            sparse enc >=10x vs PR-4)
#   model serving   -> bench_model_serving  (continuous-batched decode >=2x
#                                            sequential at 8 streams gate)
#   pp serving      -> bench_pp_serving     (2-stage among-device chain
#                                            steady-state >=1.5x mono gate)
#   qos serving     -> bench_qos            (1k-client Zipf+burst load: overload
#                                            p99 isolation <=1.5x gate, zero
#                                            silent drops, goodput >=0.9x gate)
#   netfault        -> bench_netfault       (delivery layer <=1.10x fault-free
#                                            gate; 5% loss: complete + bitwise +
#                                            conservation gate)
import json
import os
import platform
import sys
import time
import traceback

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_PR10.json")


def main() -> None:
    from . import (bench_compression, bench_failover, bench_kernels,
                   bench_model_serving, bench_netfault, bench_pp_serving,
                   bench_pubsub, bench_qos, bench_query,
                   bench_query_batching, bench_reconfig, bench_roofline,
                   bench_sharded_serving, bench_step_overhead, bench_sync,
                   bench_wire_path)
    from .common import ROWS, reset_rows

    reset_rows()
    print("name,us_per_call,derived")
    suites = [
        ("pubsub", bench_pubsub.run),
        ("query", bench_query.run),
        ("query_failover", bench_query.run_failover),
        ("query_batching", bench_query_batching.run),
        ("wire_path", bench_wire_path.run),
        ("model_serving", bench_model_serving.run),
        ("pp_serving", bench_pp_serving.run),
        ("qos", bench_qos.run),
        ("netfault", bench_netfault.run),
        ("sharded_serving", bench_sharded_serving.run),
        ("failover", bench_failover.run),
        ("reconfig", bench_reconfig.run),
        ("sync", bench_sync.run),
        ("compression", bench_compression.run),
        ("kernels", bench_kernels.run),
        ("step_overhead", bench_step_overhead.run),
        ("roofline", bench_roofline.run),
    ]
    failed = []
    suite_seconds = {}
    for name, fn in suites:
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"{name},0.0,SUITE_FAILED")
        finally:
            suite_seconds[name] = round(time.perf_counter() - t0, 3)

    import jax
    payload = {
        "schema": 1,
        "pr": 10,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "suites_failed": failed,
        "suite_seconds": suite_seconds,
        "rows": ROWS,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.normpath(BENCH_JSON)} ({len(ROWS)} rows)")
    for name, secs in suite_seconds.items():
        print(f"# suite {name}: {secs}s")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
