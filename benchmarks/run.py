# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   Fig. 7 pub/sub  -> bench_pubsub      (RELAY vs HYBRID vs DIRECT, 3 bands)
#   Fig. 7 query    -> bench_query       (MQTT-hybrid vs TCP + failover)
#   §4.2.3 sync     -> bench_sync        (NTP rebase vs raw clocks)
#   §3/§4.1 codecs  -> bench_compression (sparse/quant8 wire bytes)
#   kernels         -> bench_kernels     (Pallas codec kernels, interpret)
#   §Roofline       -> bench_roofline    (reads results/dryrun.json)
import sys
import traceback


def main() -> None:
    from . import (bench_compression, bench_kernels, bench_pubsub,
                   bench_query, bench_roofline, bench_sync)

    print("name,us_per_call,derived")
    suites = [
        ("pubsub", bench_pubsub.run),
        ("query", bench_query.run),
        ("query_failover", bench_query.run_failover),
        ("sync", bench_sync.run),
        ("compression", bench_compression.run),
        ("kernels", bench_kernels.run),
        ("roofline", bench_roofline.run),
    ]
    failed = 0
    for name, fn in suites:
        try:
            fn()
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},0.0,SUITE_FAILED")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
