"""Query micro-batching: server frames/sec with 8 concurrent clients,
batched (one hoisted scan dispatch per flush) vs sequential
(one interpreted round-trip per request) — the PR-2 tentpole lever.

GATES:
* batch-8 serving must sustain >= 2x the sequential server frames/sec,
  measured on the serving path itself (requests pre-queued, flush timed),
  so client-side pipeline cost does not dilute the server-side win;
* the WHOLE batched tick must beat the sequential tick (PR-5: the batched
  e2e tick used to LOSE to sequential — every deferred frame walked the
  client pipeline interpreted and the codec/stack overhead ate the compiled
  serve win; jitted deferred segments + the fused wire path reclaim it).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TensorSpec, parse_launch
from repro.core.buffers import StreamBuffer
from repro.core.elements import register_model
from repro.runtime import Device, Runtime

from .common import emit

N_CLIENTS = 8
GATE_SPEEDUP = 2.0
GATE_E2E = 1.0  # batched tick must beat (>=) the sequential tick


def _ensure_model(d: int = 192):
    key = f"qbatch_mlp_{d}"

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"w1": jax.random.normal(k1, (d, d)) * 0.05,
                "w2": jax.random.normal(k2, (d, 16)) * 0.05}

    def apply(p, x):
        h = jnp.tanh(x.astype(jnp.float32).reshape(1, -1) @ p["w1"])
        return h @ p["w2"]

    register_model(key, init, apply,
                   out_specs=(TensorSpec((1, 16), "float32"),))
    return key


def _build(query_batch: int, d: int = 192):
    rt = Runtime(query_batch=query_batch)
    model = _ensure_model(d)
    hub = Device("hub")
    srv = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    srv_run = hub.add_pipeline(srv, jit=False)
    rt.add_device(hub)
    clients = []
    for i in range(N_CLIENTS):
        dev = Device(f"tv{i}")
        cli = parse_launch(
            f"testsrc width={d // 3} height=1 ! tensor_converter ! "
            f"tensor_query_client operation=svc name=qc ! appsink name=o")
        clients.append(dev.add_pipeline(cli, jit=False))
        rt.add_device(dev)
    return rt, srv_run, [c.pipe.elements["qc"] for c in clients]


def _round_runner(rt: Runtime, qcs, d: int):
    """One serving round over the endpoint: queue one request per client,
    flush (batched) or step per request (sequential fallback inside the
    same flush API — policy decides), drain the answers."""
    batcher = next(iter(rt._batchers.values()))
    frame = StreamBuffer(tensors=(jnp.arange(d, dtype=jnp.float32) / d,),
                         pts=jnp.int32(0))

    def one_round():
        for qc in qcs:
            qc.send_query(frame)
        batcher.flush()
        for qc in qcs:
            while qc.recv_answer() is not None:
                pass
    return one_round


def run(rounds: int = 10, reps: int = 5):
    d = 192
    rt_b, srv_b, qcs_b = _build(query_batch=N_CLIENTS, d=d)
    rt_s, srv_s, qcs_s = _build(query_batch=0, d=d)
    runners = {"batched": _round_runner(rt_b, qcs_b, d),
               "sequential": _round_runner(rt_s, qcs_s, d)}
    for fn in runners.values():  # compile + warm outside the timed windows
        for _ in range(3):
            fn()
    # interleaved mins: the serving windows are short and the box is noisy
    # (2-3x run-to-run) — alternating reps hit both paths with the same
    # weather, and the min is the honest dispatch cost
    best = {k: float("inf") for k in runners}
    for _ in range(reps):
        for label, fn in runners.items():
            t0 = time.perf_counter()
            for _ in range(rounds):
                fn()
            best[label] = min(best[label],
                              (time.perf_counter() - t0) / rounds)
    fps_batched = N_CLIENTS / best["batched"]
    fps_seq = N_CLIENTS / best["sequential"]

    speedup = fps_batched / fps_seq
    emit(f"query_batching/serving_fps/batch{N_CLIENTS}",
         1e6 / fps_batched, f"frames_per_sec={fps_batched:.0f}",
         fps=round(fps_batched, 1))
    emit("query_batching/serving_fps/sequential",
         1e6 / fps_seq, f"frames_per_sec={fps_seq:.0f}",
         fps=round(fps_seq, 1))
    emit("query_batching/speedup", 0.0,
         f"batched_vs_sequential={speedup:.2f}x;gate>=2x;"
         f"pass={speedup >= GATE_SPEEDUP}",
         speedup=round(speedup, 3), gate=GATE_SPEEDUP,
         gate_pass=bool(speedup >= GATE_SPEEDUP))

    # end-to-end GATE: whole-runtime ticks with 8 live client pipelines —
    # the batched tick (jitted deferred segments + fused wire path) must
    # beat the sequential tick, not just win on serve-dispatch fps.
    # Interleaved mins: box noise hits both runtimes alike.
    rts = {}
    for label, rt in (("batched", Runtime(query_batch=8)),
                      ("sequential", Runtime(query_batch=0))):
        _build_into(rt, d)
        rt.run(3)  # compile + warm caches outside the timed window
        rts[label] = rt
    best = {k: float("inf") for k in rts}
    for _ in range(5):
        for label, rt in rts.items():
            t0 = time.perf_counter()
            rt.run(10)
            best[label] = min(best[label], (time.perf_counter() - t0) / 10)
    for label, dt in best.items():
        emit(f"query_batching/e2e_tick/{label}", dt * 1e6,
             f"ms_per_tick={dt * 1e3:.2f}")
    e2e_speedup = best["sequential"] / best["batched"]
    emit("query_batching/e2e_speedup", 0.0,
         f"batched_vs_sequential={e2e_speedup:.2f}x;gate>={GATE_E2E}x;"
         f"pass={e2e_speedup >= GATE_E2E}",
         speedup=round(e2e_speedup, 3), gate=GATE_E2E,
         gate_pass=bool(e2e_speedup >= GATE_E2E))

    if speedup < GATE_SPEEDUP:
        raise AssertionError(
            f"query batching gate failed: {speedup:.2f}x < {GATE_SPEEDUP}x")
    if e2e_speedup < GATE_E2E:
        raise AssertionError(
            f"e2e tick gate failed: batched tick is {e2e_speedup:.2f}x the "
            f"sequential tick (must be >= {GATE_E2E}x — the PR-5 regression "
            f"fix)")


def _build_into(rt: Runtime, d: int):
    model = _ensure_model(d)
    hub = Device("hub")
    srv = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    srv_run = hub.add_pipeline(srv, jit=False)
    rt.add_device(hub)
    for i in range(N_CLIENTS):
        dev = Device(f"tv{i}")
        cli = parse_launch(
            f"testsrc width={d // 3} height=1 ! tensor_converter ! "
            f"tensor_query_client operation=svc name=qc ! appsink name=o")
        dev.add_pipeline(cli, jit=False)
        rt.add_device(dev)
    return model, srv_run, rt


if __name__ == "__main__":
    run()
