"""Query micro-batching: server frames/sec with 8 concurrent clients,
batched (one hoisted scan dispatch per flush) vs sequential
(one interpreted round-trip per request) — the PR-2 tentpole lever.

GATE: batch-8 serving must sustain >= 2x the sequential server frames/sec.
Measured on the serving path itself (requests pre-queued, flush timed), so
client-side pipeline cost does not dilute the server-side win.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TensorSpec, parse_launch
from repro.core.buffers import StreamBuffer
from repro.core.elements import register_model
from repro.runtime import Device, Runtime

from .common import emit

N_CLIENTS = 8
GATE_SPEEDUP = 2.0


def _ensure_model(d: int = 192):
    key = f"qbatch_mlp_{d}"

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"w1": jax.random.normal(k1, (d, d)) * 0.05,
                "w2": jax.random.normal(k2, (d, 16)) * 0.05}

    def apply(p, x):
        h = jnp.tanh(x.astype(jnp.float32).reshape(1, -1) @ p["w1"])
        return h @ p["w2"]

    register_model(key, init, apply,
                   out_specs=(TensorSpec((1, 16), "float32"),))
    return key


def _build(query_batch: int, d: int = 192):
    rt = Runtime(query_batch=query_batch)
    model = _ensure_model(d)
    hub = Device("hub")
    srv = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    srv_run = hub.add_pipeline(srv, jit=False)
    rt.add_device(hub)
    clients = []
    for i in range(N_CLIENTS):
        dev = Device(f"tv{i}")
        cli = parse_launch(
            f"testsrc width={d // 3} height=1 ! tensor_converter ! "
            f"tensor_query_client operation=svc name=qc ! appsink name=o")
        clients.append(dev.add_pipeline(cli, jit=False))
        rt.add_device(dev)
    return rt, srv_run, [c.pipe.elements["qc"] for c in clients]


def _serving_fps(rt: Runtime, srv_run, qcs, d: int, rounds: int,
                 warmup: int = 3) -> float:
    """Time ONLY the serving path: pre-queue one request per client, then
    flush (batched) or step per request (sequential fallback inside the
    same flush API — policy decides)."""
    batcher = next(iter(rt._batchers.values()))
    frame = StreamBuffer(tensors=(jnp.arange(d, dtype=jnp.float32) / d,),
                         pts=jnp.int32(0))

    def one_round():
        for qc in qcs:
            qc.send_query(frame)
        batcher.flush()

    for _ in range(warmup):
        one_round()
    t0 = time.perf_counter()
    for _ in range(rounds):
        one_round()
    dt = time.perf_counter() - t0
    # drain the answer channels so memory stays flat across rounds
    for qc in qcs:
        while qc.recv_answer() is not None:
            pass
    return rounds * len(qcs) / dt


def run(rounds: int = 30):
    d = 192
    rt_b, srv_b, qcs_b = _build(query_batch=N_CLIENTS, d=d)
    fps_batched = _serving_fps(rt_b, srv_b, qcs_b, d, rounds)

    rt_s, srv_s, qcs_s = _build(query_batch=0, d=d)
    fps_seq = _serving_fps(rt_s, srv_s, qcs_s, d, rounds)

    speedup = fps_batched / fps_seq
    emit(f"query_batching/serving_fps/batch{N_CLIENTS}",
         1e6 / fps_batched, f"frames_per_sec={fps_batched:.0f}",
         fps=round(fps_batched, 1))
    emit("query_batching/serving_fps/sequential",
         1e6 / fps_seq, f"frames_per_sec={fps_seq:.0f}",
         fps=round(fps_seq, 1))
    emit("query_batching/speedup", 0.0,
         f"batched_vs_sequential={speedup:.2f}x;gate>=2x;"
         f"pass={speedup >= GATE_SPEEDUP}",
         speedup=round(speedup, 3), gate=GATE_SPEEDUP,
         gate_pass=bool(speedup >= GATE_SPEEDUP))

    # end-to-end sanity: whole-runtime ticks with 8 live client pipelines
    # (client pipelines run interpreted either way; this shows the tick-level
    # effect, not the serving-path gate)
    for label, rt in (("batched", Runtime(query_batch=8)),
                      ("sequential", Runtime(query_batch=0))):
        model_rt, srv_run, _ = _build_into(rt, d)
        rt.run(3)  # compile + warm caches outside the timed window
        base = srv_run.frames
        t0 = time.perf_counter()
        rt.run(10)
        dt = time.perf_counter() - t0
        emit(f"query_batching/e2e_tick/{label}", dt / 10 * 1e6,
             f"server_frames={srv_run.frames - base}")

    if speedup < GATE_SPEEDUP:
        raise AssertionError(
            f"query batching gate failed: {speedup:.2f}x < {GATE_SPEEDUP}x")


def _build_into(rt: Runtime, d: int):
    model = _ensure_model(d)
    hub = Device("hub")
    srv = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    srv_run = hub.add_pipeline(srv, jit=False)
    rt.add_device(hub)
    for i in range(N_CLIENTS):
        dev = Device(f"tv{i}")
        cli = parse_launch(
            f"testsrc width={d // 3} height=1 ! tensor_converter ! "
            f"tensor_query_client operation=svc name=qc ! appsink name=o")
        dev.add_pipeline(cli, jit=False)
        rt.add_device(dev)
    return model, srv_run, rt


if __name__ == "__main__":
    run()
