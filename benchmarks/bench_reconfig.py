"""Live reconfiguration: cutover pause and post-swap throughput
(DESIGN.md §6 — the PR-6 tentpole gates).

Three measurements:

* **hot-swap cutover** — the serving model is swapped under live traffic;
  counts the ticks inside the swap window where any client missed its
  answer.  GATE: pause <= 2 ticks (the prepare/warm work happens off the
  serving path, the commit itself is pointer moves + cache hits);
* **post-swap throughput** — steady-state µs/tick after the commit vs a
  never-reconfigured twin fleet, timed in INTERLEAVED chunks so process
  drift (GC, allocator) cancels out of the ratio.  GATE: post throughput
  >= 0.95x the twin's (the swapped plan serves through the same warmed
  executable registry);
* **request overhead** — wall µs of ``Runtime.reconfigure`` itself
  (prepare + warm, paid once, off the tick path) and of a failed prepare's
  rollback (which must leave serving untouched).
"""
from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.element import element_factory
from repro.core.elements import register_model
from repro.runtime import Device, Runtime

from .common import emit

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

GATE_PAUSE_TICKS = 2
GATE_THROUGHPUT_RATIO = 0.95
N_CLIENTS = 4


def _ensure_models():
    def init_a(rng):
        return {"w": jax.random.normal(rng, (12, 4)) * 0.3}

    def apply_a(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    def init_b(rng):
        return {"w": jax.random.normal(rng, (12, 4)) * 0.1,
                "b": jnp.ones((4,))}

    def apply_b(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"] + p["b"]

    register_model("reconfA", init_a, apply_a,
                   out_specs=(TensorSpec((1, 4), "float32"),))
    register_model("reconfB", init_b, apply_b,
                   out_specs=(TensorSpec((1, 4), "float32"),))


def _fleet():
    _ensure_models()
    rt = Runtime(query_batch=8)
    hub = Device("hub")
    sp = parse_launch(
        "tensor_query_serversrc operation=svc name=ssrc ! "
        "tensor_filter model=reconfA name=filt ! "
        "tensor_query_serversink name=ssink")
    sp.elements["ssink"].pair_with(sp.elements["ssrc"])
    hub_run = hub.add_pipeline(sp, jit=False)
    rt.add_device(hub)
    clients = []
    for i in range(N_CLIENTS):
        dev = Device(f"tv{i}")
        pc = parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_query_client operation=svc name=qc ! appsink name=res")
        clients.append(dev.add_pipeline(pc, jit=False))
        rt.add_device(dev)
    return rt, hub_run, clients


def _best_us_per_tick(rt, rounds: int = 8, chunk: int = 10) -> float:
    """Min-of-chunks per-tick µs: single long windows are dominated by
    process drift (GC, allocator), not by the serving loop."""
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        rt.run(chunk)
        best = min(best, (time.perf_counter() - t0) / chunk * 1e6)
    return best


def _swap(rt, hub_run, model, warm_ticks=1):
    return rt.reconfigure(
        hub_run, hub_run.pipe.reconfig().swap(
            "filt", element_factory("tensor_filter", model=model)),
        warm_ticks=warm_ticks)


def bench_hot_swap(max_window: int = 20, rounds: int = 6, chunk: int = 10):
    rt, hub_run, clients = _fleet()
    control, _, _ = _fleet()                 # twin fleet, never swapped
    rt.run(8)                                # warm compile caches
    control.run(8)
    pre_us = _best_us_per_tick(rt)

    rc = _swap(rt, hub_run, "reconfB")
    pause = swap_ticks = 0
    while rc.status not in ("committed", "rolled_back") and \
            swap_ticks < max_window:
        before = [c.frames for c in clients]
        rt.tick()
        swap_ticks += 1
        if any(c.frames == b for c, b in zip(clients, before)):
            pause += 1                       # a tick somebody missed
    committed = rc.status == "committed"

    best = {"swapped": float("inf"), "control": float("inf")}
    for _ in range(rounds):
        for label, r in (("swapped", rt), ("control", control)):
            t0 = time.perf_counter()
            r.run(chunk)
            best[label] = min(best[label],
                              (time.perf_counter() - t0) / chunk * 1e6)
    post_us = best["swapped"]
    ratio = best["control"] / post_us        # >1: swapped is FASTER

    lost = sum(rt.ticks - c.frames for c in clients)
    emit("reconfig/hot_swap/pre", pre_us, f"us_per_tick={pre_us:.1f}")
    emit("reconfig/hot_swap/post", post_us,
         f"us_per_tick={post_us:.1f};control={best['control']:.1f}")
    emit("reconfig/hot_swap/cutover", 0.0,
         f"pause_ticks={pause};swap_ticks={swap_ticks};"
         f"committed={committed};lost_requests={lost};"
         f"gate<={GATE_PAUSE_TICKS};pass={committed and pause <= GATE_PAUSE_TICKS}",
         pause_ticks=pause, swap_ticks=swap_ticks, committed=bool(committed),
         lost=lost, gate=GATE_PAUSE_TICKS,
         gate_pass=bool(committed and pause <= GATE_PAUSE_TICKS))
    emit("reconfig/hot_swap/throughput_ratio", 0.0,
         f"swapped_vs_twin={ratio:.3f}x;gate>={GATE_THROUGHPUT_RATIO};"
         f"pass={ratio >= GATE_THROUGHPUT_RATIO}",
         ratio=round(ratio, 4), gate=GATE_THROUGHPUT_RATIO,
         gate_pass=bool(ratio >= GATE_THROUGHPUT_RATIO))
    if not committed:
        raise AssertionError(f"hot swap did not commit: {rc.status} "
                             f"({rc.reason})")
    if lost:
        raise AssertionError(f"hot swap lost {lost} requests")
    if pause > GATE_PAUSE_TICKS:
        raise AssertionError(
            f"cutover paused {pause} ticks (> {GATE_PAUSE_TICKS})")
    if ratio < GATE_THROUGHPUT_RATIO:
        raise AssertionError(
            f"post-swap throughput {ratio:.3f}x pre "
            f"(< {GATE_THROUGHPUT_RATIO})")


def bench_request_overhead(rounds: int = 5):
    """Prepare+warm cost, paid once off the tick path, and the cost of a
    rolled-back bad edit (which must leave serving untouched)."""
    rt, hub_run, clients = _fleet()
    rt.run(8)
    best = float("inf")
    models = ("reconfB", "reconfA") * ((rounds + 1) // 2)
    for model in models[:rounds]:
        t0 = time.perf_counter()
        rc = _swap(rt, hub_run, model)
        best = min(best, (time.perf_counter() - t0) * 1e6)
        rt.run(3)                            # let it commit
        assert rc.status == "committed"
    emit("reconfig/request/prepare_warm", best, f"us_per_request={best:.1f}")

    t0 = time.perf_counter()
    rc = rt.reconfigure(hub_run, hub_run.pipe.reconfig().remove("ghost"))
    rollback_us = (time.perf_counter() - t0) * 1e6
    ticks0 = rt.ticks
    rt.run(3)
    served = all(c.frames == rt.ticks for c in clients)
    emit("reconfig/request/rollback", rollback_us,
         f"us_per_rollback={rollback_us:.1f};status={rc.status};"
         f"serving_untouched={served}",
         status=rc.status, serving_untouched=bool(served))
    if rc.status != "rolled_back" or not served:
        raise AssertionError("bad edit must roll back without touching "
                             f"serving (status={rc.status}, ticks={ticks0})")


def run():
    bench_hot_swap()
    bench_request_overhead()


if __name__ == "__main__":
    run()
