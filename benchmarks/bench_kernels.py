"""Stream-codec Pallas kernels: µs/call in interpret mode (CPU) — relative
cost of the codecs on a fixed activation frame.  Absolute TPU numbers come
from the roofline (the kernels are VMEM-resident, bandwidth-bound).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .common import emit, time_us

N = 64 * 1024


def run():
    x2 = jax.random.normal(jax.random.PRNGKey(0), (64, 1024))
    flat = jnp.where(jax.random.uniform(jax.random.PRNGKey(1), (N,)) < 0.8,
                     0.0, 1.0) * jax.random.normal(jax.random.PRNGKey(2), (N,))

    q, s = ops.quantize8(x2)
    us = time_us(lambda: jax.block_until_ready(ops.quantize8(x2)), n=5)
    emit("kernel/quant8_enc", us, f"in_bytes={x2.size * 4};out_bytes={x2.size}")
    us = time_us(lambda: jax.block_until_ready(ops.dequantize8(q, s)), n=5)
    emit("kernel/quant8_dec", us, "")

    v, i, nnz = ops.sparse_enc(flat, cap=N // 4, threshold=0.0)
    us = time_us(lambda: jax.block_until_ready(
        ops.sparse_enc(flat, cap=N // 4, threshold=0.0)), n=5)
    emit("kernel/sparse_enc", us, f"nnz={int(nnz)};cap={N // 4}")
    us = time_us(lambda: jax.block_until_ready(
        ops.sparse_dec(v, i, nnz, N)), n=5)
    emit("kernel/sparse_dec", us, "")


if __name__ == "__main__":
    run()
