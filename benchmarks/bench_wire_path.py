"""Fused batched wire path (PR 5, DESIGN.md §5): codec encode/decode inside
the compiled serving dispatch vs the eager per-frame codec path.

The paper's among-device pipelines live or die on the transport hot path
("sparse tensors and gst-gz support compressed transmissions").  BENCH_PR4
showed the codec layer erasing the compiled-serve win: sparse encode at
~101 ms/tensor, and every batched tick decoding + re-encoding each frame
eagerly on the host outside the jit.  PR 5 fuses the wire path; this suite
gates the two headline numbers:

* **e2e tick, quant8 clients, batch 8** — the whole-runtime tick with the
  fused wire path must be >= 2x faster than the eager-codec baseline
  (``Runtime(fused_wire=False)`` = the PR-4 path, bit-for-bit);
* **sparse encode per tensor** — down >= 10x from the PR-4 ~101.8 ms on the
  same LM-activation frame (the XLA fast path of the block-COO kernel).

Both comparisons are semantics-free: the fused path is pinned bitwise
against the eager one in tests/test_wire_path.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.kernels import ops as kops
from repro.runtime import Device, Runtime

from .common import emit

N_CLIENTS = 8
GATE_E2E_SPEEDUP = 2.0
# BENCH_PR4.json kernel/sparse_enc on the (64, 1024) LM-activation frame
PR4_SPARSE_ENC_US = 101_753.6
GATE_SPARSE_SPEEDUP = 10.0
LM_SHAPE = (64, 1024)


def _ensure_model(d: int = 192):
    key = f"wirepath_mlp_{d}"

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"w1": jax.random.normal(k1, (d, d)) * 0.05,
                "w2": jax.random.normal(k2, (d, 16)) * 0.05}

    def apply(p, x):
        h = jnp.tanh(x.astype(jnp.float32).reshape(1, -1) @ p["w1"])
        return h @ p["w2"]

    register_model(key, init, apply,
                   out_specs=(TensorSpec((1, 16), "float32"),))
    return key


def _build(codec: str, fused: bool, d: int = 192) -> Runtime:
    rt = Runtime(query_batch=N_CLIENTS, fused_wire=fused)
    model = _ensure_model(d)
    hub = Device("hub")
    srv = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    hub.add_pipeline(srv, jit=False)
    rt.add_device(hub)
    for i in range(N_CLIENTS):
        dev = Device(f"tv{i}")
        cli = parse_launch(
            f"testsrc width={d // 3} height=1 ! tensor_converter ! "
            f"tensor_query_client operation=svc codec={codec} name=qc ! "
            f"appsink name=o")
        dev.add_pipeline(cli, jit=False)
        rt.add_device(dev)
    return rt


def _tick_ms(rt: Runtime, reps: int = 5, ticks: int = 10) -> float:
    """Interleaved-min tick time (the box is noisy; mins compare paths)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        rt.run(ticks)
        best = min(best, (time.perf_counter() - t0) / ticks)
    return best * 1e3


def _e2e_gate():
    pairs = {}
    for codec in ("quant8", "sparse:0.25"):
        rts = {"fused": _build(codec, fused=True),
               "eager": _build(codec, fused=False)}
        for rt in rts.values():
            rt.run(3)   # compile + warm every trace outside the timed window
        ms = {}
        # interleave the two runtimes so box noise hits both alike
        best = {k: float("inf") for k in rts}
        for _ in range(5):
            for k, rt in rts.items():
                t0 = time.perf_counter()
                rt.run(10)
                best[k] = min(best[k], (time.perf_counter() - t0) / 10)
        ms = {k: v * 1e3 for k, v in best.items()}
        speedup = ms["eager"] / ms["fused"]
        tag = codec.partition(":")[0]
        emit(f"wire_path/e2e_tick/{tag}/fused", ms["fused"] * 1e3,
             f"ms_per_tick={ms['fused']:.2f}")
        emit(f"wire_path/e2e_tick/{tag}/eager", ms["eager"] * 1e3,
             f"ms_per_tick={ms['eager']:.2f}")
        gate = speedup >= GATE_E2E_SPEEDUP if tag == "quant8" else True
        emit(f"wire_path/e2e_speedup/{tag}", 0.0,
             f"fused_vs_eager={speedup:.2f}x;gate>=2x;pass={gate}",
             speedup=round(speedup, 3), gate=GATE_E2E_SPEEDUP,
             gate_pass=bool(gate))
        pairs[tag] = speedup
        # the fused path really fused: every frame went through the
        # codec-fused executable, none fell back
        qb = rts["fused"].stats()["query_batching"]
        assert qb["fused_frames"] > 0 and qb["sequential_frames"] == 0
    return pairs


def _sparse_kernel_gate():
    x = jax.random.normal(jax.random.PRNGKey(0), LM_SHAPE)
    x = jnp.where(jax.random.uniform(jax.random.PRNGKey(1), LM_SHAPE) < 0.25,
                  x, 0.0).reshape(-1)
    cap = int(x.size * 0.25)

    def enc():
        return jax.block_until_ready(kops.sparse_enc(x, cap))
    enc()
    t0 = time.perf_counter()
    n = 10
    for _ in range(n):
        enc()
    us = (time.perf_counter() - t0) / n * 1e6
    speedup = PR4_SPARSE_ENC_US / us
    emit("wire_path/sparse_enc_per_tensor", us,
         f"pr4_baseline_us={PR4_SPARSE_ENC_US};speedup={speedup:.1f}x;"
         f"gate>=10x;pass={speedup >= GATE_SPARSE_SPEEDUP}",
         speedup=round(speedup, 2), gate=GATE_SPARSE_SPEEDUP,
         gate_pass=bool(speedup >= GATE_SPARSE_SPEEDUP))
    return speedup


def _batched_codec_dispatch():
    """Informational: one stacked dispatch vs batch x per-frame dispatches,
    at the query-request frame size the scheduler actually batch-encodes
    (the gain is dispatch amortization; at multi-MB pub/sub frames the
    host fetch dominates instead, which is why only the query round path
    uses encode_batch — pub/sub publishes stay eager)."""
    from repro.core import StreamBuffer, compression as comp
    frames = [StreamBuffer(tensors=(jax.random.normal(
        jax.random.PRNGKey(i), (192,)),), pts=jnp.int32(i))
        for i in range(N_CLIENTS)]

    def loop():
        return [comp.encode(f, "quant8") for f in frames]

    def batched():
        return comp.encode_batch(frames, "quant8")

    for fn in (loop, batched):
        fn()
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready([e.tensors[0].q for e, _ in loop()])
    t_loop = (time.perf_counter() - t0) / 5
    t0 = time.perf_counter()
    for _ in range(5):
        batched()   # encode_batch fetches to host internally
    t_batch = (time.perf_counter() - t0) / 5
    emit("wire_path/encode_batch8/quant8", t_batch * 1e6,
         f"per_frame_loop_us={t_loop * 1e6:.0f};"
         f"speedup={t_loop / t_batch:.2f}x")


def run():
    speedups = _e2e_gate()
    sparse_speedup = _sparse_kernel_gate()
    _batched_codec_dispatch()
    if speedups["quant8"] < GATE_E2E_SPEEDUP:
        raise AssertionError(
            f"wire-path gate failed: quant8 fused e2e "
            f"{speedups['quant8']:.2f}x < {GATE_E2E_SPEEDUP}x")
    if sparse_speedup < GATE_SPARSE_SPEEDUP:
        raise AssertionError(
            f"sparse encode gate failed: {sparse_speedup:.1f}x < "
            f"{GATE_SPARSE_SPEEDUP}x vs PR-4 baseline")


if __name__ == "__main__":
    run()
