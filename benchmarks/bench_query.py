"""Fig. 7 (right): query offloading — MQTT-hybrid vs TCP-raw round trips at
three payload bandwidths, plus the failover capability only hybrid has.

Reproduced claim: MQTT-hybrid ≈ TCP (data plane identical; control plane via
broker costs nothing on the hot path) while adding discovery + failover.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.runtime import Device, Runtime

from .common import BANDWIDTHS, emit, sustainable_fps, time_us


def _ensure_model(h: int, w: int):
    key = f"bench_id_{h}x{w}"
    def init(rng):
        return {}

    def apply(p, x):
        return (jnp.mean(x.astype(jnp.float32), axis=-1),)

    register_model(key, init, apply,
                   out_specs=(TensorSpec((h, w), "float32"),))
    return key


def _build(transport: str, h: int, w: int):
    rt = Runtime()
    model = _ensure_model(h, w)
    hub = Device("hub")
    srv = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    hub.add_pipeline(srv, jit=False)
    rt.add_device(hub)
    tv = Device("tv")
    cli = parse_launch(
        f"testsrc width={w} height={h} ! tensor_converter ! "
        f"tensor_query_client operation=svc transport={transport} name=qc ! "
        f"appsink name=o")
    tv.add_pipeline(cli, jit=False)
    rt.add_device(tv)
    if transport == "tcp":
        # TCP-raw: the explicit IP:port config the paper's R3 removes
        cli.elements["qc"].connect_direct(srv.elements["ssrc"].endpoint)
        srv.elements["ssrc"].endpoint.spec.setdefault(
            "inline_runner", lambda r=hub.runs[0]: rt._run_once(r))
    return rt, srv.elements["ssrc"]


def run(frames: int = 30):
    for band, (h, w) in BANDWIDTHS.items():
        results = {}
        for transport in ("tcp", "hybrid"):
            rt, ssrc = _build(transport, h, w)
            us = time_us(rt.tick, n=frames)
            bpf = ssrc.endpoint.requests.bytes_sent / max(
                ssrc.endpoint.requests.msgs_sent, 1)
            results[transport] = us
            emit(f"query/{band}/{transport}", us,
                 f"req_bytes_per_frame={bpf:.0f}")
        emit(f"query_norm/{band}", 0.0,
             f"hybrid_vs_tcp={results['hybrid'] / results['tcp']:.3f}")


def run_failover(frames: int = 10):
    """Hybrid continues after a server death; measures the failover cost."""
    rt, ssrc1 = _build("hybrid", 120, 160)
    hub2 = Device("hub2")
    model = _ensure_model(120, 160)
    srv2 = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    srv2.elements["ssink"].pair_with(srv2.elements["ssrc"])
    hub2.add_pipeline(srv2, jit=False)
    rt.add_device(hub2)
    rt.run(frames)
    ssrc1.endpoint.alive = False
    rt.broker.mark_down(ssrc1.registration)
    rt.run(frames)
    client_dev = [d for d in rt.devices if d.name == "tv"][0]
    done = client_dev.runs[0].frames
    emit("query/failover", 0.0,
         f"frames_completed={done}/{2 * frames};"
         f"survived_server_death={done == 2 * frames}")


if __name__ == "__main__":
    run()
    run_failover()
