"""Shared benchmark plumbing.

The paper evaluates on RPi4 boards over Ethernet (Fig. 6/7): throughput, CPU
usage, peak memory for three stream bandwidths at 60 Hz.  Here the "network"
is the in-process Channel; we measure (a) host-side cost per frame in µs
(the CPU-usage analogue), (b) wire bytes per frame, and (c) derived
sustainable fps over a modelled 1 Gbps link — broker-relayed transports pay
the relay hop twice, which is exactly the effect Fig. 7 shows.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

LINK_BYTES_PER_S = 125e6        # 1 Gbps Ethernet (RPi4)
TARGET_FPS = 60.0

# the paper's three bandwidths
BANDWIDTHS: Dict[str, Tuple[int, int]] = {
    "low_qqvga": (120, 160),
    "mid_vga": (480, 640),
    "high_fullhd": (1080, 1920),
}


def time_us(fn: Callable, n: int, warmup: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def sustainable_fps(bytes_per_frame: float, relay_hops: int,
                    cpu_us_per_frame: float) -> float:
    """fps over the modelled link: every relay hop re-sends the payload."""
    wire = bytes_per_frame * (1 + relay_hops)
    net_fps = LINK_BYTES_PER_S / max(wire, 1)
    cpu_fps = 1e6 / max(cpu_us_per_frame, 1e-9)
    return min(net_fps, cpu_fps)


# Machine-readable result collection: every emit() lands here as a dict so
# benchmarks/run.py can dump BENCH_PR<k>.json and the perf trajectory is
# tracked across PRs instead of living only in stdout CSV.
ROWS: List[Dict] = []


def reset_rows():
    ROWS.clear()


def emit(name: str, us_per_call: float, derived: str, **fields):
    """Print the legacy CSV line AND record a structured row.

    ``derived`` stays the human-readable summary; ``fields`` carries any
    machine-readable extras (fps, speedups, byte counts, ...).
    """
    print(f"{name},{us_per_call:.1f},{derived}")
    ROWS.append({"name": name, "us_per_call": round(float(us_per_call), 3),
                 "derived": derived, **fields})
