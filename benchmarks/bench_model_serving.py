"""Continuous-batching model serving: decode tokens/sec with 8 concurrent
streams, batched (ONE stateful serve-tick dispatch over the slot table) vs
sequential (one jitted b=1 decode dispatch per stream per step) — the PR-7
tentpole lever (DESIGN.md §7).

GATE: continuous-batched decode must sustain >= 2x the sequential decode
tokens/sec at 8 concurrent streams on the small transformer preset.  The
FLOPs are identical by construction (each slot runs the same b=1 program
the sequential path runs — that is the bitwise-parity contract); the win is
dispatch amortization: 1 serve-tick dispatch per step instead of 8, exactly
the stack-scan lever PR-2 gated for stateless serving, carried to stateful
decode.

Also emitted (ungated): end-to-end runtime tokens/sec with 8 live
streaming client pipelines — prefills, admissions, finish/delivery and the
host edges included.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.launch import model_serve as ms
from repro.runtime import Device, Runtime

from .common import emit

N_STREAMS = 8
MAX_SEQ = 64
GATE_SPEEDUP = 2.0


def _server_run(slots: int):
    rt = Runtime(query_batch=N_STREAMS)
    hub = Device("hub")
    ps = ms.serve_pipeline(model="stablelm-smoke-flash", slots=slots,
                           max_seq=MAX_SEQ)
    run = hub.add_pipeline(ps, jit=False)
    rt.add_device(hub)
    return rt, run, ps.elements["lm"]


def run(steps: int = 20, reps: int = 5):
    rt, srv, elem = _server_run(slots=N_STREAMS)
    params = srv.params["lm"]
    cfg = elem.cfg

    # -- continuous: admit 8 streams into the slot table, then time the
    # steady-state decode tick (remaining is huge so nobody leaves)
    admits = []
    for i in range(N_STREAMS):
        tok, cache = elem.host_prefill(params, [i + 1, i + 2, i + 3])
        admits.append((i, tok, 10 ** 6, cache))
    plan = srv.pipe.plan
    src = plan.query_sources[0].name
    sink = plan.query_sinks[0].name
    serve = plan.compiled_serve_tick(srv.state)
    state = [srv.state]
    outputs, state[0] = serve(srv.params, state[0],
                              {src: elem.build_admit(admits)})
    jax.block_until_ready(outputs[sink].tensors)
    empty = {src: elem.empty_admit()}

    def batched_step():
        outputs, state[0] = serve(srv.params, state[0], empty)
        jax.block_until_ready(outputs[sink].tensors[0])

    # -- sequential: the same 8 streams as 8 independent b=1 jitted decode
    # dispatches per step (the pre-continuous-batching serving shape)
    from repro.models import transformer

    @jax.jit
    def decode(p, tok, cache):
        import jax.numpy as jnp
        logits, cache = transformer.lm_decode(p, cfg, tok[None], cache)
        return jnp.argmax(logits[0], -1).astype(jnp.int32), cache

    streams = []
    for i in range(N_STREAMS):
        tok, cache = elem.host_prefill(params, [i + 1, i + 2, i + 3])
        import jax.numpy as jnp
        streams.append([jnp.int32(tok), cache])

    def sequential_step():
        last = None
        for s in streams:
            s[0], s[1] = decode(params, s[0], s[1])
            last = s[0]
        jax.block_until_ready(last)

    for fn in (batched_step, sequential_step):   # compile + warm
        for _ in range(3):
            fn()

    # interleaved mins: alternate reps so box noise hits both paths alike
    best = {"batched": float("inf"), "sequential": float("inf")}
    for _ in range(reps):
        for label, fn in (("batched", batched_step),
                          ("sequential", sequential_step)):
            t0 = time.perf_counter()
            for _ in range(steps):
                fn()
            best[label] = min(best[label],
                              (time.perf_counter() - t0) / steps)
    tps_batched = N_STREAMS / best["batched"]
    tps_seq = N_STREAMS / best["sequential"]
    speedup = tps_batched / tps_seq
    emit(f"model_serving/decode_tps/batch{N_STREAMS}",
         best["batched"] * 1e6, f"tokens_per_sec={tps_batched:.0f}",
         tokens_per_sec=round(tps_batched, 1))
    emit("model_serving/decode_tps/sequential",
         best["sequential"] * 1e6, f"tokens_per_sec={tps_seq:.0f}",
         tokens_per_sec=round(tps_seq, 1))
    emit("model_serving/speedup", 0.0,
         f"batched_vs_sequential={speedup:.2f}x;gate>={GATE_SPEEDUP}x;"
         f"pass={speedup >= GATE_SPEEDUP}",
         speedup=round(speedup, 3), gate=GATE_SPEEDUP,
         gate_pass=bool(speedup >= GATE_SPEEDUP))

    # -- end-to-end: full runtime with 8 live streaming clients ------------------
    rt2 = Runtime(query_batch=N_STREAMS)
    hub = Device("hub")
    ps2 = ms.serve_pipeline(model="stablelm-smoke-flash", slots=N_STREAMS,
                            max_seq=MAX_SEQ)
    hub.add_pipeline(ps2, jit=False)
    rt2.add_device(hub)
    for i in range(N_STREAMS):
        dev = Device(f"tv{i}")
        dev.add_pipeline(ms.client_pipeline(prompts=f"{i+1},{i+2}",
                                            gens="6"), jit=False)
        rt2.add_device(dev)
    rt2.run(4)                                   # compile + warm
    qb0 = rt2.stats()["query_batching"]["tokens_delivered"]
    t0 = time.perf_counter()
    rt2.run(30)
    dt = time.perf_counter() - t0
    delivered = rt2.stats()["query_batching"]["tokens_delivered"] - qb0
    emit("model_serving/e2e_tokens_per_sec", dt / max(delivered, 1) * 1e6,
         f"tokens_per_sec={delivered / dt:.0f};delivered={delivered}",
         tokens_per_sec=round(delivered / dt, 1))

    if speedup < GATE_SPEEDUP:
        raise AssertionError(
            f"model serving gate failed: continuous-batched decode is "
            f"{speedup:.2f}x sequential (must be >= {GATE_SPEEDUP}x)")


if __name__ == "__main__":
    from .common import reset_rows
    reset_rows()
    run()
