"""Failover fabric: ticks-to-recovery and heartbeat steady-state cost
(DESIGN.md §3 — the PR-3 tentpole gates).

Three measurements:

* **in-flight failover** — a serving device dies mid-batch with requests
  stranded on it; counts redispatches and asserts zero client-visible loss
  (every tick answered for every client, fault or not);
* **ticks-to-recovery** — the ONLY server dies, clients park; after the
  replacement's register event, how many scheduler ticks until every parked
  frame has its answer.  GATE: <= 2 ticks;
* **heartbeat penalty** — steady-state µs/tick of the identical workload
  with the lease/heartbeat protocol on vs off (fps cost of liveness).
"""
from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.runtime import Device, Runtime

from .common import emit

# reuse the deterministic chaos harness's fault primitives (tick-scripted
# kills/revivals, mid-batch tripwire) so the benchmark gates on exactly the
# fault semantics the tests exercise — no second copy to drift
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from chaoslib import Chaos  # noqa: E402

GATE_RECOVERY_TICKS = 2
N_CLIENTS = 4


def _ensure_model():
    key = "failover_svc"

    def init(rng):
        return {"w": jax.random.normal(rng, (12, 4)) * 0.3}

    def apply(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    register_model(key, init, apply,
                   out_specs=(TensorSpec((1, 4), "float32"),))
    return key


def _server(rt, name="hub"):
    model = _ensure_model()
    dev = Device(name)
    ps = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return dev, run, ps.elements["ssrc"]


def _clients(rt, n):
    runs = []
    for i in range(n):
        dev = Device(f"tv{i}")
        pc = parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_query_client operation=svc name=qc ! appsink name=res")
        runs.append(dev.add_pipeline(pc, jit=False))
        rt.add_device(dev)
    return runs


def bench_inflight_failover(ticks: int = 10, kill_tick: int = 5):
    rt = Runtime(query_batch=8)
    devA, _, ssrcA = _server(rt, "hubA")
    _server(rt, "hubB")
    clients = _clients(rt, N_CLIENTS)
    # die mid-batch: the kill lands after the 2nd request of the kill tick
    # is already on hubA's queue — the remaining dispatches and the two
    # orphans must re-route to hubB inside the same tick
    harness = Chaos(rt)
    harness.kill_server_mid_batch(kill_tick, devA, ssrcA, after_n=2)
    harness.run(ticks)
    lost = sum(ticks - c.frames for c in clients)
    fo = rt.stats()["failover"]
    emit("failover/inflight", 0.0,
         f"redispatches={fo['redispatches']};lost_requests={lost};"
         f"zero_loss={lost == 0}",
         redispatches=fo["redispatches"], lost=lost,
         zero_loss=bool(lost == 0))
    if lost:
        raise AssertionError(f"in-flight failover lost {lost} requests")


def bench_ticks_to_recovery(kill_tick: int = 4, dead_ticks: int = 3):
    rt = Runtime(query_batch=8, lease_ticks=3)
    dev, _, ssrc = _server(rt)
    clients = _clients(rt, N_CLIENTS)
    harness = Chaos(rt)
    harness.kill_server(kill_tick + 1, dev, ssrc, crash=True)
    harness.run(kill_tick + dead_ticks)      # everything parks
    parked = rt.stats()["failover"]["parked_now"]
    harness._revive(dev, ssrc)               # the register event
    recovery = 0
    while rt.stats()["failover"]["parked_now"] and \
            recovery <= GATE_RECOVERY_TICKS + 1:
        rt.tick()
        recovery += 1
    done = rt.stats()["failover"]["parked_now"] == 0
    emit("failover/ticks_to_recovery", 0.0,
         f"parked={parked};recovery_ticks={recovery};"
         f"gate<={GATE_RECOVERY_TICKS};pass={done and recovery <= GATE_RECOVERY_TICKS}",
         parked=parked, recovery_ticks=recovery,
         gate=GATE_RECOVERY_TICKS,
         gate_pass=bool(done and recovery <= GATE_RECOVERY_TICKS))
    if not done or recovery > GATE_RECOVERY_TICKS:
        raise AssertionError(
            f"recovery took {recovery} ticks (> {GATE_RECOVERY_TICKS}) "
            f"or frames still parked")


def bench_heartbeat_penalty(rounds: int = 4, chunk: int = 10):
    """Interleave timed chunks of two identical workloads (leases on / off)
    and keep the per-config minimum — back-to-back whole-run timing is
    dominated by process drift (GC, allocator), not by the heartbeats."""
    rts = {}
    for label, lease in (("leased", 2), ("no_lease", None)):
        rt = Runtime(query_batch=8, lease_ticks=lease)
        _server(rt)
        _clients(rt, N_CLIENTS)
        rt.run(5)                            # warm compile caches
        rts[label] = rt
    best = {label: float("inf") for label in rts}
    for _ in range(rounds):
        for label, rt in rts.items():
            t0 = time.perf_counter()
            rt.run(chunk)
            best[label] = min(best[label],
                              (time.perf_counter() - t0) / chunk * 1e6)
    for label, us in best.items():
        emit(f"failover/heartbeat/{label}", us, f"us_per_tick={us:.1f}")
    penalty = best["leased"] / best["no_lease"]
    emit("failover/heartbeat/penalty", 0.0,
         f"leased_vs_unleased={penalty:.3f}x",
         penalty=round(penalty, 4))


def run():
    bench_inflight_failover()
    bench_ticks_to_recovery()
    bench_heartbeat_penalty()


if __name__ == "__main__":
    run()
