"""Host-side dispatch overhead: seed per-frame interpreter vs the compiled
execution plan vs scan-batched bursts (the ISSUE-1 tentpole win).

The paper's Fig. 6/7 "CPU usage" axis is host work per frame; NNStreamer
keeps it near zero by compiling the pipeline graph once and streaming
buffers through it.  This benchmark measures µs/frame on a 9-element
pipeline (the Listing-1 shape: src ! tee ! 2 branches ! compositor-free
linear tail) under four regimes:

  * ``seed_interp``   — the seed ``Pipeline.step`` loop: un-jitted, re-sorts
                        links and rebuilds dicts every frame (what the seed
                        Runtime actually executed per tick);
  * ``seed_jit``      — ``jax.jit`` around the seed loop: one dispatch per
                        frame, tracing cost amortized;
  * ``plan_jit``      — the cached compiled plan executable, one dispatch
                        per frame;
  * ``plan_burst8``   — ``step_n`` with burst 8: ONE dispatch per 8 frames
                        via ``lax.scan``.

Acceptance: plan_burst8 must be ≥2× lower µs/frame than the seed per-frame
loop (both baselines reported; the jitted one is the harder target).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model

from .common import emit, time_us

BURST = 8

PIPELINE = """
    testsrc name=cam width=32 height=32 ! videoconvert ! videoscale !
      video/x-raw,width=16,height=16,format=RGB !
      tensor_converter !
      tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 !
      tensor_filter model=benchcls ! tensor_decoder mode=classification !
      appsink name=out
"""


def _register():
    def init(rng):
        return {"w": jax.random.normal(rng, (768, 16)) * 0.05}

    def apply(p, x):
        return x.reshape(1, -1) @ p["w"]

    register_model("benchcls", init, apply,
                   out_specs=(TensorSpec((1, 16), "float32"),))


def _block(tree):
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, tree)


def run(frames: int = 60):
    _register()
    pipe = parse_launch(PIPELINE).realize()
    n_elems = len(pipe.elements)
    assert n_elems >= 6, f"need a ≥6-element pipeline, got {n_elems}"
    params = pipe.init(jax.random.PRNGKey(0))
    s0 = pipe.init_state()

    results = {}

    # -- seed interpreter, un-jitted (what the seed Runtime ran per tick) ----
    state = dict(s0)

    def seed_interp():
        nonlocal state
        outs, state = pipe.step_interpreted(params, state)
        _block(outs)

    results["seed_interp"] = time_us(seed_interp, n=frames)

    # -- seed loop under jit: per-frame dispatch --------------------------------
    state = dict(s0)
    jit_seed = jax.jit(pipe.step_interpreted)

    def seed_jit():
        nonlocal state
        outs, state = jit_seed(params, state)
        _block(outs)

    results["seed_jit"] = time_us(seed_jit, n=frames)

    # -- compiled plan: per-frame dispatch --------------------------------------
    state = dict(s0)
    compiled = pipe.compiled_step()

    def plan_jit():
        nonlocal state
        outs, state = compiled(params, state)
        _block(outs)

    results["plan_jit"] = time_us(plan_jit, n=frames)

    # -- compiled plan, scan-batched burst: one dispatch per BURST frames -------
    state = dict(s0)
    step_n = pipe.compiled_step_n()

    def plan_burst():
        nonlocal state
        outs, state = step_n(params, state, n=BURST)
        _block(outs)

    results["plan_burst8"] = time_us(plan_burst, n=max(1, frames // BURST)) / BURST

    speed_interp = results["seed_interp"] / results["plan_burst8"]
    speed_jit = results["seed_jit"] / results["plan_burst8"]
    for name, us in results.items():
        extra = {"elements": n_elems, "burst": BURST if "burst" in name else 1}
        if name == "plan_burst8":
            extra.update(speedup_vs_seed_interp=round(speed_interp, 2),
                         speedup_vs_seed_jit=round(speed_jit, 2))
        emit(f"step_overhead/{name}", us,
             f"us_per_frame={us:.1f};elements={n_elems}", **extra)
    emit("step_overhead/speedup", speed_interp,
         f"burst8_vs_seed_interp={speed_interp:.1f}x;"
         f"burst8_vs_seed_jit={speed_jit:.1f}x;target>=2x",
         speedup_vs_seed_interp=round(speed_interp, 2),
         speedup_vs_seed_jit=round(speed_jit, 2), target=2.0)
    assert speed_interp >= 2.0, (
        f"compiled burst-8 must be ≥2× faster than the seed per-frame loop; "
        f"got {speed_interp:.2f}×")
    return results


if __name__ == "__main__":
    run()
