"""Compressed stream transmission (paper §3/§4.1): sparse tensor streams and
quant8 for language/speech activation streams — wire bytes + codec cost.

The paper: "some clients have explicitly requested sparse tensor streams to
compress streams for language and speech models".  We measure a
transformer-activation-shaped stream at several sparsity levels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import StreamBuffer
from repro.core import compression as comp

from .common import emit, time_us

SHAPE = (64, 1024)  # one frame of LM activations (seq x d)


def _frame(sparsity: float) -> StreamBuffer:
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, SHAPE)
    if sparsity > 0:
        keep = jax.random.uniform(k2, SHAPE) >= sparsity
        x = jnp.where(keep, x, 0.0)
    return StreamBuffer(tensors=(x,))


def run():
    for sparsity in (0.0, 0.75, 0.9):
        buf = _frame(sparsity)
        raw = buf.nbytes()
        density = max(0.05, round((1 - sparsity) * 1.25, 2))
        for codec in ("none", "quant8", f"sparse:{density}"):
            if codec.startswith("sparse") and sparsity == 0.0:
                continue  # dense payload: COO framing would expand
            us = time_us(lambda: jax.block_until_ready(
                comp.encode(buf, codec)[0].tensors), n=10)
            _, nbytes = comp.encode(buf, codec)
            # verify lossless reconstruction within codec tolerance
            dec = comp.decode(comp.encode(buf, codec)[0], codec)
            assert dec.tensors[0].shape == SHAPE
            emit(f"compress/sparsity{sparsity}/{codec}", us,
                 f"wire_bytes={nbytes};ratio={raw / max(nbytes, 1):.2f}x")


if __name__ == "__main__":
    run()
