"""Timestamp synchronization (paper §4.2.3): inter-source timestamp error
with vs without the NTP base-time mechanism, under injected clock skew —
the paper's queue2-latency experiment.
"""
from __future__ import annotations

import numpy as np

from repro.core import SimClock, StreamBuffer, ntp_offset
from repro.core.sync import PipelineClock

from .common import emit


def run(n_frames: int = 50, skew_ms: float = 50.0):
    skew_ns = int(skew_ms * 1e6)
    # two publishers: one true clock, one skewed; a subscriber rebases both
    sub = PipelineClock(SimClock(skew_ns=0)).start()
    pubs = []
    for skew in (0, skew_ns):
        clk = SimClock(skew_ns=skew, jitter_ns=20_000, seed=skew & 1023)
        ref = SimClock()
        pc = PipelineClock(clk).calibrate(ref)
        pc.start()
        pubs.append(pc)

    err_sync, err_raw = [], []
    for i in range(n_frames):
        for pc in pubs:
            pc.clock.advance(16_666_667)
        sub.clock.advance(16_666_667)
        pts = []
        pts_raw = []
        for pc in pubs:
            rel = pc.running_time()
            buf = StreamBuffer(tensors=(np.zeros(1),), pts=np.int64(rel),
                               meta={"base_time_utc": pc.base_time_utc()})
            pts.append(int(sub.rebase(buf).pts))
            # without sync: subscriber uses the publisher's local wall clock
            pts_raw.append(pc.clock.now())
        err_sync.append(abs(pts[0] - pts[1]))
        err_raw.append(abs(pts_raw[0] - pts_raw[1]))

    emit("sync/no_ntp", 0.0,
         f"mean_pairwise_skew_ms={np.mean(err_raw) / 1e6:.3f}")
    emit("sync/ntp_rebase", 0.0,
         f"mean_pairwise_skew_ms={np.mean(err_sync) / 1e6:.3f};"
         f"improvement={np.mean(err_raw) / max(np.mean(err_sync), 1):.0f}x")


if __name__ == "__main__":
    run()
