"""Pipeline-parallel serving as among-device hops: staged steady-state
decode tokens/sec vs the single-device full model (DESIGN.md §8).

GATE: 2-stage steady-state tokens/sec >= 1.5x the single-device full-model
serve tick at 8 concurrent streams on an 8-layer bench preset.

Steady-state model (GPipe): the N stage devices run CONCURRENTLY — while
stage 1 decodes step t's boundary activations, stage 0 is already decoding
step t+1 — so once the pipeline fills, the chain emits one 8-stream step
every max_k(stage-tick time), not every sum_k.  The in-process harness
executes hops sequentially (one simulated device pool), so the gated
number is the measured per-stage serve-tick time under the pipelined
model: ``S / max_k t_k`` vs the monolithic ``S / t_full``.  The layer
FLOPs split evenly by construction (stage k owns R/N layers; embed and
unembed ride the end stages), so the gate passes exactly when per-stage
dispatch overhead stays well under half the full-model tick — the same
dispatch-amortization lever the §7 bench gates, measured per hop.

Also emitted (ungated): per-stage tick micros, and end-to-end runtime
tokens/sec of the live 2-stage chain with 8 streaming clients — prefill
chains, hop round-trips, per-stage codec edges and delivery included.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.modelserve import SERVE_MODELS, register_serve_model
from repro.launch import model_serve as ms
from repro.runtime import Device, Runtime

from .common import emit

N_STREAMS = 8
N_STAGES = 2
MAX_SEQ = 64
GATE_SPEEDUP = 1.5
BENCH_MODEL = "stablelm-bench-8l"


def _bench_8l():
    """8-layer smoke variant: deep enough that per-layer compute, not
    fixed per-dispatch overhead, dominates a stage tick — the regime the
    pipelined gate is about (a 2-layer stage would just measure jit
    dispatch latency)."""
    return dataclasses.replace(SERVE_MODELS["stablelm-smoke"](), n_layers=8)


if BENCH_MODEL not in SERVE_MODELS:
    register_serve_model(BENCH_MODEL, _bench_8l)


def _stage_run(stage: int):
    rt = Runtime(query_batch=N_STREAMS)
    dev = Device(f"stage{stage}")
    ps = ms.stage_pipeline(model=BENCH_MODEL, slots=N_STREAMS,
                           max_seq=MAX_SEQ, stage=stage, n_stages=N_STAGES)
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return rt, run, ps.elements["lm"]


def _steady_state_step(run, elem, x_in, seed_fn):
    """Fill the stage's slot table with 8 live streams, then return a
    timed steady-state (no-join) decode-hop closure.  ``seed_fn`` maps a
    prompt to this stage's prefill input (the prompt itself on stage 0,
    upstream boundary activations downstream)."""
    params = run.params["lm"]
    plan = run.pipe.plan
    src = plan.query_sources[0].name
    sink = plan.query_sinks[0].name
    admits = []
    for i in range(N_STREAMS):
        prompt = np.asarray([i + 1, i + 2, i + 3], np.int32)
        _, cache = elem.host_stage_prefill(params, seed_fn(prompt))
        admits.append((i, cache))
    active = np.ones((N_STREAMS,), np.bool_)
    serve = plan.compiled_serve_tick(run.state)
    state = [run.state]
    outputs, state[0] = serve(run.params, state[0],
                              {src: elem.build_hop(x_in, active, admits)})
    jax.block_until_ready(outputs[sink].tensors)
    empty = {src: elem.build_hop(x_in, active, [])}

    def step():
        outputs, state[0] = serve(run.params, state[0], empty)
        jax.block_until_ready(outputs[sink].tensors[0])
    return step


def run(steps: int = 20, reps: int = 5):
    from repro.models import transformer

    # -- per-stage steady-state serve ticks -----------------------------------
    rt0, run0, elem0 = _stage_run(0)
    params0, cfg = run0.params["lm"], elem0.cfg

    def acts_from_prompt(prompt):
        x, _ = transformer.stage_prefill(params0, cfg, 0, N_STAGES,
                                         np.asarray(prompt, np.int32)[None],
                                         MAX_SEQ)
        return x

    tok_in = np.arange(1, N_STREAMS + 1, dtype=np.int32)
    step0 = _steady_state_step(run0, elem0, tok_in, lambda p: p)
    # stage 1's steady-state input: stage 0's per-slot boundary acts
    acts_in = np.zeros((N_STREAMS, 1, cfg.d_model), np.float32)
    for i in range(N_STREAMS):
        _, c = transformer.stage_prefill(
            params0, cfg, 0, N_STAGES,
            np.asarray([[i + 1, i + 2, i + 3]], np.int32), MAX_SEQ)
        y, _ = transformer.stage_decode(params0, cfg, 0, N_STAGES,
                                        np.asarray([1], np.int32), c)
        acts_in[i] = np.asarray(y[0])

    rt1, run1, elem1 = _stage_run(1)
    step1 = _steady_state_step(run1, elem1, acts_in, acts_from_prompt)

    # -- single-device full model (the §7 monolithic serve tick) --------------
    rtm = Runtime(query_batch=N_STREAMS)
    hub = Device("hub")
    psm = ms.serve_pipeline(model=BENCH_MODEL, slots=N_STREAMS,
                            max_seq=MAX_SEQ)
    runm = hub.add_pipeline(psm, jit=False)
    rtm.add_device(hub)
    elemm = psm.elements["lm"]
    paramsm = runm.params["lm"]
    admits = []
    for i in range(N_STREAMS):
        tok, cache = elemm.host_prefill(paramsm, [i + 1, i + 2, i + 3])
        admits.append((i, tok, 10 ** 6, cache))
    plan = runm.pipe.plan
    src = plan.query_sources[0].name
    sink = plan.query_sinks[0].name
    serve = plan.compiled_serve_tick(runm.state)
    state = [runm.state]
    outputs, state[0] = serve(runm.params, state[0],
                              {src: elemm.build_admit(admits)})
    jax.block_until_ready(outputs[sink].tensors)
    empty = {src: elemm.empty_admit()}

    def step_mono():
        outputs, state[0] = serve(runm.params, state[0], empty)
        jax.block_until_ready(outputs[sink].tensors[0])

    stages = {"stage0": step0, "stage1": step1, "mono": step_mono}
    for fn in stages.values():                   # compile + warm
        for _ in range(3):
            fn()
    # interleaved mins: alternate reps so box noise hits all paths alike
    best = {k: float("inf") for k in stages}
    for _ in range(reps):
        for label, fn in stages.items():
            t0 = time.perf_counter()
            for _ in range(steps):
                fn()
            best[label] = min(best[label],
                              (time.perf_counter() - t0) / steps)

    t_stage_max = max(best["stage0"], best["stage1"])
    tps_staged = N_STREAMS / t_stage_max         # pipelined steady state
    tps_mono = N_STREAMS / best["mono"]
    speedup = tps_staged / tps_mono
    for k in ("stage0", "stage1"):
        emit(f"pp_serving/stage_tick/{k}", best[k] * 1e6,
             f"tokens_per_sec={N_STREAMS / best[k]:.0f}",
             tokens_per_sec=round(N_STREAMS / best[k], 1))
    emit(f"pp_serving/decode_tps/staged{N_STAGES}", t_stage_max * 1e6,
         f"tokens_per_sec={tps_staged:.0f};pipelined=S/max_stage_tick",
         tokens_per_sec=round(tps_staged, 1))
    emit("pp_serving/decode_tps/mono", best["mono"] * 1e6,
         f"tokens_per_sec={tps_mono:.0f}",
         tokens_per_sec=round(tps_mono, 1))
    emit("pp_serving/speedup", 0.0,
         f"staged{N_STAGES}_vs_mono={speedup:.2f}x;gate>={GATE_SPEEDUP}x;"
         f"pass={speedup >= GATE_SPEEDUP}",
         speedup=round(speedup, 3), gate=GATE_SPEEDUP,
         gate_pass=bool(speedup >= GATE_SPEEDUP))

    # -- end-to-end: the live 2-stage chain with 8 streaming clients ----------
    rt = Runtime(query_batch=N_STREAMS)
    for k, ps in enumerate(ms.staged_serve_pipelines(
            model=BENCH_MODEL, slots=N_STREAMS, max_seq=MAX_SEQ,
            n_stages=N_STAGES)):
        dev = Device(f"stage{k}")
        dev.add_pipeline(ps, jit=False)
        rt.add_device(dev)
    for i in range(N_STREAMS):
        dev = Device(f"tv{i}")
        dev.add_pipeline(ms.client_pipeline(prompts=f"{i+1},{i+2}",
                                            gens="6"), jit=False)
        rt.add_device(dev)
    rt.run(4)                                    # compile + warm
    qb0 = rt.stats()["query_batching"]["tokens_delivered"]
    t0 = time.perf_counter()
    rt.run(30)
    dt = time.perf_counter() - t0
    delivered = rt.stats()["query_batching"]["tokens_delivered"] - qb0
    emit("pp_serving/e2e_tokens_per_sec", dt / max(delivered, 1) * 1e6,
         f"tokens_per_sec={delivered / dt:.0f};delivered={delivered}",
         tokens_per_sec=round(delivered / dt, 1))

    if speedup < GATE_SPEEDUP:
        raise AssertionError(
            f"pp serving gate failed: staged steady-state decode is "
            f"{speedup:.2f}x the single-device full model "
            f"(must be >= {GATE_SPEEDUP}x)")


if __name__ == "__main__":
    from .common import reset_rows
    reset_rows()
    run()
