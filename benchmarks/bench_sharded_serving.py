"""Mesh-sharded serving: server frames/sec with 8 concurrent clients on an
8-way host mesh — the PR-4 tentpole lever on top of PR 2's micro-batching.

Four serving modes, measured on the serving path itself (requests
pre-queued, flush timed, exactly like bench_query_batching), interleaved
round-robin so host load drift hits all of them equally:

* ``mesh_auto``  — the production config: ``Runtime(mesh=...)`` with the
                   calibrated placement (probe sharded-vs-single per batch
                   size, keep the faster — core/batching.py);
* ``sharded``    — the sharded executable FORCED (``shard_mode="always"``):
                   batch-8 laid out along the mesh's data axes, one frame
                   slice per device;
* ``batched``    — batch-8 flush on a single device (the PR-2 path);
* ``sequential`` — one interpreted round-trip per request (the paper's
                   Fig. 2 baseline).

GATE: batch-8 serving on the 8-way host mesh (``mesh_auto``, the config a
deployment actually runs) must sustain >= 2x the sequential server
frames/sec.  The forced-sharded ratios are reported alongside
(``sharded_vs_sequential``, ``shard_vs_batched``): on real multi-chip
meshes they are the win, on a host-forged mesh (8 "devices" timeshared on
a couple of cores) SPMD dispatch overhead makes them < 1 — which is
exactly the dispatch-vs-silicon gap the calibrated placement exists to
absorb, and why the gate is on the calibrated path.

XLA fixes the device count at backend init, and benchmarks/run.py runs many
suites in one process that must see the host as-is — so when this process
has fewer than 2 devices the measurement re-executes itself in a subprocess
with ``--xla_force_host_platform_device_count=8`` and adopts its rows.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from .common import emit

N_CLIENTS = 8
N_DEVICES = 8
GATE_SPEEDUP = 2.0
_SENTINEL = "BENCH_SHARDED_ROWS_JSON:"


def _ensure_model(d: int = 192):
    import jax
    import jax.numpy as jnp
    from repro.core import TensorSpec
    from repro.core.elements import register_model

    key = f"shard_mlp_{d}"

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"w1": jax.random.normal(k1, (d, d)) * 0.05,
                "w2": jax.random.normal(k2, (d, 16)) * 0.05}

    def apply(p, x):
        h = jnp.tanh(x.astype(jnp.float32).reshape(1, -1) @ p["w1"])
        return h @ p["w2"]

    register_model(key, init, apply,
                   out_specs=(TensorSpec((1, 16), "float32"),))
    return key


def _build(query_batch: int, mesh, d: int, shard_mode: str = "auto"):
    from repro.core import parse_launch
    from repro.runtime import Device, Runtime

    rt = Runtime(query_batch=query_batch, mesh=mesh, shard_mode=shard_mode)
    model = _ensure_model(d)
    hub = Device("hub")
    srv = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    srv.elements["ssink"].pair_with(srv.elements["ssrc"])
    srv_run = hub.add_pipeline(srv, jit=False)
    rt.add_device(hub)
    qcs = []
    for i in range(N_CLIENTS):
        dev = Device(f"tv{i}")
        cli = parse_launch(
            f"testsrc width={d // 3} height=1 ! tensor_converter ! "
            f"tensor_query_client operation=svc name=qc ! appsink name=o")
        dev.add_pipeline(cli, jit=False)
        rt.add_device(dev)
        qcs.append(cli.elements["qc"])
    return rt, srv_run, qcs


def _round_fn(rt, qcs, d: int):
    """One serving round: queue one request per client, flush the batch."""
    import jax.numpy as jnp
    from repro.core.buffers import StreamBuffer

    batcher = next(iter(rt._batchers.values()))
    frame = StreamBuffer(tensors=(jnp.arange(d, dtype=jnp.float32) / d,),
                         pts=jnp.int32(0))

    def one_round():
        for qc in qcs:
            qc.send_query(frame)
        batcher.flush()

    def drain():
        for qc in qcs:
            while qc.recv_answer() is not None:
                pass
    return one_round, drain


def _interleaved_medians(entries, rounds: int, warmup: int = 5):
    """Time each mode's rounds ROUND-ROBIN and report the median round per
    mode.  The host-mesh CI box forges 8 devices on very few, noisily
    shared cores: load drift between two back-to-back measurement windows
    swings 2x+, so separate windows would measure the machine, not the
    serving paths.  Interleaving exposes every mode to the same drift;
    the median discards the scheduler spikes."""
    times = {name: [] for name, _, _ in entries}
    for name, one_round, _ in entries:
        for _ in range(warmup):
            one_round()
    for _ in range(rounds):
        for name, one_round, _ in entries:
            t0 = time.perf_counter()
            one_round()
            times[name].append(time.perf_counter() - t0)
    for _, _, drain in entries:
        drain()
    return {name: sorted(ts)[len(ts) // 2] for name, ts in times.items()}


def _measure(rounds: int = 30, d: int = 192):
    """Requires >= 2 local devices; returns the structured rows."""
    import jax
    from repro.launch.mesh import data_axis_size, make_host_mesh

    mesh = make_host_mesh()
    dsize = data_axis_size(mesh)
    rows = []

    rt_a, _, qcs_a = _build(N_CLIENTS, mesh, d, shard_mode="auto")
    rt_sh, _, qcs_sh = _build(N_CLIENTS, mesh, d, shard_mode="always")
    rt_b, _, qcs_b = _build(N_CLIENTS, None, d)
    rt_s, _, qcs_s = _build(0, None, d)
    meds = _interleaved_medians(
        [("mesh_auto", *_round_fn(rt_a, qcs_a, d)),
         ("sharded", *_round_fn(rt_sh, qcs_sh, d)),
         ("batched", *_round_fn(rt_b, qcs_b, d)),
         ("sequential", *_round_fn(rt_s, qcs_s, d))], rounds)
    fps_auto = N_CLIENTS / meds["mesh_auto"]
    fps_sharded = N_CLIENTS / meds["sharded"]
    fps_batched = N_CLIENTS / meds["batched"]
    fps_seq = N_CLIENTS / meds["sequential"]
    assert rt_sh.stats()["query_batching"]["sharded_frames"] > 0, \
        "forced mesh path never engaged"
    placement = next(iter(rt_a._batchers.values())).placements.get(
        N_CLIENTS, "single")

    speedup = fps_auto / fps_seq
    rows.append(dict(
        name=f"sharded_serving/serving_fps/mesh{dsize}_auto_batch{N_CLIENTS}",
        us=1e6 / fps_auto, derived=(f"frames_per_sec={fps_auto:.0f};"
                                    f"placement={placement}"),
        fps=round(fps_auto, 1), devices=dsize, placement=placement))
    rows.append(dict(
        name=f"sharded_serving/serving_fps/mesh{dsize}_forced_batch{N_CLIENTS}",
        us=1e6 / fps_sharded, derived=f"frames_per_sec={fps_sharded:.0f}",
        fps=round(fps_sharded, 1), devices=dsize))
    rows.append(dict(
        name="sharded_serving/serving_fps/single_device_batch",
        us=1e6 / fps_batched, derived=f"frames_per_sec={fps_batched:.0f}",
        fps=round(fps_batched, 1)))
    rows.append(dict(
        name="sharded_serving/serving_fps/sequential",
        us=1e6 / fps_seq, derived=f"frames_per_sec={fps_seq:.0f}",
        fps=round(fps_seq, 1)))
    rows.append(dict(
        name="sharded_serving/speedup", us=0.0,
        derived=(f"mesh_auto_vs_sequential={speedup:.2f}x;gate>=2x;"
                 f"pass={speedup >= GATE_SPEEDUP}"),
        speedup=round(speedup, 3), gate=GATE_SPEEDUP,
        gate_pass=bool(speedup >= GATE_SPEEDUP),
        sharded_vs_sequential=round(fps_sharded / fps_seq, 3),
        shard_vs_batched=round(fps_sharded / fps_batched, 3)))
    return rows


def _measure_subprocess(rounds: int):
    """Re-exec with forged devices; adopt the child's rows."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (flags +
                        f" --xla_force_host_platform_device_count={N_DEVICES}"
                        ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded_serving",
         "--rounds", str(rounds)],
        capture_output=True, text=True, env=env, timeout=560,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    for line in out.stdout.splitlines():
        if line.startswith(_SENTINEL):
            return json.loads(line[len(_SENTINEL):])
    raise RuntimeError(
        f"sharded-serving subprocess produced no rows\nstdout:\n{out.stdout}"
        f"\nstderr:\n{out.stderr}")


def run(rounds: int = 30):
    import jax
    if len(jax.devices()) >= 2:
        rows = _measure(rounds)
    else:
        rows = _measure_subprocess(rounds)
    gate_row = None
    for r in rows:
        fields = {k: v for k, v in r.items()
                  if k not in ("name", "us", "derived")}
        emit(r["name"], r["us"], r["derived"], **fields)
        if r["name"].endswith("/speedup"):
            gate_row = r
    if gate_row is None or not gate_row["gate_pass"]:
        got = gate_row and gate_row["speedup"]
        raise AssertionError(
            f"sharded serving gate failed: {got}x < {GATE_SPEEDUP}x")


if __name__ == "__main__":
    rounds = 30
    if "--rounds" in sys.argv:
        rounds = int(sys.argv[sys.argv.index("--rounds") + 1])
    rows = _measure(rounds)
    print(_SENTINEL + json.dumps(rows))
