"""Pipeline-parallel serving as among-device hops (DESIGN.md §8).

The model's layer stack splits into N ``model_serve_stage`` pipelines —
stage k owns layers [k*R/N, (k+1)*R/N) plus its slice of the slot-stacked
decode-cache plan state — and stage k's per-slot boundary activations
stream to stage k+1 over the SAME pub/sub + query fabric clients use:
broker discovery ranks stages, leases detect stage death, §6 reconfig
covers stage swap.  The acceptance contract pinned here:

* staged decode at N ∈ {2, 4} is BITWISE the single-stage ``model_serve``
  answer AND the per-request sequential decode, at batch 1, 4 and 8,
  including mid-generation joins and leaves;
* the staged hop chain computes the same tokens pp_serve's shard_map step
  does (the intra-process pipeline-parallel reference) — same split, two
  transports;
* killing a MID-CHAIN stage mid-generation loses zero tokens and replays
  ONLY that stage's cache slice: the coordinator re-runs the dead stage's
  retained boundary activations through a standby's prefill/replay verbs
  (never a whole-generation restart — ``prefills`` stays equal to
  ``streams_started``), and every answer is bitwise the fault-free twin's;
* a §6 hot swap of a downstream stage bumps its epoch fence and recovers
  through the SAME stage-local replay rule, bitwise;
* conservation holds per stage — ``hops_dispatched[k] == hops_completed[k]
  + hops_failed[k]`` — and the §7 token law holds at the coordinator
  (soak).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batching import StagedStreamingBatcher
from repro.core.element import element_factory
from repro.launch import model_serve as ms
from repro.runtime import Device, Runtime

pytestmark = pytest.mark.ppstage

MAX_SEQ = 32
MODEL = "stablelm-smoke-4l"


def _staged(rt, n_stages, slots=8, prefix="stage"):
    """One device per stage — the among-device chain.  Every stage inits
    from PRNGKey(0) and slices the SAME full tree, so any standby stage's
    params are bitwise the original's."""
    out = []
    for k, ps in enumerate(ms.staged_serve_pipelines(
            model=MODEL, slots=slots, max_seq=MAX_SEQ, n_stages=n_stages)):
        dev = Device(f"{prefix}{k}")
        out.append((dev, dev.add_pipeline(ps, jit=False), ps))
        rt.add_device(dev)
    return out


def _standby(rt, stage, n_stages, slots=8, name="standby"):
    dev = Device(f"{name}{stage}")
    ps = ms.stage_pipeline(model=MODEL, slots=slots, max_seq=MAX_SEQ,
                           stage=stage, n_stages=n_stages)
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return dev, run, ps


def _mono(rt, slots=8):
    dev = Device("hub")
    ps = ms.serve_pipeline(model=MODEL, slots=slots, max_seq=MAX_SEQ)
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return dev, run, ps


def _client(rt, i, prompts, gens):
    dev = Device(f"tv{i}")
    run = dev.add_pipeline(ms.client_pipeline(prompts=prompts, gens=gens),
                           jit=False)
    rt.add_device(dev)
    return run


def _answers(run):
    return [np.asarray(b.tensor).tolist() for b in run.sink_log.get("res", [])]


def _coord(rt) -> StagedStreamingBatcher:
    (b,) = [b for b in rt._batchers.values()
            if isinstance(b, StagedStreamingBatcher)]
    return b


_REF_CACHE = {}


def _ref(params, cfg, prompt, gen):
    key = (id(params), tuple(prompt), gen)
    if key not in _REF_CACHE:
        _REF_CACHE[key] = (params, ms.sequential_decode(params, cfg, prompt,
                                                        gen, MAX_SEQ))
    return _REF_CACHE[key][1]


def _assert_conservation(coord: StagedStreamingBatcher):
    st = coord.stats()
    assert st["tokens_generated"] == st["tokens_delivered"] + \
        st["tokens_dropped"] + st["tokens_in_flight"]
    for k in range(1, coord.n_stages):
        led = coord.stage_ledger(k)
        assert led["dispatched"] == led["completed"] + led["failed"], (k, led)


class TestStagedParity:
    @pytest.mark.parametrize("n_stages", [2, 4])
    @pytest.mark.parametrize("n_clients", [1, 4, 8])
    def test_bitwise_vs_sequential_decode(self, n_stages, n_clients):
        """THE tentpole pin: every answer the N-stage chain delivers is
        bitwise the per-request sequential decode of the FULL model —
        splitting the layer stack across among-device hops changes where
        compute happens, never what it computes."""
        gen_mix = ["4", "3;6", "5", "6;3"]
        rt = Runtime(query_batch=8)
        _staged(rt, n_stages)
        cls = [( _client(rt, i, f"{i+1},{i+2},{i+3}",
                         gen_mix[i % len(gen_mix)]), i)
               for i in range(n_clients)]
        rt2 = Runtime(query_batch=8)
        _, mrun, mps = _mono(rt2)
        rt.run(16)
        params, cfg = mrun.params["lm"], mps.elements["lm"].cfg
        for run, i in cls:
            got = _answers(run)
            assert len(got) >= 2
            gens = [int(g) for g in gen_mix[i % len(gen_mix)].split(";")]
            for j, ans in enumerate(got):
                ref = _ref(params, cfg, [i + 1, i + 2, i + 3],
                           gens[j % len(gens)])
                assert ans == ref, f"client {i} answer {j}: {ans} != {ref}"
        _assert_conservation(_coord(rt))

    def test_staged_answers_match_monolithic_runtime(self):
        """Same clients, same ticks, two fabrics: the 2-stage chain's full
        answer streams are bitwise the single-stage ``model_serve``
        runtime's — transport-level equivalence, not just per-answer."""
        outs = []
        for build in ("staged", "mono"):
            rt = Runtime(query_batch=8)
            if build == "staged":
                _staged(rt, 2)
            else:
                _mono(rt)
            cls = [_client(rt, i, f"{i+1},{i+2}", "5") for i in range(4)]
            rt.run(14)
            outs.append([_answers(c) for c in cls])
        staged, mono = outs
        for i, (a, b) in enumerate(zip(staged, mono)):
            assert len(a) >= 2
            assert a == b, f"client {i}: staged {a} != monolithic {b}"

    def test_mid_generation_join_and_leave_staggered(self):
        """Late joiners enter the live slot table mid-chain: downstream
        stages see them only as admit-mask rows in the next hop — both
        sides stay bitwise sequential."""
        rt = Runtime(query_batch=8)
        _staged(rt, 2)
        rt2 = Runtime(query_batch=8)
        _, mrun, mps = _mono(rt2)
        early = [_client(rt, i, f"{i+1},{i+2}", "8") for i in range(4)]
        rt.run(3)                    # early streams mid-generation
        late = [_client(rt, 4 + i, f"{i+11}", "3") for i in range(4)]
        rt.run(17)
        params, cfg = mrun.params["lm"], mps.elements["lm"].cfg
        for i, run in enumerate(early):
            got = _answers(run)
            assert len(got) >= 2
            for ans in got:
                assert ans == _ref(params, cfg, [i + 1, i + 2], 8)
        for i, run in enumerate(late):
            got = _answers(run)
            assert len(got) >= 3
            for ans in got:
                assert ans == _ref(params, cfg, [i + 11], 3)
        _assert_conservation(_coord(rt))


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(__import__("jax"), "shard_map"),
    reason="partial-manual shard_map subgroups CHECK-fail inside jaxlib "
           "0.4.x's SPMD partitioner (spmd_partitioner.cc:512); needs the "
           "jax>=0.5 manual-axes path")
def test_staged_hops_match_shard_map_pp_step():
    """Same split, two transports: one decode step through the staged
    stage_prefill/stage_decode hop chain computes the tokens pp_serve's
    shard_map ppermute step does on the same params (the intra-process
    pipeline-parallel reference, pod axis = stage axis)."""
    from repro.launch.mesh import set_mesh
    from repro.launch.pp_serve import make_pp_serve_step, pp_applicable
    from repro.models import ModelConfig, build_model
    from repro.models import transformer as T
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
                      dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    sp = m.stack_params(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, 97)
    lp, cache = m.prefill_stacked(sp, {"tokens": toks}, max_seq=20)
    nxt = jnp.argmax(lp, -1)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    assert pp_applicable(m, mesh)
    with set_mesh(mesh):
        tok_pp, _ = jax.jit(make_pp_serve_step(m, mesh))(sp, nxt, cache)
    # the among-device split of the same step: per-stage prefill chain on
    # the prompt, then one boundary-activation decode hop through both
    # stages
    n_stages = 2
    stage_p = [T.stage_params(params, cfg, k, n_stages)
               for k in range(n_stages)]
    x, caches = toks, []
    for k in range(n_stages):
        x, c = T.stage_prefill(stage_p[k], cfg, k, n_stages, x, 20)
        caches.append(c)
    assert np.array_equal(np.asarray(jnp.argmax(x[:, -1], -1)),
                          np.asarray(nxt))
    y = nxt.astype(jnp.int32)
    for k in range(n_stages):
        y, caches[k] = T.stage_decode(stage_p[k], cfg, k, n_stages, y,
                                      caches[k])
    np.testing.assert_array_equal(np.asarray(jnp.argmax(y, -1)),
                                  np.asarray(tok_pp))


class TestStageChaos:
    def test_mid_chain_stage_kill_stage_local_replay_bitwise(self, chaos):
        """THE §8 chaos pin: stage 1 of a 2-stage chain dies at tick 5 with
        every stream mid-generation.  The coordinator re-binds to the
        standby, replays ONLY stage 1's cache slice from the retained
        boundary activations (prefill + one replay verb per committed
        step), re-merges the parked caches under the next hop's admit mask
        — and every delivered answer is bitwise the fault-free twin's.  No
        generation restarts: ``prefills`` stays ``streams_started`` and
        zero tokens drop (the §7 kill test drops partials and re-prefills;
        the staged chain keeps them — strictly better)."""
        ticks, kill_at = 24, 5

        rt0 = Runtime(query_batch=8)
        _staged(rt0, 2)
        ref = [_client(rt0, i, f"{i+1},{i+2}", "8") for i in range(3)]
        rt0.run(ticks)

        rt = Runtime(query_batch=8)
        stages = _staged(rt, 2)
        _standby(rt, stage=1, n_stages=2)
        got = [_client(rt, i, f"{i+1},{i+2}", "8") for i in range(3)]
        dev1, _, ps1 = stages[1]
        harness = chaos(rt)
        harness.kill_server(kill_at, dev1, ps1.elements["ssrc"], crash=True)
        harness.run(ticks)

        for r0, r1 in zip(ref, got):
            a, b = _answers(r0), _answers(r1)
            assert len(b) >= 2
            assert a == b          # same ticks, same answers — no delay even
        coord = _coord(rt)
        st = coord.stats()
        assert st["stage_replays"] >= 1
        assert st["stage_replay_steps"] >= 1     # mid-generation steps replayed
        assert st["tokens_dropped"] == 0         # never a whole-gen restart
        assert st["prefills"] == st["streams_started"]
        _assert_conservation(coord)

    def test_stage_death_no_standby_stalls_then_resumes(self, chaos):
        """No standby: the chain stalls (conservation still balances — the
        failed hops are ledgered, streams stay in flight) and resumes
        bitwise when the stage revives — §3 lease semantics per stage."""
        ticks, kill_at, revive_at = 26, 4, 12
        rt0 = Runtime(query_batch=8)
        _staged(rt0, 2)
        ref = [_client(rt0, i, f"{i+1}", "6") for i in range(2)]
        rt0.run(ticks)

        rt = Runtime(query_batch=8)
        stages = _staged(rt, 2)
        got = [_client(rt, i, f"{i+1}", "6") for i in range(2)]
        dev1, _, ps1 = stages[1]
        harness = chaos(rt)
        harness.kill_server(kill_at, dev1, ps1.elements["ssrc"], crash=True)
        harness.revive_server(revive_at, dev1, ps1.elements["ssrc"])
        harness.run(ticks)

        coord = _coord(rt)
        st = coord.stats()
        assert st["hops_failed"] >= 1            # the stall is ledgered
        assert st["tokens_dropped"] == 0
        for r0, r1 in zip(ref, got):
            a, b = _answers(r0), _answers(r1)
            assert len(b) >= 1
            for x, y in zip(a, b):
                assert x == y                    # delayed, never different
        _assert_conservation(coord)


def _composite_ref(stage_params, cfg, prompt, gen):
    """Sequential greedy decode of a COMPOSITE staged model — per-stage
    param trees that need not come from one init (a §6 stage swap installs
    fresh weights in ONE slice while the others keep theirs).  Pure
    stage_prefill/stage_decode chaining, the reference the post-swap
    chain must reproduce bitwise."""
    from repro.models import transformer as T
    n = len(stage_params)
    x = jnp.asarray(prompt, jnp.int32)[None]
    caches = []
    for k, p in enumerate(stage_params):
        x, c = T.stage_prefill(p, cfg, k, n, x, MAX_SEQ)
        caches.append(c)
    tok = jnp.argmax(x[0], axis=-1).astype(jnp.int32)
    out = [int(tok)]
    for _ in range(max(0, gen - 1)):
        x = tok[None]
        for k, p in enumerate(stage_params):
            x, caches[k] = T.stage_decode(p, cfg, k, n, x, caches[k])
        tok = jnp.argmax(x[0], axis=-1).astype(jnp.int32)
        out.append(int(tok))
    return out


class TestStageHotSwap:
    def test_swap_downstream_stage_mid_decode(self):
        """§6 reconfig covers stage swap: hot-swapping stage 1's serve
        element mid-generation bumps the stage's epoch fence
        (``serve_epoch``) and the coordinator distrusts its parked slice,
        stage-local-replaying the retained activations onto the NEW
        element.  The swap installs fresh stage-1 weights (reconfig derives
        new-element params from its own rng), so the §8 contract is: no
        stream drops or restarts (history preserved — ``prefills`` stays
        ``streams_started``), every stream runs to full length, and
        generations started after the commit are BITWISE the sequential
        decode of the COMPOSITE model — old stage-0 slice, new stage-1
        slice — i.e. the chain really serves the swapped weights."""
        ticks, swap_at = 24, 4
        rt = Runtime(query_batch=8)
        stages = _staged(rt, 2)
        cls = [_client(rt, i, f"{i+3},{i+4}", "8") for i in range(3)]
        srun0 = stages[0][1]
        _, srun1, ps1 = stages[1]
        rt.run(swap_at)
        rc = rt.reconfigure(srun1, ps1.reconfig().swap(
            "lm", element_factory("model_serve_stage", model=MODEL,
                                  slots="8", max_seq=str(MAX_SEQ),
                                  stage="1", n_stages="2")),
            warm_ticks=1, rng=jax.random.PRNGKey(7))
        rt.run(ticks - swap_at)
        assert rc.status == "committed"
        assert ps1.elements["ssrc"].endpoint.spec["serve_epoch"] >= 1
        coord = _coord(rt)
        st = coord.stats()
        assert st["stage_replays"] >= 1
        assert st["tokens_dropped"] == 0         # history preserved
        assert st["prefills"] == st["streams_started"]   # no restarts
        cfg = ps1.elements["lm"].cfg
        composite = [srun0.params["lm"], srun1.params["lm"]]
        for i, run in enumerate(cls):
            got = _answers(run)
            assert len(got) >= 2
            assert all(len(a) == 8 for a in got)         # full length, always
            # every answer delivered after the first is a generation that
            # started post-commit: bitwise the composite model's decode
            ref = _composite_ref(composite, cfg, [i + 3, i + 4], 8)
            for ans in got[1:]:
                assert ans == ref, f"client {i}: {ans} != composite {ref}"
        _assert_conservation(coord)


@pytest.mark.soak
def test_staged_soak_per_stage_conservation(chaos):
    """200-tick staged decode soak (DESIGN.md §8): 8 clients with mixed
    generation cycles over a 2-stage chain with a standby, one mid-chain
    stage kill + revival mid-run.  Per-stage hop conservation
    (``dispatched == completed + failed``) and the §7 token law must
    balance to the unit at the end, and every delivered answer stays
    bitwise sequential."""
    TICKS, KILL_AT, REVIVE_AT = 200, 60, 100
    N = 8
    rt = Runtime(query_batch=8)
    stages = _staged(rt, 2, slots=4)
    _standby(rt, stage=1, n_stages=2, slots=4)
    gen_mix = ["4", "3;6", "5;2", "6"]
    cls = [_client(rt, i, f"{i+1},{i+2}", gen_mix[i % 4]) for i in range(N)]
    dev1, _, ps1 = stages[1]
    harness = chaos(rt)
    harness.kill_server(KILL_AT, dev1, ps1.elements["ssrc"], crash=True)
    harness.revive_server(REVIVE_AT, dev1, ps1.elements["ssrc"])
    harness.run(TICKS)

    coord = _coord(rt)
    st = coord.stats()
    assert st["tokens_generated"] == st["tokens_delivered"] + \
        st["tokens_dropped"] + st["tokens_in_flight"]
    assert st["tokens_dropped"] == 0             # stage-local replay only
    assert st["streams_finished"] >= N * 10      # the workload really churned
    assert st["stage_replays"] >= 1              # the kill exercised replay
    for k in range(1, coord.n_stages):
        led = coord.stage_ledger(k)
        assert led["dispatched"] == led["completed"] + led["failed"], (k, led)

    rt2 = Runtime(query_batch=8)
    _, mrun, mps = _mono(rt2)
    params, cfg = mrun.params["lm"], mps.elements["lm"].cfg
    for i, run in enumerate(cls):
        gens = [int(g) for g in gen_mix[i % 4].split(";")]
        for j, ans in enumerate(_answers(run)):
            ref = _ref(params, cfg, [i + 1, i + 2], gens[j % len(gens)])
            assert ans == ref, f"client {i} answer {j}"
