"""Property tests for the §10 delivery layer (core/netfault.py).

The at-least-once + dedup algebra gets the same treatment the broker and
admission cores got: generated duplicate/reorder schedules against
brute-force oracles.  Pinned laws:

* EFFECTIVELY-ONCE: however a delivery schedule duplicates and reorders a
  sender's frames, the set a :class:`DeliveryGuard` accepts is exactly one
  copy per delivery id, in first-arrival order (the exactly-once oracle);
* the dedup window is a bounded LRU — it never grows past ``window``, and
  while an id is among the ``window`` most recently touched it can never
  be re-accepted (no double-serve of a live id);
* ``forget`` is the ONLY way a live id re-admits (the shed-unserved
  escape hatch), and it re-admits exactly once;
* the retransmit backoff schedule is monotone non-decreasing, starts at
  ``timeout_ticks``, caps at ``max_backoff_ticks``, and never waits zero
  ticks (a zero wait would retransmit every drain round, flooding the
  link the policy exists to respect).

Runs under real hypothesis when installed, else the deterministic
vendored shim (tests/_vendor).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.buffers import StreamBuffer
from repro.core.netfault import DeliveryGuard, DeliveryPolicy, stamp

pytestmark = pytest.mark.netchaos

# a delivery schedule: which logical message (by seq) arrives next — values
# repeat (duplicates) and interleave (reorder) freely
SCHEDULES = st.lists(st.integers(min_value=0, max_value=11),
                     min_size=1, max_size=40)
WINDOWS = st.integers(min_value=1, max_value=8)
TIMEOUTS = st.integers(min_value=0, max_value=6)
BACKOFFS = st.floats(min_value=1.0, max_value=4.0)
CAPS = st.integers(min_value=1, max_value=64)


def _frame(seq):
    return stamp(StreamBuffer(
        tensors=(np.full((3,), seq, np.float32),), pts=np.int64(seq),
        meta={}), (1, int(seq)))


class TestEffectivelyOnce:
    @given(SCHEDULES)
    @settings(max_examples=60)
    def test_accepts_exactly_one_copy_per_id_in_arrival_order(self, sched):
        """The oracle: whatever the duplication/reordering, the accepted
        subsequence is the schedule with every repeat deleted."""
        guard = DeliveryGuard(DeliveryPolicy())   # window >> id space
        accepted = [seq for seq in sched
                    if guard.check(_frame(seq)) == "ok"]
        oracle, seen = [], set()
        for seq in sched:
            if seq not in seen:
                seen.add(seq)
                oracle.append(seq)
        assert accepted == oracle
        assert guard.stats()["deduped"] == len(sched) - len(oracle)

    @given(SCHEDULES)
    @settings(max_examples=40)
    def test_verdicts_partition_the_schedule(self, sched):
        """Every arrival gets exactly one verdict; accepted + deduped
        covers the whole (uncorrupted) schedule — the guard can neither
        invent nor silently swallow a frame."""
        guard = DeliveryGuard(DeliveryPolicy())
        for seq in sched:
            assert guard.check(_frame(seq)) in ("ok", "dup")
        s = guard.stats()
        assert s["accepted"] + s["deduped"] == len(sched)
        assert s["rejected_corrupt"] == 0


class TestBoundedWindow:
    @given(SCHEDULES, WINDOWS)
    @settings(max_examples=60)
    def test_window_never_exceeds_bound(self, sched, window):
        guard = DeliveryGuard(DeliveryPolicy(window=window))
        for seq in sched:
            guard.check(_frame(seq))
            assert len(guard._seen) <= window

    @given(SCHEDULES, WINDOWS)
    @settings(max_examples=60)
    def test_live_ids_never_readmit(self, sched, window):
        """LRU oracle: a duplicate whose id is still among the ``window``
        most recently touched ids MUST dedup — eviction may only ever
        bite the least recently touched tail."""
        guard = DeliveryGuard(DeliveryPolicy(window=window))
        lru = []                                  # most recent last
        for seq in sched:
            verdict = guard.check(_frame(seq))
            if seq in lru:
                assert verdict == "dup"           # live: never re-accepted
                lru.remove(seq)
            else:
                assert verdict == "ok"            # evicted or brand new
            lru.append(seq)
            lru[:] = lru[-window:]

    @given(SCHEDULES)
    @settings(max_examples=40)
    def test_forget_readmits_exactly_once(self, sched):
        """After ``forget``, the next copy of that id is accepted (the
        shed request's failover retry) and the one after dedups again —
        the escape hatch opens the window exactly one slot wide."""
        guard = DeliveryGuard(DeliveryPolicy())
        for seq in sched:
            guard.check(_frame(seq))
        target = sched[0]
        guard.forget((1, target))
        assert guard.check(_frame(target)) == "ok"
        assert guard.check(_frame(target)) == "dup"


class TestBackoffSchedule:
    @given(TIMEOUTS, BACKOFFS, CAPS)
    @settings(max_examples=80)
    def test_monotone_capped_and_never_zero(self, timeout, backoff, cap):
        pol = DeliveryPolicy(timeout_ticks=timeout, backoff=backoff,
                             max_backoff_ticks=cap)
        sched = [pol.retry_in(k) for k in range(10)]
        assert all(t >= 1 for t in sched)         # never a same-tick storm
        assert all(t <= max(cap, 1) for t in sched)
        assert all(a <= b for a, b in zip(sched, sched[1:]))

    @given(TIMEOUTS, BACKOFFS, CAPS)
    @settings(max_examples=40)
    def test_reaches_the_cap_and_stays(self, timeout, backoff, cap):
        """The schedule converges: some retry count hits a fixed point at
        (or below) the cap and never moves again — retransmit cadence is
        eventually periodic, not unbounded."""
        pol = DeliveryPolicy(timeout_ticks=timeout, backoff=backoff,
                             max_backoff_ticks=cap)
        sched = [pol.retry_in(k) for k in range(64)]
        assert sched[-1] == sched[-2]             # fixed point reached
        assert sched[-1] <= max(cap, 1)
