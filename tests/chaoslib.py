"""Deterministic chaos harness for among-device failover tests.

A :class:`Chaos` wraps a :class:`~repro.runtime.Runtime` and executes a
*scripted fault schedule*: faults are keyed to scheduler ticks, fire
immediately BEFORE the tick they are scheduled at executes, and mutate only
simulation state (device liveness flags, broker registrations, channel
wiring) — no threads, no wall-clock, no randomness, so every run of a chaos
scenario is bit-for-bit reproducible and can be compared against its
fault-free twin.

Fault vocabulary:

* ``kill_server(tick, device, ssrc, crash=True)`` — the serving device dies.
  ``crash=True`` is an announced death (``broker.mark_down`` fires the
  ``down`` event at once); ``crash=False`` is a *silent* death — the device
  merely stops heartbeating and serving, and the broker only learns of it
  when the registration's lease expires (``Runtime(lease_ticks=...)``).
* ``kill_server_mid_batch(tick, device, ssrc, after_n=1)`` — arms a tripwire
  on the server's request channel: the device dies the instant its
  ``after_n``-th request of that tick lands, i.e. mid-gather with earlier
  requests already stranded on the dead endpoint.  This is the scenario the
  in-flight failover exists for.
* ``kill_server_mid_flush(tick, device, ssrc, ssink, after_answers=N)`` —
  arms a tripwire on the serving sink's answer paths (eager ``apply`` and
  fused ``push_wire``): the device dies the instant the ``after_answers``-th
  answer of that tick lands, i.e. MID-FLUSH — requests the batcher already
  popped off the request channel are in its hands, invisible to the down
  event's channel purge, and must reach the orphan ledger instead of being
  served by the corpse.
* ``revive_server(tick, device, ssrc)`` — the device returns and re-registers
  under its original registration (same reg_id, so a preferred server wins
  its bindings back).
* ``kill_device(tick, device)`` / ``revive_device(tick, device)`` — generic
  liveness flips (publishers, clients); announced via ``mark_down`` on every
  registration the device holds.
* ``sever(tick, pub_channel, rx)`` / ``restore(tick, pub_channel, rx)`` —
  cut/mend one subscriber's data-plane link: frames published while severed
  never reach that consumer (the broker is oblivious — control and data
  planes fail independently).
* ``at(tick, fn, label)`` — escape hatch for bespoke faults.

All mutations funnel through ``_kill``/``_revive`` so tests, benchmarks
(``benchmarks/bench_failover.py``), and examples
(``examples/failover_offloading.py``) exercise exactly the code paths the
runtime's failover fabric watches — one copy of the fault semantics.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


def lcg_stream(seed: int = 0):
    """Deterministic uniform(0,1) stream (32-bit LCG) — the chaos harness's
    stand-in for randomness: same seed, same traffic, every run."""
    state = (int(seed) & 0xFFFFFFFF) or 1
    while True:
        state = (1664525 * state + 1013904223) & 0xFFFFFFFF
        yield state / 2.0 ** 32


def zipf_tenants(n: int, tenants: Sequence[str], s: float = 1.1,
                 seed: int = 0) -> List[str]:
    """Assign ``n`` clients to tenant ids with a Zipf(s) popularity skew —
    tenant k's mass ∝ 1/(k+1)^s, so the first tenant dominates the way a
    real fleet's biggest customer does.  Deterministic in ``seed``."""
    weights = [1.0 / (k + 1) ** s for k in range(len(tenants))]
    total = sum(weights)
    cumulative, acc = [], 0.0
    for w in weights:
        acc += w / total
        cumulative.append(acc)
    rng = lcg_stream(seed)
    out: List[str] = []
    for _ in range(int(n)):
        u = next(rng)
        for tid, edge in zip(tenants, cumulative):
            if u <= edge:
                out.append(tid)
                break
        else:
            out.append(tenants[-1])
    return out


def burst_schedule(n_ticks: int, base: int = 1, burst: int = 0,
                   burst_at: Iterable[int] = (), width: int = 1
                   ) -> List[int]:
    """Arrivals-per-tick script: ``base`` sustained load with scripted
    overload windows of ``burst`` arrivals starting at each tick in
    ``burst_at`` (0-based, ``width`` ticks wide).  Ticks are script indices
    — the caller maps them onto runtime ticks."""
    sched = [int(base)] * int(n_ticks)
    for t0 in burst_at:
        for t in range(int(t0), min(int(n_ticks), int(t0) + int(width))):
            sched[t] = int(burst)
    return sched


def tenant_arrivals(n_ticks: int, tenants: Sequence[str],
                    schedule: Sequence[int], s: float = 1.1,
                    seed: int = 0) -> List[List[str]]:
    """Per-tick tenant-tagged request script: tick t injects
    ``schedule[t]`` requests, each drawn from the Zipf tenant skew.  The
    flattened draw order is identical to ``zipf_tenants(sum(schedule))``,
    so per-tenant totals are reproducible whatever the tick shaping."""
    flat = zipf_tenants(sum(int(c) for c in schedule[:n_ticks]),
                        tenants, s=s, seed=seed)
    out, i = [], 0
    for t in range(int(n_ticks)):
        c = int(schedule[t]) if t < len(schedule) else 0
        out.append(flat[i:i + c])
        i += c
    return out


def lossy_endpoint(fabric, ep, req_policy, ans_policy=None, name=""):
    """Make a query endpoint's links lossy (DESIGN.md §10): install a fault
    link on its request channel and — because the endpoint mints per-client
    answer channels lazily on the first routed answer — shadow
    ``ep.client_channel`` so every answer channel, including ones born
    after a teardown/activation purge, gets its own link.  Answer links
    derive per-client seeds from ``ans_policy.seed``, so the fault schedule
    is deterministic per (endpoint, client) regardless of arrival order.
    Returns the list of installed links (grows as clients appear)."""
    import dataclasses
    links = [fabric.install(ep.requests, req_policy,
                            name=f"{name}/req" if name else "req")]
    if ans_policy is not None:
        orig = ep.client_channel

        def client_channel(cid):
            ch = orig(cid)
            if id(ch) not in fabric.links:
                pol = dataclasses.replace(
                    ans_policy,
                    seed=(ans_policy.seed + 7919 * int(cid)) & 0xFFFFFFFF)
                links.append(fabric.install(
                    ch, pol, name=f"{name}/ans{cid}" if name
                    else f"ans{cid}"))
            return ch
        ep.client_channel = client_channel
    return links


class Chaos:
    def __init__(self, runtime):
        self.rt = runtime
        self._schedule: Dict[int, List[Tuple[Callable[[], None], str]]] = {}
        #: (tick, label) of every fault that fired, in order
        self.log: List[Tuple[int, str]] = []

    # -- schedule construction -------------------------------------------------
    def at(self, tick: int, fn: Callable[[], None],
           label: Optional[str] = "custom") -> "Chaos":
        """``label=None`` schedules silently (internal plumbing like arming
        a tripwire — the real fault logs itself when it fires)."""
        self._schedule.setdefault(int(tick), []).append((fn, label))
        return self

    def kill_server(self, tick: int, device, ssrc, crash: bool = True
                    ) -> "Chaos":
        return self.at(tick, lambda: self._kill(device, ssrc, crash),
                       f"kill {device.name} ({'crash' if crash else 'silent'})")

    def kill_server_mid_batch(self, tick: int, device, ssrc, after_n: int = 1
                              ) -> "Chaos":
        """The fault is logged when the kill actually FIRES (the
        ``after_n``-th request of that tick lands), not when the tripwire
        is armed; if the tick ends with fewer sends, the tripwire disarms
        and a DISARMED entry is logged instead — a vacuous chaos run can
        never masquerade as a survived fault."""
        def arm():
            chan = ssrc.endpoint.requests
            orig_push = chan.push
            seen = [0]

            def tripwire(buf, nbytes=None):
                ok = orig_push(buf, nbytes)
                seen[0] += 1
                if seen[0] == after_n:
                    chan.push = orig_push  # disarm before the kill purges
                    self._kill(device, ssrc, crash=True)
                    self.log.append(
                        (self.rt.ticks,
                         f"kill {device.name} mid-batch (request {after_n})"))
                return ok

            def disarm():
                if chan.push is tripwire:
                    chan.push = orig_push
                    self.log.append(
                        (self.rt.ticks + 1,
                         f"mid-batch kill of {device.name} DISARMED "
                         f"(fewer than {after_n} sends on tick {tick})"))

            chan.push = tripwire
            self.at(tick + 1, disarm, label=None)
        return self.at(tick, arm, label=None)

    def kill_server_mid_flush(self, tick: int, device, ssrc, ssink,
                              after_answers: int = 1) -> "Chaos":
        """Die while the batcher is SERVING (vs ``kill_server_mid_batch``,
        which dies while clients are still gathering): the kill fires on the
        ``after_answers``-th answer push of that tick, so the flush's
        remaining popped-but-unserved groups race the death.  Same
        arm/fire/DISARM discipline as the mid-batch tripwire — a vacuous
        run logs DISARMED instead of masquerading as a survived fault."""
        def arm():
            orig_apply = ssink.apply
            orig_push_wire = ssink.push_wire
            seen = [0]
            armed = [True]

            def disarm(quiet: bool = False):
                if not armed[0]:
                    return
                armed[0] = False
                ssink.__dict__.pop("apply", None)
                ssink.__dict__.pop("push_wire", None)
                if not quiet:
                    self.log.append(
                        (self.rt.ticks + 1,
                         f"mid-flush kill of {device.name} DISARMED "
                         f"(fewer than {after_answers} answers on "
                         f"tick {tick})"))

            def fire():
                seen[0] += 1
                if seen[0] == after_answers:
                    disarm(quiet=True)  # restore before the kill purges
                    self._kill(device, ssrc, crash=True)
                    self.log.append(
                        (self.rt.ticks,
                         f"kill {device.name} mid-flush "
                         f"(answer {after_answers})"))

            def apply_wrap(params, inputs, ctx=None):
                out = orig_apply(params, inputs, ctx)
                fire()
                return out

            def push_wire_wrap(payload, nbytes, client_id):
                out = orig_push_wire(payload, nbytes, client_id)
                fire()
                return out

            ssink.apply = apply_wrap
            ssink.push_wire = push_wire_wrap
            self.at(tick + 1, disarm, label=None)
        return self.at(tick, arm, label=None)

    def revive_server(self, tick: int, device, ssrc) -> "Chaos":
        return self.at(tick, lambda: self._revive(device, ssrc),
                       f"revive {device.name}")

    def kill_device(self, tick: int, device) -> "Chaos":
        def fn():
            device.alive = False
            for reg in self._device_regs(device):
                self.rt.broker.mark_down(reg)
        return self.at(tick, fn, f"kill {device.name}")

    def revive_device(self, tick: int, device) -> "Chaos":
        def fn():
            device.alive = True
            for reg in self._device_regs(device):
                self.rt.broker.revive(reg)
        return self.at(tick, fn, f"revive {device.name}")

    def sever(self, tick: int, pub_channel, rx) -> "Chaos":
        def fn():
            if rx in pub_channel.consumers:
                pub_channel.consumers.remove(rx)
        return self.at(tick, fn, "sever channel")

    def restore(self, tick: int, pub_channel, rx) -> "Chaos":
        def fn():
            if rx not in pub_channel.consumers:
                pub_channel.consumers.append(rx)
        return self.at(tick, fn, "restore channel")

    def partition_control(self, tick0: int, tick1: int, device) -> "Chaos":
        """Partition a device's CONTROL plane for ticks [tick0, tick1): its
        heartbeats are lost in the network while the device itself keeps
        running — the broker's lease lapses into SUSPICION (not declared
        death; DESIGN.md §10), clients fail over, and when the partition
        heals the resumed beats win the registration back without
        double-serving anything the dedup layer already settled."""
        self.at(tick0, lambda: self.rt._control_blocked.add(device),
                f"control-partition {device.name}")
        self.at(tick1, lambda: self.rt._control_blocked.discard(device),
                f"heal control-partition {device.name}")
        return self

    def expire_lease(self, tick: int, device, reg) -> "Chaos":
        """Force the registration's lease to lapse on the very next broker
        tick — models a stalled (not crashed) device.  The device must also
        stop heartbeating (``alive = False``): the runtime beats on behalf
        of live devices at the top of every tick, which would refresh the
        backdated lease before the expiry check ever saw it.  Requires a
        leased registration (``lease_ticks`` set)."""
        def fn():
            device.alive = False
            reg.last_beat = -10**9
        return self.at(tick, fn, f"expire lease of {reg.topic}")

    # -- fault primitives --------------------------------------------------------
    def _kill(self, device, ssrc, crash: bool):
        device.alive = False
        ssrc.endpoint.alive = False  # stops serving NOW either way
        if crash and ssrc.registration is not None:
            self.rt.broker.mark_down(ssrc.registration)
        # silent death: the broker finds out at lease expiry

    def _revive(self, device, ssrc):
        device.alive = True
        ssrc.endpoint.alive = True
        if ssrc.registration is not None:
            self.rt.broker.revive(ssrc.registration)

    def _device_regs(self, device):
        for run in device.runs:
            for e in run.pipe.elements.values():
                reg = getattr(e, "registration", None)
                if reg is not None:
                    yield reg

    # -- execution ---------------------------------------------------------------
    def run(self, n_ticks: int):
        """Drive the runtime ``n_ticks`` ticks, firing each scheduled fault
        immediately before its tick executes (tick numbers are 1-based and
        continue across successive ``run`` calls, matching
        ``Runtime.ticks``)."""
        for _ in range(n_ticks):
            t = self.rt.ticks + 1
            for fn, label in self._schedule.pop(t, ()):
                fn()
                if label is not None:
                    self.log.append((t, label))
            self.rt.tick()
        return self.rt
