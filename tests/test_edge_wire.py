"""Edge wire-format round-trip and corruption-rejection tests (edge/edge.py).

The NNSE format is the contract with non-jax devices (RTOS sensors, plain
python processes).  Round trips must be lossless for every supported dtype
and degenerate shape; malformed frames — wrong magic, future versions,
truncation anywhere, inconsistent sizes — must raise, never misparse.
"""
import struct

import numpy as np
import pytest

from repro.edge.edge import (ChecksumError, _DTYPES, _MAGIC, pack_buffer,
                             unpack_buffer)


def _arr(dtype: str, shape=(3, 4)) -> np.ndarray:
    rng = np.random.default_rng(hash(dtype) % 2 ** 31)
    if dtype.startswith("float"):
        return rng.standard_normal(shape).astype(dtype)
    info = np.iinfo(dtype)
    return rng.integers(info.min, int(info.max) + 1, size=shape,
                        dtype=np.dtype(dtype))


def _assert_roundtrip(tensors, pts=0):
    got, got_pts = unpack_buffer(pack_buffer(tensors, pts))
    assert got_pts == pts
    assert len(got) == len(tensors)
    for a, b in zip(tensors, got):
        assert a.dtype == b.dtype
        assert a.shape == b.shape
        np.testing.assert_array_equal(a, b)


class TestRoundTrip:
    @pytest.mark.parametrize("dtype", _DTYPES)
    def test_all_dtypes(self, dtype):
        _assert_roundtrip([_arr(dtype)])

    @pytest.mark.parametrize("dtype", _DTYPES)
    def test_zero_dim(self, dtype):
        _assert_roundtrip([_arr(dtype, shape=())])

    @pytest.mark.parametrize("shape", [(0,), (0, 3), (4, 0, 2)])
    def test_empty_tensors(self, shape):
        _assert_roundtrip([np.zeros(shape, np.float32)])

    def test_multi_tensor_mixed_dtypes(self):
        # deliberately places a float64 tensor at an offset that is not a
        # multiple of 8 relative to the payload start — the seed parser's
        # whole-buffer frombuffer choked on exactly this framing
        _assert_roundtrip([_arr("uint8", (5,)), _arr("float64", (2, 3)),
                           _arr("int16", ()), _arr("float32", (0, 2))])

    @pytest.mark.parametrize("pts", [0, -1, -(2 ** 62), 2 ** 62])
    def test_pts_signed_range(self, pts):
        _assert_roundtrip([_arr("int32", (2,))], pts=pts)

    def test_no_tensors(self):
        _assert_roundtrip([])


class TestRejection:
    def test_bad_magic(self):
        wire = bytearray(pack_buffer([_arr("uint8")]))
        wire[:4] = b"XXSE"
        with pytest.raises(ValueError, match="magic"):
            unpack_buffer(bytes(wire))

    def test_unknown_version(self):
        wire = bytearray(pack_buffer([_arr("uint8")]))
        struct.pack_into("<H", wire, 4, 99)
        with pytest.raises(ValueError, match="version 99"):
            unpack_buffer(bytes(wire))

    def test_unknown_dtype_tag(self):
        wire = bytearray(pack_buffer([_arr("uint8", (2,))]))
        struct.pack_into("<H", wire, 16, len(_DTYPES))  # first tensor's tag
        with pytest.raises(ValueError, match="dtype tag"):
            unpack_buffer(bytes(wire))

    def test_payload_size_mismatch(self):
        wire = bytearray(pack_buffer([_arr("float32", (2, 2))]))
        # nbytes field sits after tag(2)+ndim(2)+dims(2*4) = 12 bytes
        struct.pack_into("<Q", wire, 16 + 12, 15)
        with pytest.raises(ValueError, match="payload size"):
            unpack_buffer(bytes(wire))

    def test_every_truncation_rejected(self):
        """No prefix of a valid frame may parse: byte-exhaustive sweep."""
        wire = pack_buffer([_arr("uint8", (3,)), _arr("float64", (2, 2))],
                           pts=-7)
        for cut in range(len(wire)):
            with pytest.raises(ValueError):
                unpack_buffer(wire[:cut])

    def test_trailing_garbage_rejected(self):
        wire = pack_buffer([_arr("int32", (2, 2))])
        with pytest.raises(ValueError, match="trailing"):
            unpack_buffer(wire + b"\x00")

    def test_memoryview_input_accepted(self):
        wire = pack_buffer([_arr("uint16", (4,))])
        got, _ = unpack_buffer(memoryview(wire))
        assert got[0].dtype == np.uint16


class TestChecksum:
    """v2 CRC32 trailer (DESIGN.md §10): bit damage that parses structurally
    must still be rejected — with an error DISTINCT from protocol damage,
    because a retransmit of a corrupt frame can succeed where a retransmit
    of a protocol mismatch cannot."""

    def test_payload_bit_flip_rejected(self):
        wire = bytearray(pack_buffer([_arr("float32", (4, 4))]))
        # flip one bit deep inside the tensor payload: every structure
        # field (header, dims, sizes) is untouched, so only the CRC can
        # tell this frame from the real one
        wire[40] ^= 0x10
        with pytest.raises(ChecksumError, match="checksum mismatch"):
            unpack_buffer(bytes(wire))

    def test_every_payload_bit_position_rejected(self):
        """Byte-exhaustive over the tensor payload region: no single-byte
        corruption may slip through the trailer."""
        body = pack_buffer([_arr("uint8", (8,))])
        payload_start = 16 + 2 + 2 + 4 + 8   # header + tag/ndim + dims + nbytes
        for pos in range(payload_start, payload_start + 8):
            wire = bytearray(body)
            wire[pos] ^= 0x01
            with pytest.raises(ChecksumError):
                unpack_buffer(bytes(wire))

    def test_trailer_corruption_rejected(self):
        wire = bytearray(pack_buffer([_arr("int32", (2,))]))
        wire[-1] ^= 0x80
        with pytest.raises(ChecksumError):
            unpack_buffer(bytes(wire))

    def test_checksum_error_is_value_error(self):
        # callers of the PR-2 rejection matrix catch ValueError; the new
        # failure mode must land inside that net, just distinguishable
        assert issubclass(ChecksumError, ValueError)

    def test_structural_damage_keeps_specific_error(self):
        # a corrupt STRUCTURE field fails its own check, not the checksum:
        # the parse-then-verify order keeps the PR-2 matrix's diagnostics
        wire = bytearray(pack_buffer([_arr("uint8", (2,))]))
        struct.pack_into("<H", wire, 16, len(_DTYPES))
        with pytest.raises(ValueError, match="dtype tag"):
            unpack_buffer(bytes(wire))

    def test_v1_frame_without_trailer_accepted(self):
        # pre-§10 sender: same format minus the trailer, version 1
        arr = _arr("int16", (3,))
        wire = bytearray(pack_buffer([arr])[:-4])
        struct.pack_into("<H", wire, 4, 1)
        got, _ = unpack_buffer(bytes(wire))
        np.testing.assert_array_equal(got[0], arr)

    def test_empty_frame_has_valid_trailer(self):
        got, pts = unpack_buffer(pack_buffer([], pts=5))
        assert got == [] and pts == 5
        wire = bytearray(pack_buffer([], pts=5))
        wire[8] ^= 0x01     # pts byte: structure-silent, checksum-loud
        with pytest.raises(ChecksumError):
            unpack_buffer(bytes(wire))
