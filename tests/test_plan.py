"""Compiled execution plan: parity with the seed interpreter, executable
caching, burst semantics, and runtime burst draining.

Every pipeline exercised by tests/test_pipeline.py (plus a dedicated
tee/compositor graph) must produce BITWISE-identical sink outputs and
next-state under four execution tiers:

  1. the seed per-frame interpreter (``Pipeline.step_interpreted``),
  2. the plan schedule (``Pipeline.step``),
  3. the cached compiled executable (``Pipeline.compiled_step``),
  4. scan-batched bursts (``Pipeline.step_n`` / ``compiled_step_n``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Channel, StreamBuffer, TensorSpec, parse_launch,
                        stack_buffers, unstack_buffers)
from repro.core.elements import register_model
from repro.runtime import Device, Runtime


@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jax.random.normal(rng, (3, 10)) * 0.1}

    def apply(p, x):
        return jnp.mean(x.reshape(-1, 3), 0) @ p["w"]

    register_model("plancls", init, apply,
                   out_specs=(TensorSpec((10,), "float32"),))

    def apply_det(p, x):
        boxes = jnp.array([[0.1, 0.1, 0.5, 0.6], [0.2, 0.3, 0.4, 0.5]])
        scores = jnp.array([0.9, 0.1])
        return boxes, scores

    register_model("plandet", lambda rng: {}, apply_det,
                   out_specs=(TensorSpec((2, 4), "float32"),
                              TensorSpec((2,), "float32")))


LISTING1 = """
    v4l2src name=cam ! tee name=ts
    ts. queue leaky=2 ! videoconvert ! mix.sink_1
    ts. videoconvert ! videoscale !
      video/x-raw,width=16,height=16,format=RGB !
      tensor_converter !
      tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 !
      tensor_filter model=plandet !
      tensor_decoder mode=bounding_boxes option4=64:48 ! queue ! mix.sink_0
    compositor name=mix sink_0::zorder=2 sink_1::zorder=1 ! videoconvert !
      appsink name=display
"""

TEE_COMPOSITOR = """
    testsrc name=s width=12 height=12 ! tee name=t
    t. queue ! videoconvert ! cmp.sink_0
    t. videoconvert ! videoscale ! video/x-raw,width=6,height=6,format=RGB !
      videoconvert ! cmp.sink_1
    compositor name=cmp sink_0::zorder=1 sink_1::zorder=2 sink_1::xpos=3 !
      appsink name=out
"""

PARITY_PIPELINES = {
    "listing1": LISTING1,
    "tee_compositor": TEE_COMPOSITOR,
    "mux_forward_ref": """
        testsrc ! tensor_converter ! mux.sink_0
        testsrc ! tensor_converter ! mux.sink_1
        tensor_mux name=mux ! appsink name=o
    """,
    "demux": """
        testsrc ! tensor_converter ! mux.sink_0
        testsrc ! tensor_converter ! mux.sink_1
        tensor_mux name=mux ! tensor_demux name=d
        d.src_0 ! appsink name=a
        d.src_1 ! appsink name=b
    """,
    "transform": """
        testsrc width=8 height=8 ! tensor_converter !
        tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 !
        appsink name=o
    """,
    "filter_cls": """
        testsrc width=8 height=8 ! tensor_converter !
        tensor_transform mode=arithmetic option=typecast:float32 !
        tensor_filter model=plancls ! tensor_decoder mode=classification !
        appsink name=o
    """,
    "sparse_roundtrip": """
        testsrc width=8 height=8 ! tensor_converter !
        tensor_transform mode=arithmetic option=typecast:float32 !
        tensor_sparse_enc max_nnz=256 ! tensor_sparse_dec ! appsink name=o
    """,
    "tensor_if": """
        testsrc width=4 height=4 ! tensor_converter !
        tensor_transform mode=arithmetic option=typecast:float32,div:255.0 !
        tensor_if threshold=2.0 operator=GE ! appsink name=o
    """,
}


def assert_tree_equal(a, b, label=""):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"{label}: treedef mismatch {ta} vs {tb}"
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, f"{label}: dtype {x.dtype} vs {y.dtype}"
        assert np.array_equal(x, y), f"{label}: values differ"


@pytest.mark.parametrize("name", sorted(PARITY_PIPELINES))
def test_plan_matches_interpreter_bitwise(name):
    """Eager plan vs eager seed loop, and compiled/burst vs jitted seed loop
    (the seed tests always ran ``jax.jit(pipe.step)``).  XLA may legitimately
    fuse float arithmetic differently between eager and jit, so parity is
    asserted within each execution mode — bitwise."""
    n = 3
    pipe = parse_launch(PARITY_PIPELINES[name]).realize()
    params = pipe.init(jax.random.PRNGKey(0))
    s0 = pipe.init_state()

    # tier 1: eager plan schedule vs eager seed interpreter
    ref_outs, si = [], dict(s0)
    for _ in range(n):
        o, si = pipe.step_interpreted(params, si)
        ref_outs.append(o)
    sp = dict(s0)
    for k in range(n):
        o, sp = pipe.step(params, sp)
        assert_tree_equal(o, ref_outs[k], f"{name}/plan[{k}]")
    assert_tree_equal(sp, si, f"{name}/plan-state")

    # tier 2: compiled plan + scan bursts vs the jitted seed step loop
    jit_ref = jax.jit(pipe.step_interpreted)
    jref_outs, sj = [], dict(s0)
    for _ in range(n):
        o, sj = jit_ref(params, sj)
        jref_outs.append(o)
    assert_tree_equal(sj, si, f"{name}/jit-ref-state")

    sc = dict(s0)
    compiled = pipe.compiled_step()
    for k in range(n):
        o, sc = compiled(params, sc)
        assert_tree_equal(o, jref_outs[k], f"{name}/compiled[{k}]")
    assert_tree_equal(sc, sj, f"{name}/compiled-state")

    outs_b, sb = pipe.compiled_step_n()(params, dict(s0), n=n)
    for k, per in enumerate(unstack_buffers(outs_b, n)):
        assert_tree_equal(per, jref_outs[k], f"{name}/burst[{k}]")
    assert_tree_equal(sb, sj, f"{name}/burst-state")


def test_schedule_is_static_no_per_step_sorting():
    pipe = parse_launch(PARITY_PIPELINES["demux"]).realize()
    plan = pipe.plan
    # flattened: every op's wiring is resolved to integer slots up front
    assert all(isinstance(op.in_slots, tuple) for op in plan.ops)
    assert len(plan.ops) == len(pipe.elements)
    names = [op.name for op in plan.ops]
    assert names == [e.name for e in pipe._order]


def test_executable_cache_shared_across_identical_pipelines():
    desc = """
        testsrc name=s width=8 height=8 ! tensor_converter name=c !
        tensor_transform name=t mode=arithmetic option=typecast:float32 !
        appsink name=o
    """
    p1 = parse_launch(desc).realize()
    p2 = parse_launch(desc).realize()
    assert p1.plan.fingerprint == p2.plan.fingerprint
    assert p1.compiled_step() is p2.compiled_step()
    # and re-realizing (failover re-wire path) keeps the fingerprint stable
    fp = p1.plan.fingerprint
    p1._realized = False
    p1.realize()
    assert p1.plan.fingerprint == fp
    assert p1.compiled_step() is p2.compiled_step()


def test_different_config_gets_different_fingerprint():
    a = parse_launch("testsrc name=s width=8 height=8 ! appsink name=o").realize()
    b = parse_launch("testsrc name=s width=4 height=4 ! appsink name=o").realize()
    assert a.plan.fingerprint != b.plan.fingerprint


def test_step_n_with_injected_inputs_matches_sequential():
    """appsrc-fed pipeline: stacked injected frames through one scan."""
    n = 4
    desc = """
        appsrc name=in ! tensor_transform mode=arithmetic
          option=typecast:float32,mul:2.0 ! appsink name=o
    """
    pipe = parse_launch(desc).realize()
    params, s0 = pipe.init(jax.random.PRNGKey(0)), pipe.init_state()
    frames = [StreamBuffer(tensors=(jnp.full((3, 3), i, jnp.float32),),
                           pts=jnp.int32(i)) for i in range(n)]

    ref, si = [], dict(s0)
    for f in frames:
        o, si = pipe.step_interpreted(params, si, {"in": f})
        ref.append(o)

    stacked = {"in": stack_buffers(frames)}
    outs, sb = pipe.step_n(params, dict(s0), stacked)
    for k, per in enumerate(unstack_buffers(outs, n)):
        assert_tree_equal(per, ref[k], f"inject[{k}]")
    assert_tree_equal(sb, si, "inject-state")


class TestChannelReplayCap:
    def test_late_subscriber_replay_capped_at_capacity(self):
        pub = Channel(capacity=64)
        for i in range(10):
            pub.push(StreamBuffer(tensors=(jnp.full((1,), i),)))
        sub = pub.attach_consumer(capacity=4)
        assert len(sub) == 4
        assert sub.drops == 6  # skipped history accounted as leaky drops
        # newest-first survivors: frames 6..9
        got = [float(sub.pop().tensor[0]) for _ in range(4)]
        assert got == [6.0, 7.0, 8.0, 9.0]

    def test_replay_within_capacity_is_lossless(self):
        pub = Channel(capacity=16)
        for i in range(3):
            pub.push(StreamBuffer(tensors=(jnp.full((1,), i),)))
        sub = pub.attach_consumer()
        assert len(sub) == 3 and sub.drops == 0


class TestRuntimeBurstDraining:
    def _backlogged_runtime(self, burst):
        rt = Runtime(burst=burst)
        pub = Device("cam")
        p = parse_launch("testsrc width=8 height=8 ! tensor_converter ! "
                         "mqttsink pub-topic=live name=snk")
        pub.add_pipeline(p, jit=False)
        rt.add_device(pub)
        # build a 5-frame backlog before the subscriber joins
        rt.run(5)
        sub = Device("screen")
        s = parse_launch("mqttsrc sub-topic=live name=src ! appsink name=o")
        run = sub.add_pipeline(s, jit=False)
        rt.add_device(sub)
        return rt, run

    def test_burst_drains_backlog_in_one_tick(self):
        rt, run = self._backlogged_runtime(burst=8)
        rt.tick()  # publisher emits frame 6, subscriber drains all 6
        assert run.frames == 6
        assert run.bursts == 1 and run.burst_frames == 6
        # frames arrive in order, bitwise identical to per-frame pulls
        pts = [int(b.pts) for b in run.sink_log["o"]]
        assert pts == sorted(pts) and len(set(pts)) == 6

    def test_burst_cap_respected(self):
        rt, run = self._backlogged_runtime(burst=4)
        rt.tick()
        assert run.frames == 4  # capped at burst, remainder stays queued
        rt.tick()
        assert run.frames == 7  # 2 leftover + 2 fresh publisher frames

    def test_burst_disabled_matches_seed_cadence(self):
        rt, run = self._backlogged_runtime(burst=1)
        rt.tick()
        assert run.frames == 1 and run.bursts == 0

    def test_burst_vs_per_frame_outputs_identical(self):
        rt1, run1 = self._backlogged_runtime(burst=8)
        rt1.tick()
        rt2, run2 = self._backlogged_runtime(burst=1)
        for _ in range(6):
            rt2.tick()
        n = min(len(run1.sink_log["o"]), len(run2.sink_log["o"]))
        assert n >= 5
        for a, b in zip(run1.sink_log["o"][:n], run2.sink_log["o"][:n]):
            assert_tree_equal(a, b, "burst-vs-seed")

    def test_query_pipelines_never_burst(self):
        """Query round-trips are not hoistable; plan must refuse bursts."""
        srv = parse_launch(
            "tensor_query_serversrc operation=op name=ssrc ! "
            "tensor_query_serversink name=ssink")
        srv.elements["ssink"].pair_with(srv.elements["ssrc"])
        srv.realize()
        assert not srv.plan.burstable and not srv.plan.pure

    def test_pure_pipeline_flags(self):
        p = parse_launch("testsrc ! tensor_converter ! appsink name=o").realize()
        assert p.plan.pure and p.plan.burstable
        assert not p.plan.all_sources_host_driven  # live source: never burst
        q = parse_launch("mqttsrc sub-topic=x ! appsink name=o").realize()
        assert not q.plan.pure and q.plan.burstable
        assert q.plan.all_sources_host_driven

    def test_mixed_live_source_stays_on_tick_cadence(self):
        """A live testsrc muxed with an mqttsrc must NOT be fast-forwarded
        by burst draining — the camera would fabricate future frames."""
        rt = Runtime(burst=8)
        pub = Device("cam")
        p = parse_launch("testsrc width=4 height=4 ! tensor_converter ! "
                         "mqttsink pub-topic=live name=snk")
        pub.add_pipeline(p, jit=False)
        rt.add_device(pub)
        rt.run(5)  # 5-frame backlog
        mixer = Device("mixer")
        m = parse_launch("""
            mqttsrc sub-topic=live name=src ! queue ! mux.sink_0
            testsrc name=local width=4 height=4 ! tensor_converter ! mux.sink_1
            tensor_mux name=mux ! appsink name=o
        """)
        run = mixer.add_pipeline(m, jit=False)
        rt.add_device(mixer)
        assert not m.plan.all_sources_host_driven
        rt.tick()
        assert run.frames == 1 and run.bursts == 0

    def test_unread_frames_survive_and_replay_in_order(self):
        """Frames handed back to an mqttsrc re-emerge first and decoded
        exactly once (no raw re-queue)."""
        rt, run = self._backlogged_runtime(burst=1)
        src = run.pipe.elements["src"]
        first = src.pull()
        second = src.pull()
        src.unread([first, second])
        assert src.queued() >= 2
        got = src.pull_burst(2)
        assert [int(b.pts) for b in got] == [int(first.pts), int(second.pts)]


def test_executable_cache_is_bounded():
    from repro.core.plan import _EXEC_CACHE, _EXEC_CACHE_MAX
    assert _EXEC_CACHE_MAX >= 1
    for i in range(3):
        p = parse_launch(
            f"testsrc name=s width={4 + i} height=4 ! appsink name=o").realize()
        p.compiled_step()
    assert len(_EXEC_CACHE) <= _EXEC_CACHE_MAX
