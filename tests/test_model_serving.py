"""Real model inference on the serving fabric (DESIGN.md §7).

A ``model_serve`` element runs autoregressive decode as PLAN STATE
(slot-stacked KV-cache / rGLRU-state pytrees carried across ticks) with
CONTINUOUS BATCHING: requests join and leave the decode batch
mid-generation through slot allocation inside ONE jitted serve-tick
dispatch.  The acceptance contract pinned here:

* continuous-batched decode is BITWISE the per-request sequential decode —
  at batch 1, 4 and 8, including mid-generation joins/leaves (staggered
  arrivals, mixed generation lengths) and both state families (KV-cache
  transformer, rGLRU recurrent hybrid);
* a mid-decode hot swap commits and every post-commit answer is bitwise
  what a FRESHLY BUILT server with the new model produces (in-flight
  streams replay on the new epoch);
* killing a server mid-generation with live KV state loses zero tokens —
  orphaned streams re-dispatch with prefill replay on a survivor and the
  answers stay bitwise the fault-free twin's; with no survivor the park
  deadline turns mid-stream requests into client-visible errors;
* the token conservation law ``generated == delivered + dropped +
  in_flight`` balances through churn, death and hot swaps (soak).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as comp
from repro.core.batching import StreamingQueryBatcher
from repro.core.buffers import StreamBuffer
from repro.core.element import element_factory
from repro.core.plan import executable_cache_info
from repro.launch import model_serve as ms
from repro.runtime import Device, Runtime

pytestmark = pytest.mark.modelserve

MAX_SEQ = 32


def _server(rt, name="hub", model="stablelm-smoke-flash", slots=8,
            max_seq=MAX_SEQ):
    """One serving device.  All servers init from PRNGKey(0), so any
    survivor regenerates bitwise-identical tokens — the fault-free twin."""
    dev = Device(name)
    ps = ms.serve_pipeline(model=model, slots=slots, max_seq=max_seq)
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return dev, run, ps


def _client(rt, i, prompts, gens):
    dev = Device(f"tv{i}")
    run = dev.add_pipeline(ms.client_pipeline(prompts=prompts, gens=gens),
                           jit=False)
    rt.add_device(dev)
    return run


def _answers(run):
    return [np.asarray(b.tensor).tolist() for b in run.sink_log.get("res", [])]


# sequential_decode re-jits per call; memoize per (params, prompt, gen) so
# repeated parity checks trace once.  The cache value pins ``params`` so the
# id() key can never be recycled by the allocator mid-session.
_REF_CACHE = {}


def _ref(params, cfg, prompt, gen):
    key = (id(params), tuple(prompt), gen)
    if key not in _REF_CACHE:
        _REF_CACHE[key] = (params, ms.sequential_decode(params, cfg, prompt,
                                                        gen, MAX_SEQ))
    return _REF_CACHE[key][1]


def _check_stream(run, prompts, gens, params, cfg, min_answers=1):
    """Every delivered answer must be bitwise the sequential-decode
    reference for its position in the client's (prompt, gen) cycle."""
    got = _answers(run)
    assert len(got) >= min_answers
    for j, ans in enumerate(got):
        prompt = prompts[j % len(prompts)]
        gen = gens[j % len(gens)]
        ref = _ref(params, cfg, prompt, gen)
        assert ans == ref, f"answer {j}: {ans} != sequential {ref}"


class TestContinuousBatchingParity:
    @pytest.mark.parametrize("n_clients", [1, 4, 8])
    def test_bitwise_vs_sequential_decode(self, n_clients):
        """THE tentpole pin: N concurrent streams with mixed generation
        lengths — every answer the continuous batch delivers is bitwise the
        per-request sequential decode of the same prompt."""
        gen_mix = ["4", "3;6", "5", "6;3"]
        rt = Runtime(query_batch=8)
        _, srv, ps = _server(rt, slots=8)
        cls = []
        for i in range(n_clients):
            cls.append((_client(rt, i, f"{i+1},{i+2},{i+3}",
                                gen_mix[i % len(gen_mix)]), i))
        rt.run(16)
        params, cfg = srv.params["lm"], ps.elements["lm"].cfg
        for run, i in cls:
            gens = [int(g) for g in gen_mix[i % len(gen_mix)].split(";")]
            _check_stream(run, [[i + 1, i + 2, i + 3]], gens, params, cfg,
                          min_answers=2)
        qb = rt.stats()["query_batching"]
        assert qb["tokens_generated"] == qb["tokens_delivered"] + \
            qb["tokens_dropped"] + qb["tokens_in_flight"]
        if n_clients == 8:
            # the batch really was continuous: more slot-tokens than
            # dispatches means >1 stream decoded per serve tick
            assert qb["batched_frames"] > qb["decode_ticks"]

    def test_mid_generation_join_and_leave_staggered(self):
        """Requests join the live decode batch mid-generation: 4 long
        streams start first, 4 short ones arrive 3 ticks later (device
        join), finish EARLIER (leave mid-batch), and every answer on both
        sides stays bitwise sequential."""
        rt = Runtime(query_batch=8)
        _, srv, ps = _server(rt, slots=8)
        early = [_client(rt, i, f"{i+1},{i+2}", "8") for i in range(4)]
        rt.run(3)                    # early streams are mid-generation
        late = [_client(rt, 4 + i, f"{i+11}", "3") for i in range(4)]
        rt.run(17)
        params, cfg = srv.params["lm"], ps.elements["lm"].cfg
        for i, run in enumerate(early):
            _check_stream(run, [[i + 1, i + 2]], [8], params, cfg,
                          min_answers=2)
        for i, run in enumerate(late):
            _check_stream(run, [[i + 11]], [3], params, cfg, min_answers=3)
        qb = rt.stats()["query_batching"]
        assert qb["streams_finished"] >= 2 * 4 + 3 * 4
        assert qb["tokens_generated"] == qb["tokens_delivered"] + \
            qb["tokens_dropped"] + qb["tokens_in_flight"]

    def test_more_streams_than_slots_waits_fifo(self):
        """6 streams over 4 slots: the overflow waits in the FIFO and joins
        as slots free — nothing is dropped, parity still holds."""
        rt = Runtime(query_batch=8)
        _, srv, ps = _server(rt, slots=4)
        cls = [_client(rt, i, f"{i+1}", "4") for i in range(6)]
        rt.run(14)
        params, cfg = srv.params["lm"], ps.elements["lm"].cfg
        for i, run in enumerate(cls):
            _check_stream(run, [[i + 1]], [4], params, cfg, min_answers=1)
        qb = rt.stats()["query_batching"]
        assert qb["tokens_dropped"] == 0

    def test_rglru_recurrent_state_family(self):
        """The SSM-side pin: recurrentgemma's rGLRU recurrence + windowed
        attention ring caches ride the same plan-state slots bitwise."""
        rt = Runtime(query_batch=8)
        _, srv, ps = _server(rt, model="recurrentgemma-smoke", slots=4)
        cls = [_client(rt, i, f"{i+5},{i+6}", "5") for i in range(2)]
        rt.run(10)
        params, cfg = srv.params["lm"], ps.elements["lm"].cfg
        for i, run in enumerate(cls):
            _check_stream(run, [[i + 5, i + 6]], [5], params, cfg,
                          min_answers=1)


class TestStatefulExecCache:
    def test_serve_tick_fingerprint_axis(self):
        """The stateless-batch refactor's new exec-cache axis: a stateful
        serve executable is keyed by the STATE STRUCTURE (treedef + leaf
        shapes/dtypes — cache layout and the active-slot mask), so the same
        structure reuses one executable across every join/leave while a
        different slot table gets its own entry, never a collision."""
        rt = Runtime(query_batch=8)
        _, srv, ps = _server(rt, slots=4)
        _client(rt, 0, "1,2", "4")
        rt.run(6)
        plan = srv.pipe.plan
        assert plan.stream_serving
        f1 = plan.compiled_serve_tick(srv.state)
        assert plan.compiled_serve_tick(srv.state) is f1  # join/leave reuse
        doubled = dict(srv.state)
        doubled["lm"] = jax.tree_util.tree_map(
            lambda l: jnp.zeros((l.shape[0] * 2,) + l.shape[1:], l.dtype),
            srv.state["lm"])
        assert plan.compiled_serve_tick(doubled) is not f1
        keys = [k for k in plan._cache()["fns"] if k[0] == "serve_tick"]
        assert len(keys) == 2


class TestHotSwapMidDecode:
    def test_swap_commits_mid_decode_bitwise_fresh_build(self):
        """Server-side hot swap while every stream is mid-generation: the
        commit is NOT blocked (only client runs drain on in-flight
        streams), in-flight streams replay on the new epoch, and every
        answer delivered after the commit is bitwise what a freshly built
        server with the new model computes."""
        rt = Runtime(query_batch=8)
        _, srv, ps = _server(rt, model="stablelm-smoke-flash", slots=8)
        cls = [_client(rt, i, f"{i+1},{i+2}", "8") for i in range(3)]
        rt.run(2)                     # mid-generation, nothing delivered
        assert all(len(_answers(r)) == 0 for r in cls)
        old_params = srv.params["lm"]
        rc = rt.reconfigure(srv, srv.pipe.reconfig().swap(
            "lm", element_factory("model_serve", model="stablelm-smoke",
                                  slots="8", max_seq=str(MAX_SEQ))),
            warm_ticks=1)
        assert rc.status == "warming"
        rt.run(2)
        assert rc.status == "committed"   # NOT blocked by in-flight streams
        rt.run(14)
        new_params = srv.params["lm"]
        assert new_params is not old_params
        cfg_new = srv.pipe.elements["lm"].cfg
        for i, run in enumerate(cls):
            # every answer (all post-commit) is the NEW model's, from
            # scratch — bitwise a fresh build
            _check_stream(run, [[i + 1, i + 2]], [8], new_params, cfg_new,
                          min_answers=2)
        qb = rt.stats()["query_batching"]
        assert qb["replays"] == 3             # every in-flight stream replayed
        assert qb["tokens_dropped"] > 0       # partial epochs declared
        assert qb["tokens_generated"] == qb["tokens_delivered"] + \
            qb["tokens_dropped"] + qb["tokens_in_flight"]
        assert rt.stats()["reconfig"]["planned"] == 1


class TestChaosStatefulFailover:
    def test_kill_mid_generation_zero_token_loss_bitwise(self, chaos):
        """THE stateful chaos pin: the serving device dies at tick 4 with
        live KV-cache slots mid-generation.  The orphaned streams'
        PendingQuery records re-dispatch to the survivor, which PREFILL
        REPLAYS them from the retained prompt — greedy decode regenerates
        the identical tokens, so every delivered answer is bitwise the
        fault-free twin's and no client ever sees a truncated stream."""
        ticks = 16

        rt0 = Runtime(query_batch=8)
        _server(rt0, name="hubA")
        _server(rt0, name="hubB")
        ref = [_client(rt0, i, f"{i+1},{i+2},{i+3}", "6") for i in range(3)]
        rt0.run(ticks)

        rt = Runtime(query_batch=8)
        devA, runA, psA = _server(rt, name="hubA")
        devB, runB, psB = _server(rt, name="hubB")
        got = [_client(rt, i, f"{i+1},{i+2},{i+3}", "6") for i in range(3)]
        harness = chaos(rt)
        harness.kill_server(4, devA, psA.elements["ssrc"], crash=True)
        harness.run(ticks)

        for r0, r1 in zip(ref, got):
            a, b = _answers(r0), _answers(r1)
            # the outage delays (replay restarts the generation) but every
            # answer that lands is bitwise the twin's, full length
            assert len(b) >= 2
            for x, y in zip(a, b):
                assert x == y
            assert all(len(y) == 6 for y in b)   # never truncated
        fo = rt.stats()["failover"]
        qb = rt.stats()["query_batching"]
        assert fo["redispatches"] >= 3          # the mid-stream orphans
        assert qb["tokens_dropped"] > 0         # dead epoch's partials
        assert qb["tokens_generated"] == qb["tokens_delivered"] + \
            qb["tokens_dropped"] + qb["tokens_in_flight"]
        assert runB.frames > 0                  # the survivor decoded

    def test_park_deadline_expires_mid_stream_requests(self, chaos):
        """No survivor: mid-generation requests park when their server dies
        and expire at the deadline into client-visible errors — explicit
        degradation, not a silent stall."""
        rt = Runtime(query_batch=8, park_deadline_ticks=3)
        dev, srv, ps = _server(rt)
        cls = [_client(rt, i, f"{i+1},{i+2}", "6") for i in range(2)]
        harness = chaos(rt)
        harness.kill_server(3, dev, ps.elements["ssrc"], crash=True)
        harness.run(10)
        fo = rt.stats()["failover"]
        assert fo["parked_expired"] >= 2
        for r in cls:
            errs = r.sink_log.get("qc.error", [])
            assert len(errs) >= 1
            for e in errs:
                assert e.meta["error"] == "park-deadline"
                assert e.meta["operation"] == "lm"
                assert e.tensors == ()
        qb = rt.stats()["query_batching"]
        assert qb["tokens_dropped"] > 0         # aborted streams declared
        assert qb["tokens_in_flight"] == 0


def _push_raw(ep, client_id, prompt, gen):
    """Push one wire-form streaming request straight onto the endpoint —
    the regression tests drive the batcher below the scheduler."""
    buf = StreamBuffer(tensors=(np.asarray(prompt, np.int32),),
                       meta={"gen": gen, "client_id": client_id,
                             "codec": "none"})
    payload, nbytes = comp.encode(buf, "none")
    ep.requests.push(payload, nbytes)


def _pop_answers(ep, client_id):
    out = []
    ch = ep.client_channel(client_id)
    while True:
        raw = ch.pop()
        if raw is None:
            return out
        out.append(np.asarray(comp.decode(raw, "none").tensors[0]).tolist())


class TestStreamingBatcherRegressions:
    def test_pipelined_prompts_same_client_both_complete(self):
        """A client pipelines a SECOND prompt while its first stream is
        mid-generation.  ``_by_client`` keys per REQUEST (a FIFO of records
        per client) — the old one-record-per-client table overwrote the
        first stream on admit, orphaning it from ``inflight_tokens()`` and
        ``_abort_streams`` (regression)."""
        rt = Runtime(query_batch=8)
        _, srv, ps = _server(rt)
        ep = ps.elements["ssrc"].endpoint
        b = rt._batchers[ep.endpoint_id]
        _push_raw(ep, 777, [1, 2], 6)
        _push_raw(ep, 777, [3, 4], 6)
        rt.ticks += 1
        b.flush()
        assert b.active_streams() == 2          # BOTH tracked
        assert b.in_flight(777)
        # one prefill + one decoded token each (the flush admits AND runs
        # the tick's decode) — the overwrite bug would count only stream 2
        assert b.inflight_tokens() == 4
        for _ in range(8):                      # decode both to completion
            rt.ticks += 1
            b.flush()
        got = _pop_answers(ep, 777)
        assert len(got) == 2
        params, cfg = srv.params["lm"], ps.elements["lm"].cfg
        assert got[0] == _ref(params, cfg, [1, 2], 6)
        assert got[1] == _ref(params, cfg, [3, 4], 6)
        st = b.stats()
        assert st["tokens_generated"] == st["tokens_delivered"] + \
            st["tokens_dropped"] + st["tokens_in_flight"]

    def test_pipelined_prompts_same_client_through_kill(self):
        """Kill the endpoint with two live streams from ONE client: both
        records' partial tokens must be declared drops — the overwrite bug
        hid the first stream from ``_abort_streams``, silently breaking
        the conservation law."""
        rt = Runtime(query_batch=8)
        _, srv, ps = _server(rt)
        ep = ps.elements["ssrc"].endpoint
        b = rt._batchers[ep.endpoint_id]
        _push_raw(ep, 777, [1, 2], 6)
        _push_raw(ep, 777, [3, 4], 6)
        for _ in range(3):
            rt.ticks += 1
            b.flush()
        generated = b.tokens_generated
        assert b.active_streams() == 2 and generated >= 4
        ep.alive = False
        b.flush()
        assert b.active_streams() == 0
        assert b.tokens_dropped == generated    # BOTH streams' partials
        st = b.stats()
        assert st["tokens_in_flight"] == 0
        assert st["tokens_generated"] == st["tokens_delivered"] + \
            st["tokens_dropped"]

    def test_standalone_batcher_decodes_every_flush(self):
        """A batcher built WITHOUT a tick_source (no scheduler) must treat
        every flush as its own decode tick.  The old ``lambda: -1`` default
        satisfied the once-per-tick guard exactly once ever and then froze
        decode forever (regression)."""
        rt = Runtime(query_batch=8)
        _, srv, ps = _server(rt)
        ep = ps.elements["ssrc"].endpoint
        b = StreamingQueryBatcher(ep, srv, rt.batching)   # standalone
        _push_raw(ep, 555, [5, 6], 4)
        for _ in range(5):
            b.flush()
        assert b.decode_ticks >= 3              # decoded every flush
        assert b.streams_finished == 1
        params, cfg = srv.params["lm"], ps.elements["lm"].cfg
        assert _pop_answers(ep, 555) == [_ref(params, cfg, [5, 6], 4)]


class TestEmptyAdmitAliasing:
    def test_fresh_buffer_write_protected_mask(self):
        """``empty_admit`` returns a FRESH buffer (fresh meta dict) every
        call over ONE write-protected mask: the old single cached buffer
        shared its meta dict with every consumer, so one downstream meta
        mutation corrupted every later no-join tick (regression)."""
        elem = element_factory("model_serve", model="stablelm-smoke",
                               slots="4", max_seq="32")
        a, b = elem.empty_admit(), elem.empty_admit()
        assert a is not b
        assert a.meta is not b.meta
        a.meta["corrupted"] = True
        assert "corrupted" not in b.meta
        assert "corrupted" not in elem.empty_admit().meta
        assert a.tensors[0] is b.tensors[0]     # the mask itself may alias...
        with pytest.raises(ValueError):
            a.tensors[0][0] = True              # ...because writes raise


@pytest.mark.soak
def test_decode_soak_conservation_through_churn(chaos):
    """200-tick mixed streaming decode workload (DESIGN.md §7): 8 clients
    with mixed prompt/generation cycles over 4 slots (constant FIFO churn),
    one scripted kill + revival, one mid-run hot swap.  Global invariants:

    * token conservation — ``generated == delivered + dropped + in_flight``
      to the token at the end;
    * every delivered answer is bitwise a sequential decode of its epoch's
      params (pre- or post-swap), whatever the interleaving;
    * the executable cache and the endpoint's per-client response channels
      stay bounded through death/revival/swap."""
    TICKS, KILL_AT, REVIVE_AT, SWAP_AT = 200, 60, 90, 140
    N = 8
    rt = Runtime(query_batch=8)
    dev, srv, ps = _server(rt, slots=4)
    gen_mix = ["4", "3;6", "5;2", "6"]
    cls = [_client(rt, i, f"{i+1},{i+2}", gen_mix[i % 4]) for i in range(N)]

    old_params = [None]

    def swap():
        old_params[0] = srv.params["lm"]
        rt.reconfigure(srv, srv.pipe.reconfig().swap(
            "lm", element_factory("model_serve", model="stablelm-smoke-flash",
                                  slots="4", max_seq=str(MAX_SEQ))),
            warm_ticks=1)

    harness = chaos(rt)
    harness.kill_server(KILL_AT, dev, ps.elements["ssrc"], crash=True)
    harness.revive_server(REVIVE_AT, dev, ps.elements["ssrc"])
    harness.at(SWAP_AT, swap, "hot swap lm mid-run")

    harness.run(150)
    cache_mid = executable_cache_info()
    harness.run(TICKS - 150)

    qb = rt.stats()["query_batching"]
    assert qb["tokens_generated"] == qb["tokens_delivered"] + \
        qb["tokens_dropped"] + qb["tokens_in_flight"]
    assert qb["streams_finished"] >= N * 10      # the workload really churned
    assert qb["tokens_dropped"] > 0              # the kill + swap declared

    # every answer is bitwise sequential for ITS epoch's params
    cfg = srv.pipe.elements["lm"].cfg
    for i, run in enumerate(cls):
        gens = [int(g) for g in gen_mix[i % 4].split(";")]
        for j, ans in enumerate(_answers(run)):
            g = gens[j % len(gens)]
            ok = [_ref(pr, cfg, [i + 1, i + 2], g)
                  for pr in (old_params[0], srv.params["lm"])]
            assert ans in ok, f"client {i} answer {j} off-epoch"

    # bounded caches and channels through death/revival/swap
    cache_end = executable_cache_info()
    assert cache_end["fingerprints"] <= cache_mid["fingerprints"]
    assert cache_end["executables"] <= cache_mid["executables"]
    assert len(ps.elements["ssrc"].endpoint.responses) <= N
    assert rt.stats()["failover"]["parked_now"] == 0
