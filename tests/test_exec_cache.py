"""Executable-registry LRU semantics (core/plan.py, DESIGN.md §1).

DESIGN claims three properties this file pins down:

* the registry is LRU-capped at 128 fingerprints — the 129th distinct
  topology evicts the least-recently-used entry, not the most recent;
* an evicted topology that comes back retraces cleanly (fresh entry, same
  results — eviction is a perf event, never a correctness event);
* anonymous (auto-named) pipelines get fresh element names per parse and
  therefore never alias each other's executables.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import parse_launch
from repro.core.plan import (_EXEC_CACHE, _EXEC_CACHE_MAX,
                             clear_executable_cache, executable_cache_info)


def _pipe(width: int, name: str = "s"):
    return parse_launch(
        f"testsrc name={name} width={width} height=2 ! tensor_converter "
        f"name=c ! appsink name=o").realize()


@pytest.fixture(autouse=True)
def fresh_cache():
    clear_executable_cache()
    yield
    clear_executable_cache()


class TestLRUEviction:
    def test_cap_is_128_and_oldest_evicted(self):
        """Fill past the documented cap with distinct fingerprints; the
        registry stays bounded and evicts in insertion (LRU) order."""
        assert _EXEC_CACHE_MAX == 128  # the DESIGN.md §1 contract
        plans = [_pipe(w + 1).plan for w in range(_EXEC_CACHE_MAX + 2)]
        for p in plans:
            p._cache()  # registry insert without paying a trace
        assert len(_EXEC_CACHE) == _EXEC_CACHE_MAX
        assert plans[0].fingerprint not in _EXEC_CACHE
        assert plans[1].fingerprint not in _EXEC_CACHE
        assert plans[2].fingerprint in _EXEC_CACHE
        assert plans[-1].fingerprint in _EXEC_CACHE

    def test_touch_refreshes_recency(self):
        a, b = _pipe(3).plan, _pipe(4).plan
        a._cache(), b._cache()
        a._cache()  # a is now most recent
        order = list(_EXEC_CACHE)
        assert order == [b.fingerprint, a.fingerprint]

    def test_reencounter_after_eviction_retraces_cleanly(self, monkeypatch):
        import repro.core.plan as planmod
        monkeypatch.setattr(planmod, "_EXEC_CACHE_MAX", 2)
        pipe_a = _pipe(3)
        params = pipe_a.init(jax.random.PRNGKey(0))
        s0 = pipe_a.init_state()
        ref, _ = pipe_a.compiled_step()(params, dict(s0))
        # churn two other topologies through the size-2 registry → a evicted
        for w in (5, 6):
            p = _pipe(w)
            p.compiled_step()(p.init(jax.random.PRNGKey(0)), p.init_state())
        assert pipe_a.plan.fingerprint not in _EXEC_CACHE
        # re-encounter: fresh trace, identical results
        out, _ = pipe_a.compiled_step()(params, dict(s0))
        assert pipe_a.plan.fingerprint in _EXEC_CACHE
        np.testing.assert_array_equal(np.asarray(ref["o"].tensor),
                                      np.asarray(out["o"].tensor))

    def test_eviction_keeps_executable_count_consistent(self, monkeypatch):
        import repro.core.plan as planmod
        monkeypatch.setattr(planmod, "_EXEC_CACHE_MAX", 2)
        for w in range(3, 8):
            _pipe(w).compiled_step()
        info = executable_cache_info()
        assert info["fingerprints"] == 2
        assert info["executables"] == 2  # one jitted step per fingerprint


class TestAnonymousPipelinesNeverAlias:
    DESC = ("testsrc width=6 height=2 ! tensor_converter ! "
            "tensor_transform mode=arithmetic option=typecast:float32 ! "
            "appsink")

    def test_fresh_names_fresh_fingerprints(self):
        p1 = parse_launch(self.DESC).realize()
        p2 = parse_launch(self.DESC).realize()
        assert p1.plan.fingerprint != p2.plan.fingerprint
        assert p1.compiled_step() is not p2.compiled_step()
        assert executable_cache_info()["fingerprints"] == 2

    def test_anonymous_results_still_correct(self):
        p1 = parse_launch(self.DESC).realize()
        p2 = parse_launch(self.DESC).realize()
        o1, _ = p1.compiled_step()(p1.init(jax.random.PRNGKey(0)),
                                   p1.init_state())
        o2, _ = p2.compiled_step()(p2.init(jax.random.PRNGKey(0)),
                                   p2.init_state())
        (s1,), (s2,) = o1.values(), o2.values()
        np.testing.assert_array_equal(np.asarray(s1.tensor),
                                      np.asarray(s2.tensor))

    def test_named_pipelines_do_alias(self):
        """Control: identical NAMED topologies share one executable — the
        cross-pipeline sharing the anonymous case must not get."""
        desc = ("testsrc name=s width=6 height=2 ! tensor_converter name=c ! "
                "appsink name=o")
        p1 = parse_launch(desc).realize()
        p2 = parse_launch(desc).realize()
        assert p1.plan.fingerprint == p2.plan.fingerprint
        assert p1.compiled_step() is p2.compiled_step()
