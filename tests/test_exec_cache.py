"""Executable-registry LRU semantics (core/plan.py, DESIGN.md §1).

DESIGN claims three properties this file pins down:

* the registry is LRU-capped at 128 fingerprints — the 129th distinct
  topology evicts the least-recently-used entry, not the most recent;
* an evicted topology that comes back retraces cleanly (fresh entry, same
  results — eviction is a perf event, never a correctness event);
* anonymous (auto-named) pipelines get fresh element names per parse and
  therefore never alias each other's executables;
* reconfiguration churn (DESIGN.md §6) — repeated hot swaps interleaved
  with failover kills/revivals — never retraces an unchanged fingerprint
  and keeps the registry LRU-bounded.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import parse_launch
from repro.core.plan import (_EXEC_CACHE, _EXEC_CACHE_MAX,
                             clear_executable_cache, executable_cache_info)


def _pipe(width: int, name: str = "s"):
    return parse_launch(
        f"testsrc name={name} width={width} height=2 ! tensor_converter "
        f"name=c ! appsink name=o").realize()


@pytest.fixture(autouse=True)
def fresh_cache():
    clear_executable_cache()
    yield
    clear_executable_cache()


class TestLRUEviction:
    def test_cap_is_128_and_oldest_evicted(self):
        """Fill past the documented cap with distinct fingerprints; the
        registry stays bounded and evicts in insertion (LRU) order."""
        assert _EXEC_CACHE_MAX == 128  # the DESIGN.md §1 contract
        plans = [_pipe(w + 1).plan for w in range(_EXEC_CACHE_MAX + 2)]
        for p in plans:
            p._cache()  # registry insert without paying a trace
        assert len(_EXEC_CACHE) == _EXEC_CACHE_MAX
        assert plans[0].fingerprint not in _EXEC_CACHE
        assert plans[1].fingerprint not in _EXEC_CACHE
        assert plans[2].fingerprint in _EXEC_CACHE
        assert plans[-1].fingerprint in _EXEC_CACHE

    def test_touch_refreshes_recency(self):
        a, b = _pipe(3).plan, _pipe(4).plan
        a._cache(), b._cache()
        a._cache()  # a is now most recent
        order = list(_EXEC_CACHE)
        assert order == [b.fingerprint, a.fingerprint]

    def test_reencounter_after_eviction_retraces_cleanly(self, monkeypatch):
        import repro.core.plan as planmod
        monkeypatch.setattr(planmod, "_EXEC_CACHE_MAX", 2)
        pipe_a = _pipe(3)
        params = pipe_a.init(jax.random.PRNGKey(0))
        s0 = pipe_a.init_state()
        ref, _ = pipe_a.compiled_step()(params, dict(s0))
        # churn two other topologies through the size-2 registry → a evicted
        for w in (5, 6):
            p = _pipe(w)
            p.compiled_step()(p.init(jax.random.PRNGKey(0)), p.init_state())
        assert pipe_a.plan.fingerprint not in _EXEC_CACHE
        # re-encounter: fresh trace, identical results
        out, _ = pipe_a.compiled_step()(params, dict(s0))
        assert pipe_a.plan.fingerprint in _EXEC_CACHE
        np.testing.assert_array_equal(np.asarray(ref["o"].tensor),
                                      np.asarray(out["o"].tensor))

    def test_eviction_keeps_executable_count_consistent(self, monkeypatch):
        import repro.core.plan as planmod
        monkeypatch.setattr(planmod, "_EXEC_CACHE_MAX", 2)
        for w in range(3, 8):
            _pipe(w).compiled_step()
        info = executable_cache_info()
        assert info["fingerprints"] == 2
        assert info["executables"] == 2  # one jitted step per fingerprint


class TestAnonymousPipelinesNeverAlias:
    DESC = ("testsrc width=6 height=2 ! tensor_converter ! "
            "tensor_transform mode=arithmetic option=typecast:float32 ! "
            "appsink")

    def test_fresh_names_fresh_fingerprints(self):
        p1 = parse_launch(self.DESC).realize()
        p2 = parse_launch(self.DESC).realize()
        assert p1.plan.fingerprint != p2.plan.fingerprint
        assert p1.compiled_step() is not p2.compiled_step()
        assert executable_cache_info()["fingerprints"] == 2

    def test_anonymous_results_still_correct(self):
        p1 = parse_launch(self.DESC).realize()
        p2 = parse_launch(self.DESC).realize()
        o1, _ = p1.compiled_step()(p1.init(jax.random.PRNGKey(0)),
                                   p1.init_state())
        o2, _ = p2.compiled_step()(p2.init(jax.random.PRNGKey(0)),
                                   p2.init_state())
        (s1,), (s2,) = o1.values(), o2.values()
        np.testing.assert_array_equal(np.asarray(s1.tensor),
                                      np.asarray(s2.tensor))

    def test_named_pipelines_do_alias(self):
        """Control: identical NAMED topologies share one executable — the
        cross-pipeline sharing the anonymous case must not get."""
        desc = ("testsrc name=s width=6 height=2 ! tensor_converter name=c ! "
                "appsink name=o")
        p1 = parse_launch(desc).realize()
        p2 = parse_launch(desc).realize()
        assert p1.plan.fingerprint == p2.plan.fingerprint
        assert p1.compiled_step() is p2.compiled_step()


class TestReconfigurationChurn:
    """Hot-swap cycles under chaos must leave the registry warm and
    bounded: once both sides of an A↔B swap have been seen, further cycles
    — with failover kills/revivals interleaved — create ZERO new jax.jit
    executables (an unchanged fingerprint never retraces) and never grow
    ``executable_cache_info()``."""

    @pytest.fixture(autouse=True)
    def _models(self):
        from repro.core import TensorSpec
        from repro.core.elements import register_model

        def init_a(rng):
            return {"w": jnp.linspace(-1.0, 1.0, 48).reshape(12, 4)}

        def apply_a(p, x):
            return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

        def init_b(rng):
            return {"w": jnp.linspace(1.0, -1.0, 48).reshape(12, 4)}

        def apply_b(p, x):
            return x.astype(jnp.float32).reshape(1, -1) @ p["w"] * 2.0

        def init_c(rng):
            return {"w": jnp.zeros((12, 4), jnp.float32)}

        def apply_c(p, x):
            return x.astype(jnp.float32).reshape(1, -1) @ p["w"] - 1.0

        specs = (TensorSpec((1, 4), "float32"),)
        register_model("churnA", init_a, apply_a, out_specs=specs)
        register_model("churnB", init_b, apply_b, out_specs=specs)
        register_model("churnC", init_c, apply_c, out_specs=specs)

    def _fleet(self):
        from repro.runtime import Device, Runtime
        rt = Runtime(query_batch=4)
        hub = Device("hub")
        sp = parse_launch(
            "tensor_query_serversrc operation=churn name=ssrc ! "
            "tensor_filter model=churnA name=filt ! "
            "tensor_query_serversink name=ssink")
        sp.elements["ssink"].pair_with(sp.elements["ssrc"])
        hub_run = hub.add_pipeline(sp, jit=False)
        rt.add_device(hub)
        bak = Device("bak")
        bp = parse_launch(
            "tensor_query_serversrc operation=churn name=bssrc ! "
            "tensor_filter model=churnA name=bfilt ! "
            "tensor_query_serversink name=bssink")
        bp.elements["bssink"].pair_with(bp.elements["bssrc"])
        bak.add_pipeline(bp, jit=False)
        rt.add_device(bak)
        cl = Device("cl")
        pc = parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_query_client operation=churn name=qc ! appsink name=res")
        cl_run = cl.add_pipeline(pc, jit=False)
        rt.add_device(cl)
        return rt, hub_run, (bak, bp.elements["bssrc"]), cl_run

    @staticmethod
    def _cycle(chaos, rt, hub_run, bak, bssrc, model):
        """One churn cycle: planned swap of the serving model with an
        unplanned kill/revival of the backup server inside its warm
        window."""
        from repro.core.element import element_factory
        t = rt.ticks
        harness = chaos(rt)
        harness.kill_server(t + 1, bak, bssrc)
        harness.revive_server(t + 2, bak, bssrc)
        rc = rt.reconfigure(
            hub_run, hub_run.pipe.reconfig().swap(
                "filt", element_factory("tensor_filter", model=model)),
            warm_ticks=1)
        harness.run(3)
        assert rc.status == "committed"
        return rc

    def test_swap_cycles_never_retrace_unchanged_fingerprints(
            self, monkeypatch, chaos):
        rt, hub_run, (bak, bssrc), cl_run = self._fleet()
        rt.run(2)
        # warm-up: both swap targets seen once → both fingerprints (and
        # their warmed executable sets) live in the registry
        self._cycle(chaos, rt, hub_run, bak, bssrc, "churnB")
        self._cycle(chaos, rt, hub_run, bak, bssrc, "churnA")
        info_warm = executable_cache_info()

        calls = []
        orig_jit = jax.jit
        monkeypatch.setattr(
            jax, "jit",
            lambda *a, **k: calls.append(a) or orig_jit(*a, **k))
        for model in ("churnB", "churnA", "churnB", "churnA"):
            self._cycle(chaos, rt, hub_run, bak, bssrc, model)
        assert calls == []                     # zero new executables
        assert executable_cache_info() == info_warm
        assert cl_run.frames == rt.ticks       # the stream never stalled
        # control against a vacuous pass: a genuinely NEW topology does
        # create executables through exactly the intercepted call
        self._cycle(chaos, rt, hub_run, bak, bssrc, "churnC")
        assert calls, "counting hook must see real executable creation"
        assert executable_cache_info()["fingerprints"] > \
            info_warm["fingerprints"]

    def test_churn_stays_lru_bounded_and_correct(self, monkeypatch, chaos):
        """With the registry capped far below the working set, churn cycles
        evict and retrace — bounded memory, and still zero frame loss."""
        import repro.core.plan as planmod
        monkeypatch.setattr(planmod, "_EXEC_CACHE_MAX", 3)
        rt, hub_run, (bak, bssrc), cl_run = self._fleet()
        rt.run(2)
        for model in ("churnB", "churnA", "churnB", "churnA"):
            self._cycle(chaos, rt, hub_run, bak, bssrc, model)
        assert len(_EXEC_CACHE) <= 3
        assert cl_run.frames == rt.ticks
        assert rt.stats()["reconfig"]["planned"] == 4
        assert rt.stats()["reconfig"]["rollbacks"] == 0
