"""Stream data types (paper §4.1): static/flexible/sparse formats + caps."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Caps, CapsError, TensorFormat, TensorSpec,
                        flex_unwrap, flex_wrap)
from repro.core.pipeline import parse_caps


class TestTensorSpec:
    def test_static_compat_exact(self):
        a = TensorSpec((3, 4), "float32")
        assert a.compatible(TensorSpec((3, 4), "float32"))
        assert not a.compatible(TensorSpec((4, 3), "float32"))
        assert not a.compatible(TensorSpec((3, 4), "int32"))

    def test_flexible_capacity(self):
        small = TensorSpec((16,), "float32", TensorFormat.FLEXIBLE)
        big = TensorSpec((64,), "float32", TensorFormat.FLEXIBLE)
        assert small.compatible(big)
        assert not big.compatible(small)

    def test_sparse_needs_nnz_bound(self):
        sp = TensorSpec((8, 8), "float32", TensorFormat.SPARSE)
        assert sp.max_nnz == 64  # defaults to dense size

    def test_rank_limit(self):
        with pytest.raises(CapsError):
            TensorSpec((1, 2, 3, 4, 5))

    def test_bad_dtype(self):
        with pytest.raises(CapsError):
            TensorSpec((2,), "complex64")


class TestCaps:
    def test_any_intersection(self):
        c = Caps(media="other/tensors", tensors=(TensorSpec((2, 2)),))
        assert Caps.ANY.intersect(c) is c
        assert c.intersect(Caps.ANY) is c

    def test_media_mismatch(self):
        with pytest.raises(CapsError):
            Caps(media="video/x-raw").intersect(Caps(media="other/tensors"))

    def test_num_tensors_mismatch(self):
        a = Caps(tensors=(TensorSpec((2,)),))
        b = Caps(tensors=(TensorSpec((2,)), TensorSpec((3,))))
        with pytest.raises(CapsError):
            a.intersect(b)


class TestParseCaps:
    def test_video(self):
        c = parse_caps("video/x-raw,width=300,height=300,format=RGB")
        assert c.tensors[0].shape == (300, 300, 3)

    def test_nnstreamer_dims(self):
        # NNStreamer dims are innermost-first (Listing 2 of the paper)
        c = parse_caps('other/tensors,num_tensors=4,dimensions=4:20:1:1,'
                       '20:1:1:1,20:1:1:1,1:1:1:1,types=float32,float32,'
                       'float32,float32')
        assert c.num_tensors == 4
        assert c.tensors[0].shape == (20, 4)
        assert c.tensors[1].shape == (20,)

    def test_flexible_format(self):
        c = parse_caps("other/tensors,format=flexible,dimensions=8:1:1:1,types=float32")
        assert c.tensors[0].format == TensorFormat.FLEXIBLE


class TestFlexible:
    @given(st.integers(1, 6), st.integers(1, 6))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip(self, h, w):
        x = jnp.arange(h * w, dtype=jnp.float32).reshape(h, w)
        payload, hdr = flex_wrap(x, capacity=64)
        assert payload.shape == (64,)
        assert int(hdr.valid) == h * w
        y = flex_unwrap(payload, hdr, static_shape=(h, w))
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_capacity_overflow(self):
        with pytest.raises(ValueError):
            flex_wrap(jnp.zeros((100,)), capacity=10)
