"""Server-side query micro-batching (core/batching.py, DESIGN.md §2).

Semantics-preservation contract: for every batch size, each client's
response stream is IDENTICAL (bitwise, per execution mode) to the
sequential one-round-trip-per-frame path — batching may only change how
many dispatches the server pays, never what any client sees.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Broker, StreamBuffer, TensorSpec, parse_launch)
from repro.core.batching import BatchingPolicy, QueryBatcher
from repro.core.elements import register_model
from repro.core.plan import PendingQuery
from repro.edge.edge import EdgeQueryClient
from repro.runtime import Device, Runtime


@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jax.random.normal(rng, (12, 4)) * 0.3}

    def apply(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    register_model("qbsvc", init, apply,
                   out_specs=(TensorSpec((1, 4), "float32"),))

    def apply_stateful(p, x):
        return jnp.cumsum(x.astype(jnp.float32).reshape(-1))[:4].reshape(1, 4)

    register_model("qbsvc2", lambda rng: {}, apply_stateful,
                   out_specs=(TensorSpec((1, 4), "float32"),))


def _server(rt, name="hub", operation="op", model="qbsvc"):
    dev = Device(name)
    ps = parse_launch(
        f"tensor_query_serversrc operation={operation} name=ssrc ! "
        f"tensor_filter model={model} ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return run, ps.elements["ssrc"]


def _clients(rt, n, operation="op", codec="none", width=2):
    runs = []
    for i in range(n):
        dev = Device(f"tv{i}")
        pc = parse_launch(
            f"testsrc width={width} height=2 ! tensor_converter ! "
            f"tensor_query_client operation={operation} codec={codec} "
            f"name=qc ! appsink name=res")
        runs.append(dev.add_pipeline(pc, jit=False))
        rt.add_device(dev)
    return runs


def _responses(run):
    return [np.asarray(b.tensor) for b in run.sink_log["res"]]


class TestSemanticsPreserving:
    @pytest.mark.parametrize("batch", [1, 4, 8])
    def test_batched_matches_sequential_bitwise(self, batch):
        """Acceptance: responses at batch {1,4,8} == sequential responses.

        The sequential reference (query_batch=0) serves interpreted; the
        batched path serves through the jitted hoisted scan.  On this
        element set the two execution modes agree bitwise; the per-mode
        guarantee is pinned separately below."""
        ticks, n_clients = 3, 8
        rt_seq = Runtime(query_batch=0)
        _server(rt_seq)
        seq_runs = _clients(rt_seq, n_clients)
        rt_seq.run(ticks)

        rt_b = Runtime(query_batch=batch)
        srv_run, _ = _server(rt_b)
        b_runs = _clients(rt_b, n_clients)
        rt_b.run(ticks)

        for sr, br in zip(seq_runs, b_runs):
            assert sr.frames == ticks and br.frames == ticks
            for a, b in zip(_responses(sr), _responses(br)):
                np.testing.assert_array_equal(a, b)
        # server served every request exactly once
        assert srv_run.frames == ticks * n_clients

    def test_batch_sizes_agree_bitwise_with_each_other(self):
        """Same execution mode (compiled hoisted scan) across batch sizes:
        scan-of-1 vs scan-of-4 vs scan-of-8 must agree bitwise — batch
        composition must never leak into any client's numerics."""
        streams = {}
        for batch in (1, 4, 8):
            rt = Runtime(query_batch=batch)
            _server(rt)
            runs = _clients(rt, 8)
            rt.run(2)
            streams[batch] = [_responses(r) for r in runs]
        for batch in (4, 8):
            for ref, got in zip(streams[1], streams[batch]):
                for a, b in zip(ref, got):
                    np.testing.assert_array_equal(a, b)

    def test_server_state_threads_in_arrival_order(self):
        """Stateless here, but arrival order still defines the scan order;
        client ids must route answers regardless of batch position."""
        rt = Runtime(query_batch=8)
        _server(rt)
        runs = _clients(rt, 5)
        rt.run(2)
        ids = [r.pipe.elements["qc"].client_id for r in runs]
        assert len(set(ids)) == 5
        for r in runs:
            assert len(r.sink_log["res"]) == 2


class TestBatchingMechanics:
    def test_one_dispatch_per_tick_at_batch_8(self):
        rt = Runtime(query_batch=8)
        srv_run, _ = _server(rt)
        _clients(rt, 8)
        rt.run(3)
        qb = rt.stats()["query_batching"]
        assert qb["batched_frames"] == 24
        assert qb["sequential_frames"] == 0
        assert qb["flushes"] == 3              # exactly one flush per tick
        assert srv_run.bursts == 3             # one scan dispatch per flush
        assert srv_run.burst_frames == 24

    def test_batch_1_serves_through_compiled_path(self):
        """Regression: ``max_batch == 1`` used to be shunted onto the
        sequential interpreted fallback (`max_batch > 1` in flush),
        contradicting the module contract that a group of one still serves
        through the compiled hoisted path — turning the batch knob down to 1
        silently changed execution mode.  Batch 1 must batch."""
        rt = Runtime(query_batch=1)
        srv_run, _ = _server(rt)
        _clients(rt, 3)
        rt.run(2)
        qb = rt.stats()["query_batching"]
        assert qb["batched_frames"] == 6
        assert qb["sequential_frames"] == 0
        assert srv_run.frames == 6

    def test_batch_1_matches_larger_batches_bitwise(self):
        """...and the compiled group-of-one agrees bitwise with the compiled
        scan-of-8, so the knob never leaks into numerics."""
        streams = {}
        for batch in (1, 8):
            rt = Runtime(query_batch=batch)
            _server(rt)
            runs = _clients(rt, 4)
            rt.run(2)
            streams[batch] = [_responses(r) for r in runs]
        for ref, got in zip(streams[1], streams[8]):
            for a, b in zip(ref, got):
                np.testing.assert_array_equal(a, b)

    def test_max_batch_chunks_oversized_ticks(self):
        rt = Runtime(query_batch=4)
        srv_run, _ = _server(rt)
        _clients(rt, 8)
        rt.run(1)
        assert srv_run.bursts == 2             # 8 requests → two scan-4s
        assert rt.stats()["query_batching"]["batched_frames"] == 8

    def test_flush_on_full_serves_before_tick_deadline(self):
        rt = Runtime(query_batch=2)
        srv_run, ssrc = _server(rt)
        _clients(rt, 4)
        rt.run(1)
        # 4 clients, batch cap 2: the batcher flushed mid-gather at least
        # once (full()), leaving nothing for the deadline flush to do twice
        assert srv_run.frames == 4
        assert len(ssrc.endpoint.requests) == 0

    def test_mixed_client_caps_fall_back_to_grouped_serving(self):
        """Clients with different tensor shapes cannot share one stacked
        scan: consecutive same-structure groups serve separately, answers
        stay correct per client."""
        rt = Runtime(query_batch=8)
        srv_run, _ = _server(rt, model="qbsvc2")
        wide = _clients(rt, 2, width=3)
        narrow = _clients(rt, 2, width=2)
        rt.run(2)
        for r in wide + narrow:
            assert r.frames == 2
            assert r.last_outputs["res"].tensor.shape == (1, 4)
        assert srv_run.frames == 8

    def test_mixed_codecs_group_by_codec(self):
        """PR-5 contract: the fused wire path decodes/encodes INSIDE the
        serving jit with the codec as a static trace parameter, so mixed-
        codec ticks split into consecutive same-codec groups — exactly how
        mixed-structure ticks have always split — and every client still
        matches its own sequential stream bitwise."""
        def build(batch, **kw):
            rt = Runtime(query_batch=batch, **kw)
            _server(rt)
            runs = _clients(rt, 2, codec="none") + \
                _clients(rt, 2, codec="quant8")
            rt.run(2)
            return rt, runs

        rt_b, batched = build(8)
        qb = rt_b.stats()["query_batching"]
        assert qb["batches"] == 4          # one per codec group per tick
        # quant8 groups fuse; "none" groups have nothing to fuse and keep
        # the lazy eager path (no per-flush answer fetch)
        assert qb["fused_frames"] == 4
        _, seq = build(0)
        for br, sr in zip(batched, seq):
            for a, b in zip(_responses(br), _responses(sr)):
                np.testing.assert_array_equal(a, b)

    def test_eager_wire_path_still_batches_mixed_codecs_together(self):
        """The PR-4 eager path (fused_wire=False) is preserved as the
        benchmark baseline: codec is routing meta there, one batch per
        tick, and it still agrees bitwise with sequential serving."""
        rt = Runtime(query_batch=8, fused_wire=False)
        _server(rt)
        runs = _clients(rt, 2, codec="none") + _clients(rt, 2, codec="quant8")
        rt.run(2)
        qb = rt.stats()["query_batching"]
        assert qb["batches"] == 2 and qb["fused_frames"] == 0
        rt_s = Runtime(query_batch=0)
        _server(rt_s)
        seq = _clients(rt_s, 2, codec="none") + _clients(rt_s, 2,
                                                         codec="quant8")
        rt_s.run(2)
        for br, sr in zip(runs, seq):
            for a, b in zip(_responses(br), _responses(sr)):
                np.testing.assert_array_equal(a, b)

    def test_non_batchable_server_plan_serves_sequentially(self):
        """Server plans the hoisted scan cannot express (extra impure
        elements, multiple serversrcs) must serve every request through the
        legacy interpreted step; forcing the flag exercises that fallback
        without building an exotic topology."""
        rt = Runtime(query_batch=8)
        srv_run, ssrc = _server(rt)
        srv_run.pipe.plan.query_batchable = False  # force the fallback
        _clients(rt, 4)
        rt.run(2)
        qb = rt.stats()["query_batching"]
        assert qb["sequential_frames"] == 8 and qb["batched_frames"] == 0
        assert srv_run.frames == 8

    def test_gather_never_overflows_request_channel(self):
        """Backpressure regression: with more concurrent clients than the
        request Channel's capacity (64) and a batch cap that would gather
        past it, the batcher must flush at the capacity floor instead of
        leaky-dropping requests (which killed the whole tick with
        BrokerError 'no answer')."""
        rt = Runtime(query_batch=BatchingPolicy(max_batch=100,
                                                flush_on_full=False))
        srv_run, ssrc = _server(rt)
        runs = _clients(rt, 70)
        rt.run(1)
        assert srv_run.frames == 70
        for r in runs:
            assert r.frames == 1
        assert ssrc.endpoint.requests.drops == 0

    def test_edge_client_contract_unchanged(self):
        """EdgeQueryClient.infer must still get its answer before returning
        (the endpoint's inline_runner is now the batcher's flush)."""
        rt = Runtime(query_batch=8)
        _server(rt)
        ec = EdgeQueryClient(rt.broker, "op")
        out = ec.infer([np.arange(12, dtype=np.uint8).reshape(2, 2, 3)])
        assert out[0].shape == (1, 4)

    def test_failover_mid_stream_keeps_batching(self):
        rt = Runtime(query_batch=8)
        run1, ssrc1 = _server(rt, name="hub1")
        run2, ssrc2 = _server(rt, name="hub2")
        runs = _clients(rt, 4)
        rt.run(1)
        assert run1.frames == 4 and run2.frames == 0
        ssrc1.endpoint.alive = False
        rt.broker.mark_down(ssrc1.registration)
        rt.run(2)
        assert run2.frames == 8  # all four clients re-bound and batched
        for r in runs:
            assert r.frames == 3

    def test_trace_cached_per_batch_size(self):
        """Batch sizes are jit trace dimensions within one fingerprint —
        ticking twice at one size must not add executables."""
        rt = Runtime(query_batch=8)
        srv_run, _ = _server(rt)
        _clients(rt, 8)
        rt.run(1)
        fns = srv_run.pipe.plan._cache()["fns"]
        n_after_first = len(fns)
        rt.run(3)
        assert len(fns) == n_after_first


class TestPlanFlags:
    def test_server_plan_is_query_batchable(self):
        ps = parse_launch(
            "tensor_query_serversrc operation=x name=ssrc ! "
            "tensor_filter model=qbsvc ! tensor_query_serversink name=ssink")
        ps.elements["ssink"].pair_with(ps.elements["ssrc"])
        ps.realize()
        assert ps.plan.query_batchable
        assert not ps.plan.burstable  # runtime bursts still refuse servers

    def test_client_plan_has_query_clients(self):
        pc = parse_launch(
            "testsrc ! tensor_converter ! tensor_query_client operation=x "
            "name=qc ! appsink name=o").realize()
        assert pc.plan.has_query_clients
        assert not pc.plan.query_batchable

    def test_deferred_run_pauses_and_resumes(self):
        pc = parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_query_client operation=x name=qc ! appsink name=o"
        ).realize()
        params, s0 = pc.init(jax.random.PRNGKey(0)), pc.init_state()
        pq = pc.plan.run_deferred(params, s0)
        assert isinstance(pq, PendingQuery)
        assert pq.client is pc.elements["qc"]
        assert pq.request.tensor.shape == (2, 2, 3)
        answer = pq.request.with_(tensors=(jnp.ones((1, 4)),))
        res = pq.resume(answer)
        assert not isinstance(res, PendingQuery)
        outputs, state = res
        np.testing.assert_array_equal(np.asarray(outputs["o"].tensor),
                                      np.ones((1, 4)))
        src_name = next(n for n, e in pc.elements.items()
                        if e.factory_name == "testsrc")
        assert int(state[src_name]["frame"]) == 1  # upstream stepped once

    def test_policy_coercion(self):
        assert BatchingPolicy.of(8).max_batch == 8
        assert not BatchingPolicy.of(0).enabled
        p = BatchingPolicy(max_batch=4, flush_on_full=False)
        assert BatchingPolicy.of(p) is p
