"""Zero-loss live reconfiguration — prepare/commit hot swap (DESIGN.md §6).

A topology edit on a RUNNING pipeline (swap an element, re-route a link,
add/remove an endpoint or pubsub binding) is a first-class runtime
operation: ``Runtime.reconfigure`` prepares and warms the new plan off the
serving path, commits at a tick boundary with queued frames and in-flight
queries carried across, and rolls back cleanly when the prepare fails or
the target dies mid-warm.

Acceptance contract pinned here (and gated in benchmarks/bench_reconfig.py):

* the hot swap commits at a tick boundary with ZERO frames lost and every
  post-commit answer bitwise identical to a freshly-built pipeline at
  query batch 1, 4 and 8;
* a chaos kill landing during the prepare/warm window never leaves the
  reconfiguration in limbo — it terminates ``rolled_back`` (or
  ``committed``), with the old topology serving untouched;
* failover itself routes through the same machinery: a server death or
  revival shows up as an UNPLANNED reconfiguration in ``Runtime.stats``.

The swapped models use DETERMINISTIC inits (independent of the rng path)
so a swapped-in element's params are bitwise what a fresh build computes —
the bitwise comparisons compare serving, not rng bookkeeping.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TensorSpec, parse_launch
from repro.core.element import element_factory
from repro.core.elements import register_model
from repro.core.reconfig import ReconfigError
from repro.runtime import Device, Runtime

pytestmark = pytest.mark.reconfig


@pytest.fixture(scope="module", autouse=True)
def models():
    # deterministic inits: params depend on nothing but the model, so the
    # hot-swapped element and the fresh-build reference are bitwise equal
    def init_a(rng):
        return {"w": jnp.linspace(-1.0, 1.0, 48).reshape(12, 4)}

    def apply_a(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    def init_b(rng):
        return {"w": jnp.linspace(1.0, -1.0, 48).reshape(12, 4),
                "b": jnp.full((4,), 0.5)}

    def apply_b(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"] + p["b"]

    register_model("rcA", init_a, apply_a,
                   out_specs=(TensorSpec((1, 4), "float32"),))
    register_model("rcB", init_b, apply_b,
                   out_specs=(TensorSpec((1, 4), "float32"),))


def _server(rt, model, name="hub"):
    dev = Device(name)
    ps = parse_launch(
        f"tensor_query_serversrc operation=svc name=ssrc ! "
        f"tensor_filter model={model} name=filt ! "
        f"tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return dev, run, ps.elements["ssrc"]


def _clients(rt, n):
    runs = []
    for i in range(n):
        dev = Device(f"tv{i}")
        pc = parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_query_client operation=svc name=qc ! appsink name=res")
        runs.append(dev.add_pipeline(pc, jit=False))
        rt.add_device(dev)
    return runs


def _responses(run):
    return [np.asarray(b.tensor) for b in run.sink_log["res"]]


def _swap_filt(run, model):
    return run.pipe.reconfig().swap(
        "filt", element_factory("tensor_filter", model=model))


class TestHotSwap:
    @pytest.mark.parametrize("query_batch", [1, 4, 8])
    def test_swap_commits_at_tick_boundary_bitwise_identical(self,
                                                             query_batch):
        """THE acceptance scenario: swap the serving model under live
        traffic.  Every pre-commit answer is bitwise the old model's, every
        answer from the commit tick onward is bitwise what a pipeline BUILT
        with the new model computes — and not one frame is lost to the
        cutover, at batch 1, 4 and 8."""
        ticks_pre, ticks_post, n_clients = 4, 6, 3
        total = ticks_pre + ticks_post

        refs = {}
        for model in ("rcA", "rcB"):
            rt0 = Runtime(query_batch=query_batch)
            _server(rt0, model)
            refs[model] = _clients(rt0, n_clients)
            rt0.run(total)

        rt = Runtime(query_batch=query_batch)
        _, hub_run, _ = _server(rt, "rcA")
        cl = _clients(rt, n_clients)
        rt.run(ticks_pre)
        rc = rt.reconfigure(hub_run, _swap_filt(hub_run, "rcB"),
                            warm_ticks=1)
        assert rc.status == "warming"          # prepared+warmed off-path
        rt.run(ticks_post)

        assert rc.status == "committed"
        # tick boundary: warm window of 1 tick after the request tick, then
        # the commit lands at the TOP of the next tick — which is therefore
        # the first tick served by the new plan
        assert rc.committed_tick == ticks_pre + 2
        cut = rc.committed_tick - 1            # index of first new answer
        for ref_a, ref_b, got in zip(refs["rcA"], refs["rcB"], cl):
            assert got.frames == total         # zero lost requests
            a, b, g = _responses(ref_a), _responses(ref_b), _responses(got)
            assert len(g) == total
            for x, y in zip(a[:cut], g[:cut]):
                np.testing.assert_array_equal(x, y)   # old epoch: bitwise A
            for x, y in zip(b[cut:], g[cut:]):
                np.testing.assert_array_equal(x, y)   # new epoch: bitwise B
        assert "b" in hub_run.params["filt"]   # the swapped params landed
        st = rt.stats()["reconfig"]
        assert st["planned"] == 1 and st["reconfigs"] == 1
        assert st["rollbacks"] == 0 and st["pending"] == 0

    def test_relink_and_remove_reroute_midstream(self):
        """Re-route a link around an element and drop it, mid-stream: the
        sink's input dtype flips exactly at the commit tick and no frame is
        lost on either side of the cutover.  Also exercises the callable
        edit form (``reconfigure(run, lambda plan: ...)``)."""
        rt = Runtime()
        dev = Device("edge")
        p = parse_launch(
            "testsrc name=s width=3 height=2 ! tensor_converter name=c ! "
            "tensor_transform mode=arithmetic option=typecast:float32 "
            "name=t ! appsink name=o")
        run = dev.add_pipeline(p, jit=False)
        rt.add_device(dev)
        rt.run(4)
        rc = rt.reconfigure(run, lambda plan: plan.relink("c", "o")
                            .remove("t"), warm_ticks=1)
        rt.run(4)
        assert rc.status == "committed"
        assert "t" not in run.pipe.elements
        log = run.sink_log["o"]
        assert len(log) == 8                   # zero loss across the cutover
        # control: what the converter emits without the typecast stage
        ctrl = parse_launch("testsrc name=s2 width=3 height=2 ! "
                            "tensor_converter name=c2 ! appsink name=o2")
        cdev = Device("ctrl")
        crun = cdev.add_pipeline(ctrl, jit=False)
        crt = Runtime()
        crt.add_device(cdev)
        crt.tick()
        native = crun.sink_log["o2"][0].tensor.dtype
        assert native != jnp.float32           # the transform did something
        cut = rc.committed_tick - 1
        assert all(b.tensor.dtype == jnp.float32 for b in log[:cut])
        assert all(b.tensor.dtype == native for b in log[cut:])

    def test_remove_all_decommissions_and_clients_rebind(self):
        """Removing every element retires the run: its registrations
        unregister at commit and the clients re-bind to the surviving hub
        with zero frames lost — a planned decommission is the graceful twin
        of the chaos kill."""
        total = 8
        rt = Runtime(query_batch=8)
        _, run_a, ssrc_a = _server(rt, "rcA", name="hubA")
        _, run_b, _ = _server(rt, "rcA", name="hubB")
        cl = _clients(rt, 3)
        rt.run(3)
        rc = rt.reconfigure(run_a, run_a.pipe.reconfig()
                            .remove("ssrc").remove("filt").remove("ssink"),
                            warm_ticks=1)
        rt.run(total - 3)
        assert rc.status == "committed"
        assert run_a.retired
        assert ssrc_a.registration is None     # left the control plane
        assert all(r.frames == total for r in cl)   # zero loss
        # hubB took over from the commit tick onward
        assert run_b.frames >= 3 * (total - rc.committed_tick + 1)
        st = rt.stats()["reconfig"]
        # the commit's own unregister events are its bookkeeping, not a
        # second (unplanned) reconfiguration
        assert st["planned"] == 1 and st["unplanned"] == 0

    def test_hot_add_pubsub_binding_publishes_at_commit(self):
        """Grow the graph mid-stream: the local sink is replaced by a
        pubsub publisher.  The new mqttsink registers only AT COMMIT (a
        prepared publisher must never be discoverable before it serves),
        and a viewer joining afterwards receives the stream."""
        total_pre = 6
        rt = Runtime()
        edge = Device("edge")
        p = parse_launch("testsrc name=s width=2 height=2 ! "
                         "tensor_converter name=c ! appsink name=o")
        run = edge.add_pipeline(p, jit=False)
        rt.add_device(edge)
        rt.run(3)
        snk = element_factory("mqttsink", name="snk", pub_topic="cam/live")
        rc = rt.reconfigure(run, lambda plan: plan.remove("o").add(snk)
                            .link("c", "snk"), warm_ticks=1)
        assert snk.registration is None        # not discoverable pre-commit
        rt.run(total_pre - 3)
        assert rc.status == "committed"
        assert snk.registration is not None    # registered at commit
        assert run.frames == total_pre         # the stream never stalled
        published = snk.channel.msgs_sent
        assert published == total_pre - rc.committed_tick + 1
        # a late viewer binds to the hot-added publisher: the retained
        # history replays and every frame published since reaches it
        viewer = Device("viewer")
        vp = parse_launch("mqttsrc sub-topic=cam/live name=vsrc ! "
                          "appsink name=vo")
        vrun = viewer.add_pipeline(vp, jit=False)
        rt.add_device(viewer)
        rt.run(4)
        assert vrun.frames == published + 4    # retained + live, none lost

    def test_commit_defers_while_frame_in_flight(self, chaos):
        """Drain semantics: a run with a frame paused at its query client
        must not cut over mid-frame — the commit defers (``draining``)
        until the parked frame resolves, then lands at the next boundary."""
        rt = Runtime(query_batch=8)
        dev, _, ssrc = _server(rt, "rcA")
        (cl_run,) = _clients(rt, 1)
        harness = chaos(rt)
        harness.kill_server(3, dev, ssrc)      # the tick-3 frame parks
        harness.revive_server(7, dev, ssrc)
        harness.run(6)
        rc = rt.reconfigure(cl_run, cl_run.pipe.reconfig().swap(
            "res", element_factory("appsink")), warm_ticks=0)
        harness.run(1)                         # eligible, but in flight
        assert rc.status == "draining"
        harness.run(1)                         # drained → tick boundary
        assert rc.status == "committed"
        # ticks 1-2 served, the parked frame completed on its OLD epoch at
        # tick 7, and the first post-commit frame followed at tick 8
        assert cl_run.frames == 4
        assert rt.stats()["failover"]["parked_now"] == 0


class TestRollback:
    def test_failed_prepare_rolls_back_with_explicit_stats(self):
        """A bad edit (unknown element) fails at prepare: the request lands
        ``rolled_back`` with the error recorded, serving never blinks, and
        the rollback is an accounted stat — not a silent no-op."""
        rt = Runtime(query_batch=4)
        _, hub_run, _ = _server(rt, "rcA")
        cl = _clients(rt, 2)
        rt.run(3)
        rc = rt.reconfigure(hub_run, hub_run.pipe.reconfig().swap(
            "nope", element_factory("tensor_filter", model="rcB")))
        assert rc.status == "rolled_back"
        assert rc.reason == "prepare-failed"
        assert isinstance(rc.error, ReconfigError)
        rc2 = rt.reconfigure(hub_run,
                             hub_run.pipe.reconfig().relink("ghost", "ssink"))
        assert rc2.status == "rolled_back"
        rt.run(3)
        assert all(r.frames == 6 for r in cl)  # serving unaffected
        assert "b" not in hub_run.params["filt"]    # old params intact
        st = rt.stats()["reconfig"]
        assert st["rollbacks"] == 2
        assert st["planned"] == 0 and st["pending"] == 0

    def test_chaos_kill_mid_warm_rolls_back_never_limbo(self, chaos):
        """The target device dies inside the warm window: the pending
        reconfiguration terminates ``rolled_back`` (never limbo), the old
        params stay, and the kill itself fails the clients over to the
        survivor with zero loss."""
        total = 8
        rt = Runtime(query_batch=8)
        dev_a, run_a, _ = _server(rt, "rcA", name="hubA")
        _, run_b, _ = _server(rt, "rcA", name="hubB")
        cl = _clients(rt, 3)
        harness = chaos(rt)
        box = []
        harness.at(4, lambda: box.append(
            rt.reconfigure(run_a, _swap_filt(run_a, "rcB"), warm_ticks=3)),
            label="request swap on hubA")
        harness.kill_server(5, dev_a, run_a.pipe.elements["ssrc"])
        harness.run(total)
        rc = box[0]
        assert rc.status == "rolled_back"      # terminal, not limbo
        assert rc.reason == "target-dead"
        assert "b" not in run_a.params["filt"]  # rcB params never landed
        st = rt.stats()["reconfig"]
        assert st["pending"] == 0
        assert st["rollbacks"] == 1
        assert st["unplanned"] >= 1            # the kill, same machinery
        assert all(r.frames == total for r in cl)   # hubB served, zero loss
        assert run_b.frames >= 3 * (total - 5)


class TestFailoverIsAReconfiguration:
    def test_initial_construction_counts_no_reconfigs(self):
        rt = Runtime(query_batch=4)
        _server(rt, "rcA")
        _clients(rt, 2)
        rt.run(3)
        assert rt.stats()["reconfig"]["reconfigs"] == 0

    def test_kill_and_revival_are_unplanned_reconfigurations(self, chaos):
        """The PR-3 failover special case is gone: broker liveness events
        route through the reconfiguration manager, so a death and a revival
        each show up as one unplanned reconfiguration — with serving intact
        through both."""
        total = 8
        rt = Runtime(query_batch=8)
        dev_a, _, ssrc_a = _server(rt, "rcA", name="hubA")
        _server(rt, "rcA", name="hubB")
        cl = _clients(rt, 2)
        harness = chaos(rt)
        harness.kill_server(3, dev_a, ssrc_a)
        harness.revive_server(6, dev_a, ssrc_a)
        harness.run(total)
        st = rt.stats()["reconfig"]
        assert st["unplanned"] == 2            # down + register, one each
        assert st["planned"] == 0
        assert [(k, s) for _, k, s, _ in rt.reconfig.log] == \
            [("unplanned", "down"), ("unplanned", "register")]
        assert all(r.frames == total for r in cl)   # zero loss throughout
