"""Leaky-queue semantics under overload (paper §5.1: 'Configurations and
behaviors of queues ... are crucial for the efficiency of parallelism';
leaky=2 drops older buffers so live streams never stall on slow consumers)."""
import jax.numpy as jnp

from repro.core import Channel, StreamBuffer, parse_launch
from repro.runtime import Device, Runtime


def test_leaky_channel_bounds_latency_under_slow_consumer():
    """A publisher at 60 Hz with a consumer that drains 1-in-3 frames: the
    channel stays bounded and always delivers the FRESHEST frames."""
    rt = Runtime()
    pub = Device("cam")
    p = parse_launch("testsrc width=8 height=8 ! tensor_converter ! "
                     "mqttsink pub-topic=live name=snk")
    pub.add_pipeline(p, jit=False)
    rt.add_device(pub)
    sub = Device("screen")
    s = parse_launch("mqttsrc sub-topic=live name=src ! appsink name=o")
    sub.add_pipeline(s, jit=False)
    rt.add_device(sub)

    src = s.elements["src"]
    run = sub.runs[0]
    # drive publisher every tick, consumer only every 3rd tick
    for t in range(60):
        rt._ntp_ref.advance(rt.tick_ns)
        for dev in rt.devices:
            dev.clock.advance(rt.tick_ns)
        rt._run_once(pub.runs[0])
        if t % 3 == 0 and rt._ready(run):
            rt._run_once(run)
    rx = src._rx
    assert rx is not None
    assert len(rx) <= rx.capacity            # bounded, never grows
    assert rx.drops > 0                      # old frames were dropped (leaky)
    # the next frame the consumer sees is recent, not 40 frames stale
    nxt = rx.pop()
    assert int(nxt.pts) >= 0


def test_channel_capacity_one_keeps_only_freshest():
    ch = Channel(capacity=1)
    for i in range(5):
        ch.push(StreamBuffer(tensors=(jnp.full((1,), i),)))
    assert ch.drops == 4
    assert float(ch.pop().tensor[0]) == 4.0
