"""Timestamp synchronization (paper §4.2.3 / Fig. 4): NTP offset estimation
and cross-pipeline rebasing minimize inter-source timestamp deltas."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Broker, SimClock, StreamBuffer, ntp_offset, parse_launch
from repro.core.sync import PipelineClock
from repro.runtime import Device, Runtime


class TestNTP:
    @given(st.integers(-10 ** 9, 10 ** 9))
    @settings(max_examples=30, deadline=None)
    def test_offset_estimation_no_jitter(self, skew):
        client = SimClock(skew_ns=0)
        server = SimClock(skew_ns=skew)
        est = ntp_offset(client, server, network_delay_ns=300_000)
        assert abs(est - skew) <= 1

    def test_offset_with_jitter_bounded(self):
        client = SimClock(skew_ns=0, jitter_ns=50_000, seed=1)
        server = SimClock(skew_ns=7_000_000, jitter_ns=50_000, seed=2)
        est = ntp_offset(client, server, network_delay_ns=300_000, rounds=16)
        assert abs(est - 7_000_000) < 200_000  # min-delay filtering bounds err


class TestRebase:
    def test_rebase_aligns_remote_pts(self):
        # publisher started 5ms after subscriber, clock skewed +2ms
        sub_clock = PipelineClock(SimClock(skew_ns=0)).start()
        pub_clock = PipelineClock(SimClock(skew_ns=2_000_000),
                                  utc_offset_ns=-2_000_000)
        pub_clock.clock.advance(5_000_000)
        pub_clock.start()
        buf = StreamBuffer(tensors=(np.zeros(1),), pts=np.int64(1_000_000),
                           meta={"base_time_utc": pub_clock.base_time_utc()})
        rebased = sub_clock.rebase(buf)
        # frame created 5ms (pub start) + 1ms (pts) after sub start
        assert int(rebased.pts) == 6_000_000


class TestEndToEndSync:
    def _run(self, latency_ticks: int, skew_ns: int):
        rt = Runtime()
        cams = []
        for i, (skew, lat) in enumerate([(0, 0), (skew_ns, latency_ticks)]):
            dev = Device(f"cam{i}", clock=SimClock(skew_ns=skew, seed=i))
            p = parse_launch(
                f"testsrc width=4 height=4 ! tensor_converter ! "
                f"mqttsink pub-topic=cam/{i}")
            dev.add_pipeline(p, jit=False)
            # inject latency (the paper uses queue2 to delay a publisher)
            p_sink = [e for e in p.elements.values()
                      if e.factory_name == "mqttsink"][0]
            p_sink.channel.latency_ns = lat * 16_666_667
            rt.add_device(dev)
            cams.append(dev)
        disp = Device("display", clock=SimClock(skew_ns=123_456, seed=9))
        pd = parse_launch("""
            mqttsrc sub-topic=cam/0 ! queue ! mux.sink_0
            mqttsrc sub-topic=cam/1 ! queue ! mux.sink_1
            tensor_mux name=mux ! appsink name=out
        """)
        disp.add_pipeline(pd, jit=False)
        rt.add_device(disp)
        rt.run(6)
        return disp.runs[0]

    def test_skewed_clocks_still_align(self):
        """With NTP-corrected base times, frames from a device with 50ms
        clock skew mux with ~frame-period deltas, not 50ms errors."""
        run = self._run(latency_ticks=0, skew_ns=50_000_000)
        assert run.frames >= 4
        last = run.sink_log["out"][-1]
        # both tensors in the muxed buffer came from the same frame index:
        # pts_min over inputs is taken; check buffer pts sane (not off by skew)
        assert abs(int(last.pts)) < 40_000_000  # << 50ms skew
